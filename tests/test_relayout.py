"""Layout / relayout / transfer-cost tests (single-device semantics +
analytic-cost properties; traffic realism is in tests/multidevice/)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import layouts as L
from repro.core.errors import LayoutError
from repro.core.relayout import relayout, shard_intervals, transfer_cost
from repro.core.sharding import single_device_mesh


class TestShardIntervals:
    def test_even_split(self):
        iv = shard_intervals(8, 4)
        np.testing.assert_array_equal(iv, [[0, 2], [2, 4], [4, 6], [6, 8]])

    def test_uneven_split_pads_like_xla(self):
        iv = shard_intervals(10, 4)
        np.testing.assert_array_equal(iv, [[0, 3], [3, 6], [6, 9], [9, 10]])

    def test_more_shards_than_rows(self):
        iv = shard_intervals(2, 4)
        assert (iv[:, 1] <= 2).all()
        covered = sum(b - a for a, b in iv)
        assert covered == 2

    @given(st.integers(1, 1000), st.integers(1, 64))
    @settings(max_examples=200, deadline=None)
    def test_intervals_partition_range(self, n, shards):
        iv = shard_intervals(n, shards)
        assert iv.shape == (shards, 2)
        assert (iv[:, 0] <= iv[:, 1]).all()
        assert sum(int(b - a) for a, b in iv) == n
        # contiguous, ordered
        flat = [x for a, b in iv for x in range(a, b)]
        assert flat == list(range(n))


class TestCyclicPermutation:
    @given(st.integers(1, 500), st.integers(1, 32))
    @settings(max_examples=200, deadline=None)
    def test_is_permutation(self, n, shards):
        perm = L.cyclic_permutation(n, shards)
        assert sorted(perm.tolist()) == list(range(n))

    def test_inverse(self):
        perm = L.cyclic_permutation(17, 4)
        inv = L.inverse_permutation(perm)
        np.testing.assert_array_equal(perm[inv], np.arange(17))

    def test_cyclic_assignment(self):
        # physical shard s holds logical rows s, s+p, s+2p, ...
        perm = L.cyclic_permutation(8, 2)
        np.testing.assert_array_equal(perm, [0, 2, 4, 6, 1, 3, 5, 7])


class TestLayoutSpecs:
    def test_by_name(self):
        assert L.by_name("row") is L.ROW
        assert L.by_name("grid_cyclic").cyclic
        with pytest.raises(LayoutError):
            L.by_name("nope")

    def test_validate_rejects_non_2d(self, mesh1):
        with pytest.raises(LayoutError):
            L.GRID.validate((3, 4, 5), mesh1)

    def test_partition_spec_drops_absent_axes(self, mesh1):
        # mesh has no 'pod' axis; specs must still resolve
        spec = L.ROW.partition_spec(mesh1)
        assert "pod" not in str(spec)

    def test_grid_shape_single_device(self, mesh1):
        assert L.GRID.grid_shape(mesh1) == (1, 1)


class TestTransferCostModel:
    def test_single_device_moves_nothing(self, mesh1):
        c = transfer_cost((64, 32), "float32", L.ROW, L.GRID, mesh1)
        assert c.bytes_moved == 0
        assert c.messages == 0

    def test_identity_relayout_free(self, mesh1):
        c = transfer_cost((64, 32), "float32", L.GRID, L.GRID, mesh1)
        assert c.bytes_moved == 0

    @given(
        st.integers(1, 300),
        st.integers(1, 300),
        st.sampled_from(["float32", "float64", "bfloat16"]),
    )
    @settings(max_examples=100, deadline=None)
    def test_bytes_total_exact(self, m, n, dtype):
        mesh = single_device_mesh()
        c = transfer_cost((m, n), dtype, L.ROW, L.GRID, mesh)
        assert c.bytes_total == m * n * jnp.dtype(dtype).itemsize

    def test_relayout_preserves_values(self, mesh1, rng):
        a = jnp.asarray(rng.standard_normal((12, 6)).astype(np.float32))
        out = relayout(a, L.GRID, mesh1, src=L.ROW)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a))

    def test_cyclic_relayout_roundtrip(self, mesh1, rng):
        a = jnp.asarray(rng.standard_normal((13, 5)).astype(np.float32))
        cyc = relayout(a, L.GRID.with_cyclic(), mesh1, src=L.ROW)
        back = relayout(cyc, L.ROW, mesh1, src=L.GRID.with_cyclic())
        np.testing.assert_allclose(np.asarray(back), np.asarray(a))
