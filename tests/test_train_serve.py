"""Training/serving substrate tests: optimizer, schedules, data pipeline,
checkpointing, short end-to-end training, serve engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import InputShape, get_config
from repro.models import build_model
from repro.serve import Request, ServeEngine
from repro.train import AdamW, SyntheticTokens, constant, cosine_warmup, make_train_step
from repro.train import checkpoint as ckpt
from repro.train.loop import train


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        opt = AdamW(learning_rate=constant(0.1), weight_decay=0.0, grad_clip_norm=None)
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = opt.update(grads, state, params)
        np.testing.assert_allclose(np.asarray(params["w"]), 0.0, atol=1e-2)

    def test_grad_clipping(self):
        opt = AdamW(learning_rate=constant(0.1), grad_clip_norm=1.0)
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        _, _, metrics = opt.update({"w": jnp.full(3, 1e6)}, state, params)
        assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip

    def test_weight_decay_skips_vectors(self):
        opt = AdamW(learning_rate=constant(0.0), weight_decay=1.0)
        # lr=0 -> no update at all; decay is inside the lr-scaled delta
        params = {"m": jnp.ones((2, 2)), "v": jnp.ones(2)}
        state = opt.init(params)
        new, _, _ = opt.update(
            {"m": jnp.zeros((2, 2)), "v": jnp.zeros(2)}, state, params
        )
        np.testing.assert_allclose(np.asarray(new["m"]), 1.0)

    def test_bf16_moments_dtype(self):
        opt = AdamW(learning_rate=constant(0.1), moment_dtype="bfloat16")
        state = opt.init({"w": jnp.zeros(4)})
        assert state.mu["w"].dtype == jnp.bfloat16


class TestSchedules:
    def test_cosine_warmup_shape(self):
        sched = cosine_warmup(1.0, warmup_steps=10, total_steps=100)
        assert float(sched(jnp.asarray(0))) == 0.0
        np.testing.assert_allclose(float(sched(jnp.asarray(10))), 1.0, rtol=1e-5)
        assert float(sched(jnp.asarray(100))) < 0.11
        # monotone decay after warmup
        vals = [float(sched(jnp.asarray(s))) for s in range(10, 101, 10)]
        assert all(a >= b for a, b in zip(vals, vals[1:]))


class TestData:
    def test_batches_deterministic_and_seekable(self, mesh1):
        cfg = get_config("qwen2-1.5b", smoke=True)
        shape = InputShape("t", 32, 4, "train")
        data = SyntheticTokens(cfg, shape, mesh1, seed=3)
        b1 = data.batch_at(7)
        b2 = data.batch_at(7)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
        b3 = data.batch_at(8)
        assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))

    def test_tokens_in_vocab(self, mesh1):
        cfg = get_config("qwen2-1.5b", smoke=True)
        data = SyntheticTokens(cfg, InputShape("t", 64, 2, "train"), mesh1)
        toks = np.asarray(data.batch_at(0)["tokens"])
        assert toks.min() >= 0 and toks.max() < cfg.vocab

    def test_markov_structure_is_learnable(self, mesh1):
        # consecutive pairs must repeat far more often than uniform chance
        cfg = get_config("qwen2-1.5b", smoke=True)
        data = SyntheticTokens(cfg, InputShape("t", 256, 4, "train"), mesh1, seed=1)
        toks = np.asarray(data.batch_at(0)["tokens"])
        pairs = set()
        for row in toks:
            pairs.update(zip(row[:-1], row[1:]))
        # 4*255 pairs drawn from at most 512*8 possible transitions, far
        # fewer than the 512^2 of an unstructured stream
        assert len(pairs) < 512 * 8 * 1.1


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, mesh1):
        cfg = get_config("qwen2-1.5b", smoke=True)
        model = build_model(cfg, mesh1)
        params = model.init(jax.random.PRNGKey(0))
        path = ckpt.save(str(tmp_path), 5, {"params": params})
        assert os.path.exists(os.path.join(path, "manifest.json"))
        assert ckpt.latest_step(str(tmp_path)) == 5
        restored = ckpt.restore(str(tmp_path), 5, {"params": params})
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            params, restored["params"],
        )

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            ckpt.restore(str(tmp_path), 1, {"w": jnp.zeros((3, 3))})


class TestTrainLoop:
    def test_loss_decreases(self, mesh1):
        cfg = get_config("qwen2-1.5b", smoke=True)
        shape = InputShape("t", 64, 8, "train")
        hist = train(cfg, shape, mesh1, steps=25, peak_lr=1e-3, warmup=5,
                     log_every=8, log_fn=lambda s: None)
        assert hist["loss"][-1] < hist["loss"][0] - 0.02

    def test_microbatching_matches_full_batch_grads(self, mesh1):
        cfg = get_config("qwen2-1.5b", smoke=True)
        model = build_model(cfg, mesh1)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW(learning_rate=constant(1e-3))
        from repro.models.registry import make_batch

        batch = make_batch(cfg, InputShape("t", 32, 4, "train"), jax.random.PRNGKey(1))
        with mesh1:
            s1 = opt.init(params)
            p1, _, m1 = jax.jit(make_train_step(model, opt))(params, s1, batch)
            s2 = opt.init(params)
            p2, _, m2 = jax.jit(make_train_step(model, opt, microbatches=2))(params, s2, batch)
        # losses averaged over microbatches == full-batch loss (linearity)
        np.testing.assert_allclose(float(m1["xent"]), float(m2["xent"]), rtol=1e-3)
        # updated params agree to optimizer tolerance
        diffs = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
            p1,
            p2,
        )
        assert max(jax.tree_util.tree_leaves(diffs)) < 5e-3


class TestServeEngine:
    def test_greedy_decode_deterministic(self, mesh1):
        cfg = get_config("qwen2-1.5b", smoke=True)
        model = build_model(cfg, mesh1)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, mesh1, params, batch_size=2, context=64)
        req = Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=6)
        o1 = eng.serve([req])[0]
        o2 = eng.serve([req])[0]
        np.testing.assert_array_equal(o1.tokens, o2.tokens)

    def test_eos_truncates(self, mesh1):
        cfg = get_config("qwen2-1.5b", smoke=True)
        model = build_model(cfg, mesh1)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, mesh1, params, batch_size=1, context=64)
        req = Request(prompt=np.array([1], np.int32), max_new_tokens=8)
        out = eng.serve([req])[0]
        eos = int(out.tokens[2])
        req_eos = Request(prompt=np.array([1], np.int32), max_new_tokens=8, eos_id=eos)
        out2 = eng.serve([req_eos])[0]
        assert len(out2.tokens) <= 3

    def test_async_submit_matches_serve(self, mesh1):
        # batches submitted through the task queue give identical results to
        # the synchronous path, and submission returns before decode finishes
        cfg = get_config("qwen2-1.5b", smoke=True)
        model = build_model(cfg, mesh1)
        params = model.init(jax.random.PRNGKey(0))
        with ServeEngine(cfg, mesh1, params, batch_size=2, context=64) as eng:
            req = Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=6)
            want = eng.serve([req])[0]
            futs = [eng.submit([req]) for _ in range(3)]
            eng.drain(timeout=300)
            for f in futs:
                assert f.done()
                np.testing.assert_array_equal(f.result()[0].tokens, want.tokens)

    def test_submit_after_close_rejected(self, mesh1):
        from repro.core.errors import TaskError

        cfg = get_config("qwen2-1.5b", smoke=True)
        model = build_model(cfg, mesh1)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, mesh1, params, batch_size=1, context=64)
        req = Request(prompt=np.array([1], np.int32), max_new_tokens=2)
        eng.submit([req]).result(timeout=300)
        eng.close()
        with pytest.raises(TaskError):
            eng.submit([req])
