"""Roofline machinery tests: HLO collective parsing, analytic attention
model, and a miniature end-to-end dry-run on a subprocess-forced mesh."""

import os
import subprocess
import sys

import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.roofline.attention_model import attention_roofline
from repro.roofline.hlo import parse_collectives, shape_bytes


class TestShapeBytes:
    def test_simple(self):
        assert shape_bytes("f32[16,16]") == 1024
        assert shape_bytes("bf16[8]") == 16
        assert shape_bytes("pred[4,4]") == 16

    def test_tuple_result(self):
        assert shape_bytes("(f32[4,4], bf16[2,2])") == 64 + 8

    def test_scalar_and_unknown(self):
        assert shape_bytes("f32[]") == 4  # scalar: empty dims -> one element
        assert shape_bytes("token[]") == 0


class TestCollectiveParse:
    HLO = """
  %all-gather.1 = f32[16,4096]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={1}
  %all-reduce.2 = bf16[1024]{0} all-reduce(%p1), replica_groups={{0,1,2,3}}, to_apply=%add
  %reduce-scatter.3 = f32[64]{0} reduce-scatter(%p2), replica_groups=[8,2]<=[16]
  %all-to-all.4 = bf16[32,32]{1,0} all-to-all(%p3), replica_groups=[4,4]<=[16]
  %collective-permute.5 = f32[10]{0} collective-permute(%p4), source_target_pairs={{0,1}}
"""

    def test_counts_and_kinds(self):
        summ = parse_collectives(self.HLO, default_group=16)
        kinds = summ.by_kind()
        assert set(kinds) == {
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute",
        }
        assert all(c == 1 for c, _ in kinds.values())

    def test_ring_traffic_model(self):
        summ = parse_collectives(self.HLO, default_group=16)
        ops = {o.kind: o for o in summ.ops}
        ag = ops["all-gather"]
        assert ag.group_size == 16
        assert ag.traffic_bytes == int(16 * 4096 * 4 * 15 / 16)
        ar = ops["all-reduce"]
        assert ar.group_size == 4
        assert ar.traffic_bytes == int(2 * 1024 * 2 * 3 / 4)
        rs = ops["reduce-scatter"]
        assert rs.group_size == 2
        assert rs.traffic_bytes == 64 * 4 * 1
        cp = ops["collective-permute"]
        assert cp.traffic_bytes == 40

    def test_while_detection(self):
        assert parse_collectives("%w = f32[2] while(%a), body=%b", default_group=4).has_while
        assert not parse_collectives(self.HLO, default_group=4).has_while

    def test_single_device_group_is_free(self):
        summ = parse_collectives(
            "%all-reduce.9 = f32[100]{0} all-reduce(%x), replica_groups={{0}}",
            default_group=1,
        )
        assert summ.total_traffic == 0


class TestAttentionModel:
    def test_causal_halves_flops(self):
        cfg = get_config("deepseek-7b")
        shape = INPUT_SHAPES["prefill_32k"]
        t = attention_roofline(cfg, shape)
        # fwd flops = n_layers * 4 B L (L/2) Hq hd
        expect = cfg.n_layers * 4 * shape.global_batch * 32768 * 16384 * cfg.n_heads * cfg.head_dim
        np.testing.assert_allclose(t.flops_global, expect, rtol=1e-6)

    def test_train_multiplier(self):
        cfg = get_config("deepseek-7b")
        tr = attention_roofline(cfg, INPUT_SHAPES["train_4k"], remat=True)
        cfg2 = get_config("deepseek-7b")
        fw = attention_roofline(cfg2, INPUT_SHAPES["train_4k"], remat=False)
        np.testing.assert_allclose(tr.flops_global / fw.flops_global, 4.0 / 3.0, rtol=1e-6)

    def test_decode_has_no_correction(self):
        cfg = get_config("deepseek-7b")
        t = attention_roofline(cfg, INPUT_SHAPES["decode_32k"])
        assert t.flops_global == 0.0

    def test_ssm_has_no_attention(self):
        cfg = get_config("mamba2-130m")
        t = attention_roofline(cfg, INPUT_SHAPES["train_4k"])
        assert t.flops_global == 0.0

    def test_window_caps_context(self):
        cfg = get_config("qwen2-1.5b")
        full = attention_roofline(cfg, INPUT_SHAPES["prefill_32k"])
        import dataclasses

        win = attention_roofline(
            cfg, dataclasses.replace(INPUT_SHAPES["long_500k"], kind="prefill")
        )
        # long_500k uses the sliding window: per-token kv length 4096 vs 16384
        per_tok_full = full.flops_global / (32 * 32768)
        per_tok_win = win.flops_global / (1 * 524288)
        assert per_tok_win < per_tok_full


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import repro.launch.dryrun as dr
import jax
from repro.core.layouts import AXIS_DATA, AXIS_MODEL
mesh = jax.make_mesh((2, 4), (AXIS_DATA, AXIS_MODEL))
import repro.configs.base as base
import dataclasses
# shrink shapes so the mini run is quick
base.INPUT_SHAPES = {
    "train_4k": dataclasses.replace(base.INPUT_SHAPES["train_4k"], seq_len=128, global_batch=4),
    "decode_32k": dataclasses.replace(base.INPUT_SHAPES["decode_32k"], seq_len=256, global_batch=4),
}
dr.INPUT_SHAPES = base.INPUT_SHAPES
orig_get = dr.get_config
dr.get_config = lambda a, **kw: orig_get(a, smoke=True)
for shape in ("train_4k", "decode_32k"):
    res = dr.lower_combo("qwen2-1.5b", shape, mesh, verbose=False)
    assert res.ok and not res.skipped, res
    r = res.report
    assert r["flops_per_device"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
print("MINI_DRYRUN_OK")
"""


def test_mini_dryrun_end_to_end(tmp_path):
    """The full dry-run pipeline (lower, compile, fit, roofline) on a tiny
    mesh/config in a subprocess."""
    script = tmp_path / "mini_dryrun.py"
    script.write_text(MINI_DRYRUN)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, str(script)], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MINI_DRYRUN_OK" in proc.stdout
