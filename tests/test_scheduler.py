"""PlacementScheduler unit tests (DESIGN.md §12): ticket lifecycle, scoring,
watermarks, aging, shared worker groups.

Tier-1 drives the scheduler with fake (unhashable-on-purpose) devices so the
policy is tested in isolation from JAX; the tier2 tests at the bottom run the
same contention patterns through a real engine and assert end-to-end
guarantees (aging bound under a small-request storm, bit-identical reads
through a shared worker group with zero engine-side attach bytes).
"""

import json
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

import repro
from repro.core.errors import AdmissionTimeout, WorkerAllocationError
from repro.core.memgov import MemoryGovernor
from repro.core.scheduler import (
    PLACED,
    PlacementRequest,
    PlacementScheduler,
    near_square_grid,
)


class FakeGov:
    """Governor stub: controllable pressure, optional hard admission gate."""

    def __init__(self, pressure=0, gate=False, watermarks=None):
        self._pressure = pressure
        self.gate = gate
        self.watermarks = watermarks

    def pressure(self):
        return self._pressure

    @property
    def has_watermarks(self):
        return self.watermarks is not None

    def admission_gate(self):
        return self.gate


class FakeResidents:
    """Resident-store stub: keys -> device-id frozensets."""

    enabled = True

    def __init__(self, placements=None):
        self.placements = placements or {}

    def device_affinity(self, keys):
        return [self.placements[k] for k in keys if k in self.placements]


def fake_devices(n=8):
    # SimpleNamespace is unhashable by design here: the scheduler must key
    # its bookkeeping on device ids, never on device objects.
    return [SimpleNamespace(id=i, platform="fake", __hash__=None) for i in range(n)]


def make_sched(n=8, *, memgov=None, residents=None, aging_bound=4):
    return PlacementScheduler(
        fake_devices(n),
        memgov=memgov or FakeGov(),
        residents=residents or FakeResidents(),
        aging_bound=aging_bound,
    )


def ids(devs):
    return [d.id for d in devs]


# ---------------------------------------------------------------------------
# request surface + basic placement
# ---------------------------------------------------------------------------


class TestPlacementRequest:
    def test_affinity_and_grid_coerced_to_tuples(self):
        req = PlacementRequest(affinity=[1, 2], grid=[2, 3])
        assert req.affinity == (1, 2)
        assert req.grid == (2, 3)

    def test_defaults(self):
        req = PlacementRequest()
        assert req.workers is None and req.grid is None
        assert req.priority == 0 and req.deadline is None and req.allow_shared

    def test_near_square_grid(self):
        assert near_square_grid(6) == (2, 3)
        assert near_square_grid(7) == (1, 7)
        assert near_square_grid(16) == (4, 4)


class TestBasicPlacement:
    def test_immediate_placement_and_ticket_summary(self):
        sched = make_sched(8)
        t = sched.submit(PlacementRequest(workers=4, deadline=0))
        assert t.state == PLACED
        assert ids(t.devices) == [0, 1, 2, 3]
        assert t.grid == (2, 2)
        assert not t.shared
        summary = t.summary()
        json.dumps(summary)  # must be wire-safe
        assert summary["workers"] == 4 and summary["devices"] == [0, 1, 2, 3]
        assert sched.admissions["immediate"] == 1

    def test_flexible_request_takes_all_free(self):
        sched = make_sched(8)
        a = sched.submit(PlacementRequest(workers=2, deadline=0))
        b = sched.submit(PlacementRequest(deadline=0))
        assert b.n == 6 and b.flexible
        sched.abort(a)
        sched.abort(b)
        assert ids(sched.free_devices) == list(range(8))

    def test_explicit_grid_overrides_workers(self):
        sched = make_sched(8)
        t = sched.submit(PlacementRequest(grid=(1, 6), deadline=0))
        assert t.n == 6 and t.grid == (1, 6)

    def test_impossible_size_fails_fast_even_with_deadline(self):
        sched = make_sched(4)
        with pytest.raises(WorkerAllocationError, match="the engine only has 4"):
            sched.submit(PlacementRequest(workers=5, deadline=30))

    def test_nonpositive_sizes_rejected(self):
        sched = make_sched(4)
        with pytest.raises(WorkerAllocationError, match="need at least 1"):
            sched.submit(PlacementRequest(workers=0, deadline=0))
        with pytest.raises(WorkerAllocationError, match="must be positive"):
            sched.submit(PlacementRequest(grid=(0, 2), deadline=0))

    def test_fail_fast_when_pool_drained(self):
        sched = make_sched(4)
        hold = sched.submit(PlacementRequest(workers=3, deadline=0))
        with pytest.raises(WorkerAllocationError, match="only 1 of 4 are available"):
            sched.submit(PlacementRequest(workers=2, deadline=0))
        sched.abort(hold)

    def test_deadline_expiry_raises_admission_timeout(self):
        sched = make_sched(2)
        hold = sched.submit(PlacementRequest(workers=2, deadline=0))
        t0 = time.monotonic()
        with pytest.raises(AdmissionTimeout, match="2 worker"):
            sched.submit(PlacementRequest(workers=2, deadline=0.2))
        assert time.monotonic() - t0 >= 0.2
        assert sched.admissions["timeouts"] == 1
        assert sched.stats()["timed_out"] == 1
        sched.abort(hold)

    def test_queued_ticket_places_on_release(self):
        sched = make_sched(4)
        hold = sched.submit(PlacementRequest(workers=4, deadline=0))
        out = {}

        def waiter():
            out["t"] = sched.submit(PlacementRequest(workers=2, deadline=10))

        th = threading.Thread(target=waiter)
        th.start()
        time.sleep(0.05)
        assert sched.queued == 1
        sched.abort(hold)
        th.join(timeout=5)
        assert out["t"].state == PLACED
        assert sched.admissions["queued"] == 1


# ---------------------------------------------------------------------------
# scoring: smallest fit + content affinity
# ---------------------------------------------------------------------------


class TestScoring:
    def _fragmented(self):
        """Free pool [0,1] + [4..7]: a 2-run and a 4-run."""
        sched = make_sched(8)
        hold = sched.submit(PlacementRequest(grid=(1, 2), deadline=0))
        big = sched.submit(PlacementRequest(workers=6, deadline=0))
        sched.abort(big)
        # re-place [2,3] so the pool is fragmented around it
        mid = sched.submit(PlacementRequest(workers=2, deadline=0))
        sched.abort(hold)
        assert ids(sched.free_devices) == [0, 1, 4, 5, 6, 7]
        return sched, mid

    def test_smallest_fit_prefers_exact_run(self):
        sched, _ = self._fragmented()
        assert ids(sched.pick_block(2, ())) == [0, 1]
        assert sched.admissions["smallest_fit_hits"] == 1

    def test_large_request_takes_large_run(self):
        sched, _ = self._fragmented()
        assert ids(sched.pick_block(4, ())) == [4, 5, 6, 7]

    def test_spanning_runs_when_no_single_run_fits(self):
        sched, _ = self._fragmented()
        assert ids(sched.pick_block(5, ())) == [0, 1, 4, 5, 6]

    def test_affinity_beats_smallest_fit(self):
        residents = FakeResidents({("k",): frozenset({4, 5})})
        sched = PlacementScheduler(
            fake_devices(8), memgov=FakeGov(), residents=residents, aging_bound=4
        )
        hold = sched.submit(PlacementRequest(workers=2, deadline=0))  # [0,1] gone
        # Without keys smallest-fit would pick the front of the big run; the
        # declared dataset pulls placement onto the warm devices instead.
        assert ids(sched.pick_block(2, [("k",)])) == [4, 5]
        assert sched.admissions["affinity_hits"] == 1
        sched.abort(hold)

    def test_unknown_keys_do_not_steer(self):
        sched = make_sched(8)
        assert ids(sched.pick_block(2, [("nope",)])) == [0, 1]
        assert sched.admissions["affinity_hits"] == 0


# ---------------------------------------------------------------------------
# priority + anti-starvation aging
# ---------------------------------------------------------------------------


class TestPriorityAndAging:
    def test_higher_priority_places_first(self):
        sched = make_sched(2)
        hold = sched.submit(PlacementRequest(workers=2, deadline=0))
        order = []

        def waiter(tag, prio):
            t = sched.submit(PlacementRequest(workers=2, priority=prio, deadline=10))
            order.append(tag)
            time.sleep(0.02)
            sched.abort(t)

        lo = threading.Thread(target=waiter, args=("lo", 0))
        lo.start()
        time.sleep(0.05)
        hi = threading.Thread(target=waiter, args=("hi", 5))
        hi.start()
        time.sleep(0.05)
        sched.abort(hold)
        lo.join(timeout=5)
        hi.join(timeout=5)
        assert order == ["hi", "lo"]

    def test_aging_bound_caps_leapfrogging(self):
        """A blocked large ticket is passed by at most aging_bound smalls."""
        bound = 2
        sched = make_sched(8, aging_bound=bound)
        holders = [sched.submit(PlacementRequest(workers=1, deadline=0)) for _ in range(8)]
        results, errors = {}, {}

        def run(tag, req, hold_s=None):
            try:
                t = sched.submit(req)
                results[tag] = t
                if hold_s is not None:
                    time.sleep(hold_s)
                    sched.abort(t)
            except Exception as e:  # pragma: no cover - failure diagnostics
                errors[tag] = e

        large = threading.Thread(
            target=run, args=("L", PlacementRequest(workers=8, deadline=30))
        )
        large.start()
        time.sleep(0.05)
        smalls = [
            threading.Thread(
                target=run, args=(f"s{i}", PlacementRequest(workers=1, deadline=30), 0.02)
            )
            for i in range(4)
        ]
        for th in smalls:
            th.start()
        time.sleep(0.05)
        for h in holders:  # drain the pool one device at a time
            sched.abort(h)
            time.sleep(0.03)
        large.join(timeout=15)
        assert not errors, errors
        big = results["L"]
        assert big.state == PLACED
        assert big.passed_by <= bound
        assert big.aged
        assert sched.stats()["aged"] == 1
        sched.abort(big)
        for th in smalls:
            th.join(timeout=15)
        assert not errors, errors
        deadline = time.monotonic() + 5
        while len(sched.free_devices) < 8 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert ids(sched.free_devices) == list(range(8))


# ---------------------------------------------------------------------------
# pressure watermarks
# ---------------------------------------------------------------------------


class TestWatermarks:
    def test_gate_blocks_private_placement(self):
        gov = FakeGov(pressure=900, gate=True, watermarks=(0.9, 0.5))
        sched = make_sched(4, memgov=gov)
        with pytest.raises(WorkerAllocationError):
            sched.submit(PlacementRequest(workers=2, deadline=0))
        assert sched.stats()["pressure_blocked"] == 1
        gov.gate = False
        t = sched.submit(PlacementRequest(workers=2, deadline=0))
        assert t.state == PLACED

    def test_governor_hysteresis(self):
        gov = MemoryGovernor(budget=1000)
        gov.set_watermarks(0.9, 0.5)
        assert gov.watermarks == (0.9, 0.5) and gov.has_watermarks
        assert not gov.admission_gate()
        gov.reserve(950)
        assert gov.admission_gate()  # above high: gate closes
        gov.unreserve(350)
        assert gov.admission_gate()  # 600 > low*1000: hysteresis holds
        gov.unreserve(350)
        assert not gov.admission_gate()  # 250 < 500: gate reopens
        gov.reserve(400)
        assert not gov.admission_gate()  # 650 < high: still open on the way up

    def test_watermark_validation(self):
        gov = MemoryGovernor(budget=1000)
        with pytest.raises(ValueError):
            gov.set_watermarks(0.5, 0.9)
        with pytest.raises(ValueError):
            gov.set_watermarks(0.0, 0.0)
        gov.set_watermarks(0.8, 0.4)
        gov.clear_watermarks()
        assert not gov.has_watermarks

    def test_no_watermarks_means_no_gate(self):
        gov = MemoryGovernor(budget=100)
        gov.reserve(100)
        assert not gov.admission_gate()

    def test_pressure_sampling(self):
        gov = FakeGov(pressure=123)
        sched = make_sched(4, memgov=gov)
        t = sched.submit(PlacementRequest(workers=2, deadline=0))
        assert t.pressure_at_queue == 123
        assert t.pressure_at_placement == 123
        assert sched.admissions["pressure_at_placement"] == 123
        # last_queued_pressure samples on every pass with a non-empty queue
        assert sched.admissions["last_queued_pressure"] == 123
        gov._pressure = 456
        sched.submit(PlacementRequest(workers=2, deadline=0))
        assert sched.admissions["last_queued_pressure"] == 456


# ---------------------------------------------------------------------------
# shared worker groups
# ---------------------------------------------------------------------------


class TestSharedGroups:
    def _sched_with_content(self):
        residents = FakeResidents()
        sched = PlacementScheduler(
            fake_devices(8), memgov=FakeGov(), residents=residents, aging_bound=4
        )
        owner = sched.submit(PlacementRequest(workers=4, deadline=0))
        sched.bind(owner, session_id=1)
        residents.placements[("x",)] = owner.group.device_ids
        return sched, owner

    def test_affine_ticket_joins_group(self):
        sched, owner = self._sched_with_content()
        reader = sched.submit(PlacementRequest(affinity=("x",), deadline=0), keys=[("x",)])
        assert reader.shared
        assert reader.group is owner.group
        assert ids(reader.devices) == ids(owner.devices)
        assert reader.grid == owner.grid  # flexible ticket adopts the group grid
        assert reader.n == 4
        assert sched.stats()["shared_joins"] == 1
        assert sched.stats()["shared_groups"] == 1
        # the join consumed no devices
        assert len(sched.free_devices) == 4

    def test_join_bypasses_pressure_gate(self):
        sched, owner = self._sched_with_content()
        sched.memgov.gate = True
        sched.memgov.watermarks = (0.9, 0.5)
        reader = sched.submit(PlacementRequest(affinity=("x",), deadline=0), keys=[("x",)])
        assert reader.shared

    def test_allow_shared_false_forces_private(self):
        sched, owner = self._sched_with_content()
        t = sched.submit(
            PlacementRequest(workers=4, affinity=("x",), deadline=0, allow_shared=False),
            keys=[("x",)],
        )
        assert not t.shared
        assert t.group is not owner.group

    def test_size_mismatch_forces_private(self):
        sched, owner = self._sched_with_content()
        t = sched.submit(PlacementRequest(workers=2, affinity=("x",), deadline=0), keys=[("x",)])
        assert not t.shared

    def test_refcounted_release(self):
        sched, owner = self._sched_with_content()
        reader = sched.submit(PlacementRequest(affinity=("x",), deadline=0), keys=[("x",)])
        sched.bind(reader, session_id=2)
        assert owner.group.refcount == 2
        sched.release_session(2, reader.devices)
        assert owner.group.refcount == 1
        assert len(sched.free_devices) == 4  # owner still holds the block
        sched.release_session(1, owner.devices)
        assert ids(sched.free_devices) == list(range(8))


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------


class TestStats:
    def test_stats_shape_and_serializable(self):
        sched = make_sched(8)
        sched.submit(PlacementRequest(workers=2, deadline=0))
        snap = sched.stats()
        json.dumps(snap)
        for key in (
            "queue_depth",
            "free_workers",
            "placed",
            "timed_out",
            "cancelled",
            "aged",
            "groups",
            "shared_groups",
            "shared_joins",
            "affinity_hits",
            "smallest_fit_hits",
            "pressure_blocked",
            "aging_bound",
            "watermarks",
        ):
            assert key in snap
        assert snap["placed"] == 1 and snap["free_workers"] == 6

    def test_aging_bound_validation(self):
        with pytest.raises(ValueError):
            make_sched(4, aging_bound=0)


# ---------------------------------------------------------------------------
# tier2: end-to-end guarantees through a real engine
# ---------------------------------------------------------------------------


@pytest.mark.tier2
class TestAdmissionFairnessE2E:
    def test_storm_respects_aging_bound(self):
        """Under a storm of small connects, a large ticket is passed at most
        aging_bound times and still places."""
        bound = 2
        engine = repro.AlchemistEngine(aging_bound=bound)
        total = engine.num_workers
        holders = [engine.connect(name=f"h{i}", num_workers=1) for i in range(total)]
        results, errors = {}, {}

        def run_large():
            try:
                s = repro.connect(
                    engine,
                    name="large",
                    placement=repro.PlacementRequest(workers=total, deadline=60),
                )
                results["L"] = s.placement
                s.close()
            except Exception as e:  # pragma: no cover - failure diagnostics
                errors["L"] = e

        def run_small(i):
            try:
                s = repro.connect(
                    engine,
                    name=f"s{i}",
                    placement=repro.PlacementRequest(workers=1, deadline=60),
                )
                results[f"s{i}"] = s.placement
                time.sleep(0.02)
                s.close()
            except Exception as e:  # pragma: no cover - failure diagnostics
                errors[f"s{i}"] = e

        large = threading.Thread(target=run_large)
        large.start()
        time.sleep(0.05)
        smalls = [threading.Thread(target=run_small, args=(i,)) for i in range(bound + 2)]
        for th in smalls:
            th.start()
        time.sleep(0.05)
        for h in holders:
            engine.release(h)
            time.sleep(0.03)
        large.join(timeout=60)
        for th in smalls:
            th.join(timeout=60)
        assert not errors, errors
        ticket = results["L"]
        assert ticket.state == "placed"
        assert ticket.passed_by <= bound
        assert engine.stats()["scheduler"]["placed"] >= total + 1

    def test_shared_group_reads_are_bit_identical(self):
        """A content-affine reader joins the writer's worker group and sees
        bit-identical data with zero engine-side attach bytes."""
        engine = repro.AlchemistEngine()
        rng = np.random.default_rng(12)
        x = rng.standard_normal((96, 64)).astype(np.float32)
        with repro.connect(engine, name="writer") as s1:
            h1 = s1.send(x)
            ref = h1.data()
            with repro.connect(
                engine,
                name="reader",
                placement=repro.PlacementRequest(affinity=(x,), deadline=10),
            ) as s2:
                assert s2.placement.shared
                assert s2.placement.summary()["devices"] == s1.placement.summary()["devices"]
                h2 = s2.send(x)
                got = h2.data()
                np.testing.assert_array_equal(ref, got)
                assert got.dtype == ref.dtype
                stats = s2.session.stats.summary()
                assert stats["placement_bytes"] == 0
                assert stats["shared_views"] == 1
                assert stats["send_bytes"] == 0
            sched = engine.stats()["scheduler"]
            assert sched["shared_joins"] == 1
