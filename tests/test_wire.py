"""The wire (DESIGN.md §11): ALWF frame round trips over real socket pairs,
loopback/TCP parity for every verb, bridge-byte accounting equivalence, and
the failure modes a socket adds — mid-collect disconnect returning the
worker group to the pool, and reconnect-with-token inside a linger window."""

import socket
import threading
import time

import numpy as np
import pytest

import repro
from _hypothesis_compat import given, settings, st
from repro.core import transport as wire
from repro.core.errors import (
    LibraryError,
    ParameterError,
    SessionError,
    ShapeError,
    TaskError,
)
from repro.core.transport import LoopbackTransport, resolve_transport
from repro.serve.wire import EngineServer, TcpTransport, ensure_server

ELEMENTAL = "repro.linalg.library:ElementalLib"


@pytest.fixture()
def engine():
    return repro.AlchemistEngine()


def _session(engine, **kw):
    s = repro.connect(engine, **kw)
    s.register_library("elemental", ELEMENTAL)
    return s


# ---------------------------------------------------------------------------
# frames over a real socket pair
# ---------------------------------------------------------------------------


class TestFramesOverSocketpair:
    def test_control_frame_roundtrip(self):
        a, b = socket.socketpair()
        try:
            payload = {"name": "svd", "k": 8, "tol": 1e-6, "block": True, "note": None}
            sent = wire.send_frame(a, wire.T_RUN, payload)
            ftype, got, nread = wire.recv_frame(b)
            assert (ftype, got) == (wire.T_RUN, payload)
            assert sent == nread
        finally:
            a.close()
            b.close()

    def test_array_roundtrip_multi_chunk(self):
        a, b = socket.socketpair()
        try:
            arr = np.arange(300_000, dtype=np.float64).reshape(600, 500)
            assert arr.nbytes > wire.CHUNK_BYTES  # really exercises chunking
            done = {}

            def reader():
                done["arr"], done["n"] = wire.recv_array(b)

            t = threading.Thread(target=reader)
            t.start()
            sent = wire.send_array(a, arr)
            t.join(30)
            np.testing.assert_array_equal(done["arr"], arr)
            assert sent == done["n"]
        finally:
            a.close()
            b.close()

    def test_array_pads_stripped_on_receive(self):
        a, b = socket.socketpair()
        try:
            padded = np.arange(20.0).reshape(4, 5)
            t = threading.Thread(target=lambda: wire.send_array(a, padded, pads=(1, 2)))
            t.start()
            got, _ = wire.recv_array(b)
            t.join(30)
            np.testing.assert_array_equal(got, padded[:3, :3])
        finally:
            a.close()
            b.close()

    def test_peer_death_mid_frame_is_connection_error(self):
        a, b = socket.socketpair()
        frame = wire.pack_frame(wire.T_SEND, {"name": "x"})
        a.sendall(frame[: len(frame) - 3])  # truncated mid-payload
        a.close()
        with pytest.raises(ConnectionError):
            wire.recv_frame(b)
        b.close()

    def test_bad_magic_rejected(self):
        a, b = socket.socketpair()
        try:
            a.sendall(b"NOPE" + bytes(9))
            with pytest.raises(ParameterError, match="magic"):
                wire.recv_frame(b)
        finally:
            a.close()
            b.close()

    def test_hostile_frame_length_capped(self):
        a, b = socket.socketpair()
        try:
            import struct

            a.sendall(struct.pack("<4sBQ", b"ALWF", wire.T_RUN, 1 << 40))
            with pytest.raises(ParameterError, match="cap"):
                wire.recv_frame(b)
        finally:
            a.close()
            b.close()

    def test_array_chunk_overflow_rejected(self):
        a, b = socket.socketpair()
        try:
            import struct

            arr = np.ones((2, 2))
            header = wire.pack_frame(wire.T_ARRAY, wire.array_header(arr))
            a.sendall(header)
            a.sendall(struct.pack("<Q", 64) + bytes(64))  # 64 > declared 32
            ftype, meta, _ = wire.recv_frame(b)
            with pytest.raises(ParameterError, match="overflow"):
                wire.recv_array_body(b, meta)
        finally:
            a.close()
            b.close()

    @given(
        st.dictionaries(
            st.text(min_size=1, max_size=16),
            st.integers(-(2**40), 2**40)
            | st.floats(allow_nan=False, allow_infinity=False)
            | st.text(max_size=32)
            | st.booleans()
            | st.none(),
            max_size=8,
        )
    )
    @settings(max_examples=50, deadline=None)
    def test_frame_roundtrip_property(self, payload):
        a, b = socket.socketpair()
        try:
            wire.send_frame(a, wire.T_OK, payload)
            ftype, got, _ = wire.recv_frame(b)
            assert (ftype, got) == (wire.T_OK, payload)
        finally:
            a.close()
            b.close()


# ---------------------------------------------------------------------------
# the TCP transport: every verb, loopback parity
# ---------------------------------------------------------------------------


class TestTcpParity:
    def test_verbs_roundtrip(self, engine):
        rng = np.random.default_rng(0)
        a = rng.standard_normal((32, 24)).astype(np.float32)
        b = rng.standard_normal((24, 16)).astype(np.float32)
        s = _session(engine, transport="tcp")
        la, lb = s.send(a), s.send(b)
        out = s.collect(s.run("elemental", "gemm", la, lb))
        np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-4, atol=1e-4)
        s.free(la)
        s.wait(30)
        s.close()
        assert engine.stats()["engine"]["available_workers"] == 1

    def test_fail_fast_errors_stay_at_call_site(self, engine):
        s = _session(engine, transport="tcp")
        with pytest.raises(LibraryError):
            s.run_async("nope", "gemm", np.ones((2, 2)), np.ones((2, 2)))
        with pytest.raises(ShapeError):
            s.run("elemental", "gemm", np.ones((2, 3)), np.ones((5, 2)))
        s.close()

    def test_unserializable_run_arg_fails_the_future_not_the_call(self, engine):
        from repro.core.client import AlchemistContext

        with pytest.warns(DeprecationWarning):
            ac = AlchemistContext(engine, transport="tcp")
        ac.register_library("elemental", ELEMENTAL)
        h = ac.send(np.ones((4, 4)))
        fut = ac.run_async("elemental", "gemm", h, object())  # must not raise
        with pytest.raises(ParameterError):
            fut.result(30)
        ac.stop()

    def test_engine_errors_cross_the_wire_typed(self, engine):
        s = _session(engine, transport="tcp")
        with pytest.raises(SessionError):
            s.transport._rpc(wire.T_FETCH, {"__ticket": 10**6})
        s.close()

    def test_bridge_byte_counters_match_loopback(self):
        """The acceptance parity check: session-level bridge accounting is
        engine-side in both transports, so an identical workload reports
        identical send/recv byte totals whether or not a socket is in the
        path. Fresh engine per run — on a shared one the second run's sends
        would dedup into attaches via the content store (zero bridge bytes),
        which is the resident-store feature, not a parity property."""
        rng = np.random.default_rng(1)
        a = rng.standard_normal((48, 32)).astype(np.float32)
        b = rng.standard_normal((32, 24)).astype(np.float32)

        def workload(transport):
            s = _session(repro.AlchemistEngine(), transport=transport)
            out = s.collect(s.run("elemental", "gemm", s.send(a), s.send(b)))
            summary = s.stats.summary()
            s.close()
            return np.asarray(out), summary

        out_loop, stats_loop = workload("loopback")
        out_tcp, stats_tcp = workload("tcp")
        np.testing.assert_allclose(out_tcp, out_loop, rtol=1e-6, atol=1e-6)
        for key in ("send_bytes", "recv_bytes", "num_sends", "num_receives"):
            assert stats_tcp[key] == stats_loop[key], key

    def test_wire_stats_count_real_traffic(self, engine):
        s = _session(engine, transport="tcp")
        s.collect(s.send(np.ones((16, 16), dtype=np.float32)))
        ws = s.transport.wire_stats()
        # at least the 16x16 f32 payload, twice (send + collect), plus frames
        assert ws["bytes_sent"] > 1024
        assert ws["bytes_received"] > 1024
        assert ws["frames"] >= 4
        s.close()


# ---------------------------------------------------------------------------
# disconnect semantics
# ---------------------------------------------------------------------------


class TestDisconnect:
    def _wait_for_free(self, engine, n, timeout=10.0):
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if engine.stats()["engine"]["available_workers"] == n:
                return
            time.sleep(0.02)
        raise AssertionError(
            f"pool never returned to {n} free workers: {engine.stats()['engine']}"
        )

    def test_killed_socket_returns_worker_group_to_pool(self, engine):
        srv = ensure_server(engine)
        before = srv.stats["disconnect_releases"]
        s = _session(engine, transport="tcp")
        assert engine.stats()["engine"]["available_workers"] == 0
        s.transport._sock.close()  # client process dies mid-session
        self._wait_for_free(engine, 1)
        assert engine.stats()["engine"]["live_sessions"] == 0
        assert srv.stats["disconnect_releases"] == before + 1

    def test_mid_collect_disconnect_releases_and_queued_connect_proceeds(self, engine):
        s = _session(engine, transport="tcp")
        la = s.send(np.ones((64, 64), dtype=np.float32))
        fut = s.collect_async(la.materialize())
        fut.result(30)  # engine-side value ready; payload not yet fetched
        # A second connect queues behind the only worker...
        got = {}

        def queued_connect():
            s2 = repro.connect(engine, placement=repro.PlacementRequest(deadline=30))
            got["n"] = s2.session.num_workers
            s2.close()

        t = threading.Thread(target=queued_connect)
        t.start()
        time.sleep(0.2)
        # ...then the first client dies mid-collect: its group must free and
        # the queued admission must complete.
        s.transport._sock.close()
        t.join(30)
        assert got.get("n") == 1
        self._wait_for_free(engine, 1)

    def test_explicit_close_is_not_a_disconnect(self, engine):
        srv = ensure_server(engine)
        before = srv.stats["disconnect_releases"]
        s = _session(engine, transport="tcp")
        s.close()
        assert engine.stats()["engine"]["available_workers"] == 1
        assert srv.stats["disconnect_releases"] == before

    def test_reconnect_with_token_inside_linger_window(self, engine):
        srv = EngineServer(engine, linger=10.0)
        transport = TcpTransport(srv)
        s = repro.connect(engine, transport=transport)
        s.register_library("elemental", ELEMENTAL)
        a = np.arange(12.0, dtype=np.float32).reshape(3, 4)
        la = s.send(a)
        transport._sock.close()  # drop; session lingers server-side
        time.sleep(0.1)
        assert srv.has_session(transport.token)
        # next RPC transparently re-dials with the session token
        out = s.collect(s.run("elemental", "gemm", la, s.send(a.T.copy())))
        np.testing.assert_allclose(np.asarray(out), a @ a.T, rtol=1e-5, atol=1e-5)
        assert srv.stats["reconnects"] == 1
        s.close()
        assert engine.stats()["engine"]["available_workers"] == 1
        srv.close()

    def test_linger_expiry_releases_session(self, engine):
        srv = EngineServer(engine, linger=0.2)
        transport = TcpTransport(srv)
        s = repro.connect(engine, transport=transport)
        transport._sock.close()
        self._wait_for_free(engine, 1)
        assert srv.stats["disconnect_releases"] == 1
        srv.close()

    def test_reconnect_after_linger_expiry_gets_typed_error(self, engine):
        """A client whose token expired must get the typed SessionError —
        never a hang — and the worker group must already be back in the
        pool when the error surfaces."""
        srv = EngineServer(engine, linger=0.2)
        transport = TcpTransport(srv)
        s = repro.connect(engine, transport=transport)
        s.register_library("elemental", ELEMENTAL)
        a = np.arange(12.0, dtype=np.float32).reshape(3, 4)
        la = s.send(a)
        transport._sock.close()  # drop; linger window starts
        self._wait_for_free(engine, 1)  # window expired: group back in pool
        assert not srv.has_session(transport.token)
        # The next RPC re-dials, finds the token unbound, and must surface
        # the typed error at the call site.
        with pytest.raises(SessionError, match="no longer bound"):
            s.collect(s.run("elemental", "gemm", la, s.send(a.T.copy())))
        assert engine.stats()["engine"]["available_workers"] == 1
        srv.close()


# ---------------------------------------------------------------------------
# EngineServer.stop(): idempotent, re-entrant, unblocks live connections
# ---------------------------------------------------------------------------


class TestServerStop:
    def test_double_stop_is_a_noop(self, engine):
        srv = EngineServer(engine)
        s = _session(engine, transport=TcpTransport(srv))
        srv.stop()
        srv.stop()  # second stop: no error, no double release
        srv.close()  # historical alias routes through the same guard
        assert engine.stats()["engine"]["available_workers"] == 1
        assert s  # keep the session referenced until after the stops

    def test_concurrent_stop_from_many_threads(self, engine):
        srv = EngineServer(engine)
        _session(engine, transport=TcpTransport(srv))
        errs = []

        def stop():
            try:
                srv.stop()
            except Exception as exc:  # noqa: BLE001 — the test is the catch
                errs.append(exc)

        threads = [threading.Thread(target=stop) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(10)
        assert errs == []
        assert engine.stats()["engine"]["available_workers"] == 1

    def test_stop_unblocks_connection_mid_fetch(self, engine):
        """A supervisor-thread stop() while a per-connection worker is
        mid-FETCH must not deadlock or leak: the blocked client RPC fails
        with a connection-level error promptly and the group frees."""
        srv = EngineServer(engine)
        transport = TcpTransport(srv)
        s = _session(engine, transport=transport)
        la = s.send(np.ones((256, 256), dtype=np.float32))
        fut = s.collect_async(la.materialize())
        fut.result(30)  # value ready engine-side; FETCH traffic still flows
        done = threading.Event()
        outcome = {}

        def fetch_forever():
            try:
                for _ in range(50):
                    s.collect(la)
                outcome["ok"] = True
            except (SessionError, ConnectionError, OSError) as exc:
                outcome["err"] = exc
            finally:
                done.set()

        t = threading.Thread(target=fetch_forever, daemon=True)
        t.start()
        time.sleep(0.05)  # let some FETCHes get in flight
        srv.stop()
        assert done.wait(15), "client thread hung after server stop"
        # either the loop finished before the stop landed, or it got a
        # typed/connection error — never a hang
        assert outcome.get("ok") or "err" in outcome
        deadline = time.monotonic() + 10
        while engine.available_workers != 1 and time.monotonic() < deadline:
            time.sleep(0.02)
        assert engine.available_workers == 1


# ---------------------------------------------------------------------------
# the v2 streaming data plane (DESIGN.md §13)
# ---------------------------------------------------------------------------


class TestV2Protocol:
    def test_version_mismatch_gets_typed_error(self, engine):
        """A v1 client (or any mismatched version) must get a typed
        SessionError naming both versions — never garbage frames."""
        srv = ensure_server(engine)
        for ftype in (wire.T_HELLO, wire.T_CONNECT):
            sock = socket.create_connection(srv.address)
            try:
                wire.send_frame(
                    sock, ftype, {"__version": 1, "__rid": 7, "__token": None}
                )
                rtype, reply, _ = wire.recv_frame(sock)
                assert rtype == wire.T_ERR
                assert reply.get("__rid") == 7  # correlated even for errors
                exc = wire.exception_from_payload(reply)
                assert isinstance(exc, SessionError)
                msg = str(exc)
                assert "version mismatch" in msg
                assert "v1" in msg and f"v{wire.WIRE_VERSION}" in msg
            finally:
                sock.close()
        assert srv.stats["version_rejects"] == 2

    def test_shard_direct_send_roundtrips_bit_identical(self, engine):
        """A multi-chunk send decodes straight into shard slabs (no
        reassembly buffer) and still round-trips bit-exactly."""
        srv = ensure_server(engine)
        direct_before = srv.stats["shard_direct_receives"]
        s = _session(engine, transport="tcp")
        rng = np.random.default_rng(3)
        a = rng.standard_normal((1024, 300)).astype(np.float32)
        assert a.nbytes > wire.CHUNK_BYTES  # really streams multiple chunks
        out = s.collect(s.send(a).materialize())
        np.testing.assert_array_equal(np.asarray(out), a)
        assert srv.stats["shard_direct_receives"] >= direct_before + 1
        assert srv.stats["reassembly_receives"] == 0
        assert srv.stats["streamed_fetches"] + srv.stats["gathered_fetches"] >= 1
        s.close()

    def test_mid_stream_death_leaves_no_leaks(self, engine):
        """Peer death between shard chunks: no partially-admitted handle, no
        stuck governor claims, the worker group returns to the pool."""
        from repro.core.layouts import by_name
        from repro.core.relayout import shard_geometry

        srv = ensure_server(engine)
        s = _session(engine, transport="tcp")
        sess = s.session
        arr = np.ones((1024, 300), dtype=np.float32)
        geom = shard_geometry(arr.shape, arr.dtype, by_name("row"), sess.mesh)
        assert geom is not None
        header, chunks, _framed = wire.encode_array(arr, geom=geom)
        assert len(chunks) >= 2
        sock = s.transport._sock
        wire.send_frame(
            sock,
            wire.T_SEND,
            {"__name": "dead", "__block": False, "__has_payload": False, "__rid": 99},
        )
        sock.sendall(header)
        c = chunks[0]  # first chunk only, then the client process "dies"
        sock.sendall(len(c).to_bytes(8, "little"))
        sock.sendall(bytes(c))
        sock.close()
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            if engine.stats()["engine"]["available_workers"] == 1:
                break
            time.sleep(0.02)
        snap = engine.stats()
        assert snap["engine"]["available_workers"] == 1, snap["engine"]
        assert snap["engine"]["live_sessions"] == 0
        assert snap["memgov"]["reserved"] == 0  # no stuck claims
        # the aborted stream never counted as a completed receive
        assert srv.stats["shard_direct_receives"] == 0
        # and the engine is healthy: a fresh session sends fine
        s2 = _session(engine, transport="tcp")
        out = s2.collect(s2.send(arr).materialize())
        np.testing.assert_array_equal(np.asarray(out), arr)
        s2.close()

    def test_multi_inflight_fetch_does_not_block_barrier(self, engine):
        """The ticket-correlated protocol: a blocked FETCH must not hold the
        connection — a concurrent BARRIER completes on the same socket, and
        the server observes a pipeline depth ≥ 2."""
        from repro.core.futures import AlFuture

        srv = ensure_server(engine)
        s = _session(engine, transport="tcp")
        gate = AlFuture(label="gate")
        ticket = srv.register_future(s.transport.token, gate)
        got = {}

        def fetch():
            got["arr"] = s.transport._rpc(
                wire.T_FETCH, {"__ticket": ticket, "__timeout": 30}, expect_array=True
            )

        t = threading.Thread(target=fetch)
        t.start()
        deadline = time.monotonic() + 5
        while srv.inflight_depth() < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert srv.inflight_depth() >= 1
        s.transport._rpc(wire.T_BARRIER, {"__timeout": 10})  # completes now
        assert t.is_alive()  # the FETCH is still parked server-side
        gate._set_result(np.eye(3, dtype=np.float32))
        t.join(10)
        assert not t.is_alive()
        np.testing.assert_array_equal(got["arr"], np.eye(3, dtype=np.float32))
        assert srv.stats["max_inflight"] >= 2
        ws = s.transport.wire_stats()
        assert ws["max_inflight"] >= 2
        s.close()

    def test_decode_array_zero_copy_multi_chunk(self):
        """Satellite regression: decoding a multi-chunk body from a
        bytearray/memoryview must view the buffer, not copy it."""
        rng = np.random.default_rng(5)
        arr = rng.standard_normal((600, 500)).astype(np.float32)
        header, chunks, _ = wire.encode_array(arr)
        assert len(chunks) >= 2
        body = bytearray()
        for c in chunks:
            body += c
        _ftype, meta = wire.unpack_frame(header)
        out = wire.decode_array(meta, body)
        np.testing.assert_array_equal(out, arr)
        src = np.frombuffer(body, dtype=np.uint8)
        assert np.shares_memory(out, src)  # no extra contiguous copy
        out2 = wire.decode_array(meta, memoryview(body))
        assert np.shares_memory(out2, src)


# ---------------------------------------------------------------------------
# transport selection
# ---------------------------------------------------------------------------


class TestResolution:
    def test_default_is_loopback(self, monkeypatch):
        monkeypatch.delenv("REPRO_TRANSPORT", raising=False)
        assert isinstance(resolve_transport(None), LoopbackTransport)

    def test_env_selects_tcp(self, monkeypatch):
        monkeypatch.setenv("REPRO_TRANSPORT", "tcp")
        assert isinstance(resolve_transport(None), TcpTransport)

    def test_unknown_name_rejected(self):
        with pytest.raises(SessionError, match="unknown transport"):
            resolve_transport("carrier-pigeon")

    def test_instance_passes_through(self):
        t = LoopbackTransport()
        assert resolve_transport(t) is t

    def test_loopback_frames_payload_bytes(self, engine):
        s = _session(engine, transport="loopback")
        a = np.ones((32, 32), dtype=np.float32)
        s.collect(s.send(a))
        ws = s.transport.wire_stats()
        assert ws["bytes_sent"] >= 2 * a.nbytes  # send + collect both framed
        assert ws["frames"] >= 2
        s.close()
