"""Differential tests: for gemm, truncated_svd, and pca, the planned/offloaded
path, the eager engine path, and the pure sparklike reference must agree on
the same inputs.

This is the numerical half of the ISSUE-2 acceptance criteria: the lazy
offload planner (DESIGN.md §6) may elide bridge crossings and dedup sends,
but it must never change results relative to eager execution — and both
engine paths must match the driver-side sparklike baselines within the
float32 tolerance of the engine's compute.

Sign/rotation indeterminacies of SVD factors are compared via singular
values and subspace overlap, the convention used across the repo.
"""

import numpy as np
import pytest

import repro
from repro.sparklike import IndexedRowMatrix, SparkLikeContext, mllib, offload

M, N, K = 96, 24, 4


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(42)
    low = rng.standard_normal((M, 6)) @ rng.standard_normal((6, N))
    return (low + 0.05 * rng.standard_normal((M, N))).astype(np.float64)


@pytest.fixture(scope="module")
def second_operand():
    rng = np.random.default_rng(43)
    return rng.standard_normal((N, 8)).astype(np.float64)


@pytest.fixture()
def ac():
    ctx = repro.AlchemistContext(repro.AlchemistEngine(), num_workers=1, name="diff")
    ctx.register_library("elemental", "repro.linalg.library:ElementalLib")
    yield ctx
    ctx.stop()


def _subspace_overlap(u: np.ndarray, v: np.ndarray) -> np.ndarray:
    """Principal-angle cosines between the column spaces (1.0 = identical)."""
    qu, _ = np.linalg.qr(u)
    qv, _ = np.linalg.qr(v)
    return np.linalg.svd(qu.T @ qv, compute_uv=False)


class TestGemmDifferential:
    def test_three_paths_agree(self, ac, dataset, second_operand):
        a, b = dataset, second_operand

        # pure sparklike: the §4.1 block-matrix shuffle recipe
        ctx = SparkLikeContext(num_partitions=4)
        ref = mllib.multiply(
            IndexedRowMatrix.from_numpy(ctx, a),
            IndexedRowMatrix.from_numpy(ctx, b),
            block_size=16,
        ).to_numpy()
        np.testing.assert_allclose(ref, a @ b, atol=1e-10)  # baseline sanity

        # eager engine: send → run → collect
        ha, hb = ac.send(a), ac.send(b)
        eager = np.asarray(ac.collect(ac.run("elemental", "gemm", ha, hb)))

        # planned: deferred DAG through the planner
        pl = ac.planner
        planned = np.asarray(pl.collect(pl.run("elemental", "gemm", pl.send(a), pl.send(b))))

        np.testing.assert_allclose(eager, ref, rtol=2e-4, atol=5e-4)
        np.testing.assert_allclose(planned, eager, atol=1e-6)  # identical engine math

    def test_offloaded_multiply_matches_reference(self, ac, dataset, second_operand):
        a, b = dataset, second_operand
        ctx = SparkLikeContext(num_partitions=4)
        ir_a = IndexedRowMatrix.from_numpy(ctx, a)
        ir_b = IndexedRowMatrix.from_numpy(ctx, b)
        ref = mllib.multiply(ir_a, ir_b, block_size=16).to_numpy()
        with offload.offloaded(ac):
            out = mllib.multiply(ir_a, ir_b).to_numpy()
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=5e-4)


class TestSvdDifferential:
    def test_three_paths_agree(self, ac, dataset):
        a = dataset

        # pure sparklike: driver-side Lanczos, one cluster matvec per iter
        ctx = SparkLikeContext(num_partitions=4)
        ir = IndexedRowMatrix.from_numpy(ctx, a)
        u_ref, s_ref, v_ref = mllib.compute_svd(ir, K)

        # eager engine
        h = ac.send(a)
        _, s_eager, hv = ac.run("elemental", "truncated_svd", h, k=K)
        s_eager = np.asarray(s_eager)
        v_eager = np.asarray(ac.collect(hv))

        # planned (the sparklike drop-in)
        with offload.offloaded(ac):
            u_off, s_off, v_off = mllib.compute_svd(ir, K)

        np.testing.assert_allclose(s_eager, s_ref, rtol=2e-2)
        np.testing.assert_allclose(s_off, s_ref, rtol=2e-2)
        np.testing.assert_allclose(
            _subspace_overlap(v_eager, v_ref), np.ones(K), atol=5e-2
        )
        np.testing.assert_allclose(
            _subspace_overlap(v_off, v_ref), np.ones(K), atol=5e-2
        )
        # U subspaces too: the resident LazyRowMatrix matches the baseline U
        np.testing.assert_allclose(
            _subspace_overlap(u_off.to_numpy(), u_ref.to_numpy()), np.ones(K), atol=5e-2
        )

    def test_reconstruction_parity(self, ac, dataset):
        """U S Vᵀ from the planned path reconstructs as well as the
        reference's — the factors are interchangeable, not just similar."""
        a = dataset
        ctx = SparkLikeContext(num_partitions=4)
        ir = IndexedRowMatrix.from_numpy(ctx, a)
        u_ref, s_ref, v_ref = mllib.compute_svd(ir, K)
        err_ref = np.linalg.norm(a - u_ref.to_numpy() @ np.diag(s_ref) @ v_ref.T)
        with offload.offloaded(ac):
            u_off, s_off, v_off = mllib.compute_svd(ir, K)
        err_off = np.linalg.norm(a - u_off.to_numpy() @ np.diag(s_off) @ v_off.T)
        assert err_off <= 1.05 * err_ref + 1e-6


class TestPcaDifferential:
    def test_three_paths_agree(self, ac, dataset):
        a = dataset
        a_c = a - a.mean(0)

        # pure sparklike reference: computeSVD of the centered matrix
        ctx = SparkLikeContext(num_partitions=4)
        _, s_ref, v_ref = mllib.compute_svd(IndexedRowMatrix.from_numpy(ctx, a_c), K)
        var_ref = s_ref**2 / (M - 1)

        # eager engine pca (centers internally)
        h = ac.send(a)
        h_comps, h_scores, var_eager = ac.run("elemental", "pca", h, k=K)
        comps_eager = np.asarray(ac.collect(h_comps))
        var_eager = np.asarray(var_eager)

        # planned pca through the planner DAG
        pl = ac.planner
        comps_l, scores_l, var_l = pl.run("elemental", "pca", pl.send(a), n_outputs=3, k=K)
        comps_planned = np.asarray(pl.collect(comps_l))
        var_planned = np.asarray(pl.collect(var_l))

        np.testing.assert_allclose(var_eager, var_ref, rtol=2e-2)
        np.testing.assert_allclose(var_planned, var_eager, atol=1e-6)
        np.testing.assert_allclose(
            _subspace_overlap(comps_eager, v_ref), np.ones(K), atol=5e-2
        )
        np.testing.assert_allclose(comps_planned, comps_eager, atol=1e-6)

    def test_planned_scores_match_eager(self, ac, dataset):
        a = dataset
        h = ac.send(a)
        _, h_scores, _ = ac.run("elemental", "pca", h, k=K)
        scores_eager = np.asarray(ac.collect(h_scores))

        pl = ac.planner
        _, scores_l, _ = pl.run("elemental", "pca", pl.send(a), n_outputs=3, k=K)
        scores_planned = np.asarray(pl.collect(scores_l))
        np.testing.assert_allclose(scores_planned, scores_eager, atol=1e-6)
