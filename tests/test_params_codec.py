"""Parameters codec tests (the paper's driver-to-driver metadata frame),
including hypothesis round-trip properties."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import params as codec
from repro.core.errors import ParameterError
from repro.core.handles import AlMatrix
from repro.core.layouts import GRID


def test_roundtrip_scalars():
    src = {
        "k": 20,
        "tol": 1e-6,
        "verbose": True,
        "mode": "lanczos",
        "nothing": None,
        "dims": [3, 4, 5],
        "weights": [0.1, 0.9],
    }
    assert codec.unpack(codec.pack(src)) == src


def test_matrix_handle_roundtrip():
    h = AlMatrix(shape=(128, 64), dtype=np.float32, layout=GRID, session_id=7, name="A")
    out = codec.unpack(codec.pack({"a": h}))["a"]
    assert isinstance(out, codec.HandleRef)
    assert out.id == h.id
    assert out.session_id == 7
    assert out.shape == (128, 64)
    assert out.dtype == "float32"
    assert out.layout == "grid"


def test_bad_magic_rejected():
    with pytest.raises(ParameterError):
        codec.unpack(b"XXXX" + b"\x00" * 16)


def test_unpackable_type_rejected():
    with pytest.raises(ParameterError):
        codec.pack({"x": object()})


scalar = st.one_of(
    st.booleans(),
    st.integers(min_value=-(2**62), max_value=2**62),
    st.floats(allow_nan=False, allow_infinity=False, width=64),
    st.text(max_size=64),
    st.none(),
    st.lists(st.integers(min_value=-(2**31), max_value=2**31), max_size=8),
    st.lists(st.floats(allow_nan=False, allow_infinity=False, width=64), min_size=1, max_size=8),
)


@given(st.dictionaries(st.text(min_size=1, max_size=32), scalar, max_size=16))
@settings(max_examples=200, deadline=None)
def test_roundtrip_property(d):
    out = codec.unpack(codec.pack(d))
    assert out == d


# Frames mixing scalars with AlMatrix handles — the paper's "pointers to
# Elemental distributed matrices" travelling in the same Parameters frame.
from repro.core.layouts import COLUMN, REPLICATED, ROW  # noqa: E402

handle = st.builds(
    AlMatrix,
    shape=st.tuples(st.integers(1, 2**31), st.integers(1, 2**31)),
    dtype=st.sampled_from([np.float32, np.float64, np.float16, np.int32]),
    layout=st.sampled_from([ROW, GRID, COLUMN, REPLICATED, GRID.with_cyclic()]),
    session_id=st.integers(0, 2**31),
    name=st.text(max_size=16),
)


@given(st.dictionaries(st.text(min_size=1, max_size=32), scalar | handle, max_size=16))
@settings(max_examples=100, deadline=None)
def test_roundtrip_property_with_handles(d):
    out = codec.unpack(codec.pack(d))
    assert set(out) == set(d)
    for key, val in d.items():
        if isinstance(val, AlMatrix):
            ref = out[key]
            assert isinstance(ref, codec.HandleRef)
            assert ref.id == val.id
            assert ref.session_id == val.session_id
            assert ref.shape == tuple(val.shape)
            assert ref.dtype == np.dtype(val.dtype).name
            assert ref.layout == val.layout.name
        else:
            assert out[key] == val
