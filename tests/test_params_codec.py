"""Parameters codec tests (the paper's driver-to-driver metadata frame),
including hypothesis round-trip properties."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import params as codec
from repro.core.errors import ParameterError
from repro.core.handles import AlMatrix
from repro.core.layouts import GRID


def test_roundtrip_scalars():
    src = {
        "k": 20,
        "tol": 1e-6,
        "verbose": True,
        "mode": "lanczos",
        "nothing": None,
        "dims": [3, 4, 5],
        "weights": [0.1, 0.9],
    }
    assert codec.unpack(codec.pack(src)) == src


def test_matrix_handle_roundtrip():
    h = AlMatrix(shape=(128, 64), dtype=np.float32, layout=GRID, session_id=7, name="A")
    out = codec.unpack(codec.pack({"a": h}))["a"]
    assert isinstance(out, codec.HandleRef)
    assert out.id == h.id
    assert out.session_id == 7
    assert out.shape == (128, 64)
    assert out.dtype == "float32"
    assert out.layout == "grid"


def test_bad_magic_rejected():
    with pytest.raises(ParameterError):
        codec.unpack(b"XXXX" + b"\x00" * 16)


def test_unpackable_type_rejected():
    with pytest.raises(ParameterError):
        codec.pack({"x": object()})


scalar = st.one_of(
    st.booleans(),
    st.integers(min_value=-(2**62), max_value=2**62),
    st.floats(allow_nan=False, allow_infinity=False, width=64),
    st.text(max_size=64),
    st.none(),
    st.lists(st.integers(min_value=-(2**31), max_value=2**31), max_size=8),
    st.lists(st.floats(allow_nan=False, allow_infinity=False, width=64), min_size=1, max_size=8),
)


@given(st.dictionaries(st.text(min_size=1, max_size=32), scalar, max_size=16))
@settings(max_examples=200, deadline=None)
def test_roundtrip_property(d):
    out = codec.unpack(codec.pack(d))
    assert out == d
