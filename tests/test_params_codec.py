"""Parameters codec tests (the paper's driver-to-driver metadata frame),
including hypothesis round-trip properties."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import params as codec
from repro.core.errors import ParameterError
from repro.core.handles import AlMatrix
from repro.core.layouts import GRID


def test_roundtrip_scalars():
    src = {
        "k": 20,
        "tol": 1e-6,
        "verbose": True,
        "mode": "lanczos",
        "nothing": None,
        "dims": [3, 4, 5],
        "weights": [0.1, 0.9],
    }
    assert codec.unpack(codec.pack(src)) == src


def test_matrix_handle_roundtrip():
    h = AlMatrix(shape=(128, 64), dtype=np.float32, layout=GRID, session_id=7, name="A")
    out = codec.unpack(codec.pack({"a": h}))["a"]
    assert isinstance(out, codec.HandleRef)
    assert out.id == h.id
    assert out.session_id == 7
    assert out.shape == (128, 64)
    assert out.dtype == "float32"
    assert out.layout == "grid"


def test_bad_magic_rejected():
    with pytest.raises(ParameterError):
        codec.unpack(b"XXXX" + b"\x00" * 16)


def test_unpackable_type_rejected():
    with pytest.raises(ParameterError):
        codec.pack({"x": object()})


class TestListTags:
    def test_empty_list_gets_stable_tag(self):
        # [] must not pack as an int list (the element-typed guards are
        # vacuously true on it): a float-list parameter that happens to be
        # empty must not change type across the wire.
        frame = codec.pack({"xs": []})
        assert frame.count(bytes([codec._T_EMPTY_LIST])) >= 1
        assert codec.unpack(frame)["xs"] == []

    def test_empty_tuple_roundtrips_as_list(self):
        assert codec.unpack(codec.pack({"xs": ()}))["xs"] == []

    def test_mixed_numeric_list_error_is_descriptive(self):
        with pytest.raises(ParameterError, match="all-int or all-float"):
            codec.pack({"xs": [1, 2.5]})
        with pytest.raises(ParameterError, match="bools are not list elements"):
            codec.pack({"flags": [True, False]})

    def test_v2_frames_still_decode(self):
        # Readers accept older versions: a v2 frame (no _T_EMPTY_LIST) is a
        # byte-identical subset of v3 apart from the header version field.
        frame = bytearray(codec.pack({"k": 7, "s": "x"}))
        frame[4:6] = (2).to_bytes(2, "little")
        assert codec.unpack(bytes(frame)) == {"k": 7, "s": "x"}

    def test_newer_version_rejected(self):
        frame = bytearray(codec.pack({"k": 7}))
        frame[4:6] = (codec._VERSION + 1).to_bytes(2, "little")
        with pytest.raises(ParameterError, match="newer than supported"):
            codec.unpack(bytes(frame))


class TestHardenedUnpack:
    """Satellite: a garbage socket read must surface as ParameterError —
    never a raw struct.error/UnicodeDecodeError escaping the codec."""

    FRAME = None  # built once below

    @classmethod
    def frame(cls):
        if cls.FRAME is None:
            h = AlMatrix(shape=(8, 4), dtype=np.float32, layout=GRID, session_id=1)
            cls.FRAME = codec.pack(
                {"k": 20, "tol": 1e-6, "mode": "lanczos", "dims": [3, 4], "h": h}
            )
        return cls.FRAME

    def test_every_truncation_offset_raises_parameter_error(self):
        buf = self.frame()
        for k in range(len(buf)):
            with pytest.raises(ParameterError):
                codec.unpack(buf[:k])

    def test_trailing_garbage_rejected(self):
        with pytest.raises(ParameterError, match="trailing"):
            codec.unpack(self.frame() + b"\x00")

    def test_non_utf8_key_wrapped(self):
        # key "k" sits right after the 10-byte header + 4-byte length.
        buf = bytearray(codec.pack({"k": 1}))
        buf[14] = 0xFF
        with pytest.raises(ParameterError, match="utf-8"):
            codec.unpack(bytes(buf))

    def test_huge_declared_string_length_rejected(self):
        # A corrupt length prefix must bounds-check, not allocate or crash.
        buf = bytearray(codec.pack({"k": 1}))
        buf[10:14] = (2**31).to_bytes(4, "little")
        with pytest.raises(ParameterError, match="truncated"):
            codec.unpack(bytes(buf))

    def test_huge_declared_list_length_rejected(self):
        frame = codec.pack({"xs": [1, 2, 3]})
        buf = bytearray(frame)
        off = frame.index(bytes([codec._T_INT_LIST])) + 1
        buf[off : off + 4] = (2**30).to_bytes(4, "little")
        with pytest.raises(ParameterError, match="truncated"):
            codec.unpack(bytes(buf))


def test_handleref_repacks_identically():
    # The engine side of the wire re-encodes decoded frames without
    # resolving matrix refs first: HandleRef packs like its AlMatrix.
    h = AlMatrix(shape=(16, 8), dtype=np.float64, layout=GRID, session_id=3)
    frame = codec.pack({"a": h, "k": 2})
    ref = codec.unpack(frame)["a"]
    assert codec.pack({"a": ref, "k": 2}) == frame


scalar = st.one_of(
    st.booleans(),
    st.integers(min_value=-(2**62), max_value=2**62),
    st.floats(allow_nan=False, allow_infinity=False, width=64),
    st.text(max_size=64),
    st.none(),
    st.lists(st.integers(min_value=-(2**31), max_value=2**31), max_size=8),
    st.lists(st.floats(allow_nan=False, allow_infinity=False, width=64), min_size=1, max_size=8),
)


@given(st.dictionaries(st.text(min_size=1, max_size=32), scalar, max_size=16))
@settings(max_examples=200, deadline=None)
def test_roundtrip_property(d):
    out = codec.unpack(codec.pack(d))
    assert out == d


# Frames mixing scalars with AlMatrix handles — the paper's "pointers to
# Elemental distributed matrices" travelling in the same Parameters frame.
from repro.core.layouts import COLUMN, REPLICATED, ROW  # noqa: E402

handle = st.builds(
    AlMatrix,
    shape=st.tuples(st.integers(1, 2**31), st.integers(1, 2**31)),
    dtype=st.sampled_from([np.float32, np.float64, np.float16, np.int32]),
    layout=st.sampled_from([ROW, GRID, COLUMN, REPLICATED, GRID.with_cyclic()]),
    session_id=st.integers(0, 2**31),
    name=st.text(max_size=16),
)


@given(st.dictionaries(st.text(min_size=1, max_size=32), scalar, max_size=8), st.data())
@settings(max_examples=200, deadline=None)
def test_truncation_property(d, data):
    """Every proper prefix of a frame is rejected as ParameterError —
    the exception a wire server declares — never struct/unicode errors."""
    buf = codec.pack(d)
    k = data.draw(st.integers(min_value=0, max_value=len(buf) - 1))
    with pytest.raises(ParameterError):
        codec.unpack(buf[:k])


@given(st.dictionaries(st.text(min_size=1, max_size=16), scalar, min_size=1, max_size=8), st.data())
@settings(max_examples=200, deadline=None)
def test_corruption_property(d, data):
    """Flipping any byte either still decodes (a value changed) or raises
    ParameterError — hostile bytes can never escape the codec's error type."""
    buf = bytearray(codec.pack(d))
    i = data.draw(st.integers(min_value=0, max_value=len(buf) - 1))
    buf[i] ^= data.draw(st.integers(min_value=1, max_value=255))
    try:
        codec.unpack(bytes(buf))
    except ParameterError:
        pass


@given(st.dictionaries(st.text(min_size=1, max_size=32), scalar | handle, max_size=16))
@settings(max_examples=100, deadline=None)
def test_roundtrip_property_with_handles(d):
    out = codec.unpack(codec.pack(d))
    assert set(out) == set(d)
    for key, val in d.items():
        if isinstance(val, AlMatrix):
            ref = out[key]
            assert isinstance(ref, codec.HandleRef)
            assert ref.id == val.id
            assert ref.session_id == val.session_id
            assert ref.shape == tuple(val.shape)
            assert ref.dtype == np.dtype(val.dtype).name
            assert ref.layout == val.layout.name
        else:
            assert out[key] == val
