"""The Dask-flavored frontend: lazy collections over the v2 session, with
compute/persist semantics and transport-agnostic execution (one test pins
the TCP wire explicitly; the rest follow REPRO_TRANSPORT like all tier-1)."""

import numpy as np
import pytest

import repro
from repro import dasklike


@pytest.fixture()
def engine():
    return repro.AlchemistEngine()


@pytest.fixture()
def data():
    rng = np.random.default_rng(11)
    a = rng.standard_normal((40, 24)).astype(np.float32)
    b = rng.standard_normal((24, 16)).astype(np.float32)
    return a, b


def test_from_array_is_lazy_and_compute_matches(engine, data):
    a, b = data
    s = repro.connect(engine)
    da = dasklike.from_array(s, a)
    assert da.shape == a.shape
    assert da.ndim == 2
    c = da @ dasklike.from_array(s, b)
    out = c.compute()
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)
    s.close()


def test_from_engine_opens_session_and_registers_elemental(engine, data):
    a, b = data
    da = dasklike.from_array(engine, a)
    db = dasklike.from_array(da._session, b)  # same session, no new allocation
    assert engine.stats()["engine"]["live_sessions"] == 1
    np.testing.assert_allclose(
        dasklike.compute(da @ db), a @ b, rtol=1e-4, atol=1e-4
    )
    da._session.close()


def test_compute_variadic_returns_tuple(engine, data):
    a, b = data
    s = repro.connect(engine)
    da, db = dasklike.from_array(s, a), dasklike.from_array(s, b)
    ra, rb = dasklike.compute(da, db)
    np.testing.assert_allclose(ra, a, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(rb, b, rtol=1e-6, atol=1e-6)
    s.close()


def test_persist_keeps_value_engine_resident(engine, data):
    a, b = data
    s = repro.connect(engine)
    c = dasklike.from_array(s, a) @ dasklike.from_array(s, b)
    assert c.state == "deferred"
    dasklike.persist(c)
    assert c.state in ("materialized", "pending")
    np.testing.assert_allclose(c.compute(), a @ b, rtol=1e-4, atol=1e-4)
    s.close()


def test_matmul_with_host_operand_and_rmatmul(engine, data):
    a, b = data
    s = repro.connect(engine)
    da = dasklike.from_array(s, a)
    np.testing.assert_allclose((da @ b).compute(), a @ b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        (a.T @ da).compute(), a.T @ a, rtol=1e-3, atol=1e-3
    )
    s.close()


def test_svd_factors_reconstruct(engine):
    rng = np.random.default_rng(3)
    a = (rng.standard_normal((48, 8)) @ rng.standard_normal((8, 32))).astype(
        np.float32
    )
    da = dasklike.from_array(repro.connect(engine), a)
    u, sv, v = dasklike.svd(da, k=8)
    uu, ss, vv = dasklike.compute(u, sv, v)
    recon = np.asarray(uu) @ np.diag(np.asarray(ss)) @ np.asarray(vv).T
    np.testing.assert_allclose(recon, a, rtol=1e-2, atol=1e-2)
    da._session.close()


def test_frontend_runs_over_tcp_transport(engine, data):
    a, b = data
    s = repro.connect(engine, transport="tcp")
    da = dasklike.from_array(s, a)
    out = (da @ b).compute()
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)
    assert s.transport.wire_stats()["frames"] > 0  # bytes really crossed
    s.close()
    assert engine.stats()["engine"]["available_workers"] == 1
