"""Property tests for pad-to-divisible send geometry (DESIGN.md §7).

The bridge pads dim0/dim1 up to the next multiple of the destination layout's
shard counts before ``device_put`` and slices the padding off on
collect/refill. Two layers of coverage:

- here: the pure geometry, on arbitrary (m, n, row_shards, col_shards) —
  pad amounts are minimal and correct, and a pad → block-shard → reassemble →
  strip round trip is bit-exact, including m < worker_count;
- tests/multidevice/_padding_script.py: the same property end-to-end through
  a real 8-device engine (send → collect across worker groups).

Runs under hypothesis when installed (CI); the deterministic parametrized
cases keep the invariants exercised everywhere else (the
tests/_hypothesis_compat.py shim skips only the property tests).
"""

import dataclasses
from typing import Tuple

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.errors import LayoutError
from repro.core.layouts import GRID, ROW, LayoutSpec
from repro.core.relayout import pad_amounts, shard_intervals

DTYPES = ["float32", "float64", "int32", "float16"]


@dataclasses.dataclass
class _FakeMesh:
    """(axis_names, devices.shape) duck-type for shard-geometry helpers."""

    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...] = ("data", "model")

    class _Dev:
        def __init__(self, shape):
            self.shape = shape

    @property
    def devices(self):
        return _FakeMesh._Dev(self.shape)


def _roundtrip(m: int, n: int, r: int, c: int, dtype: str, seed: int) -> None:
    """Pad → block-shard over an r x c grid → reassemble → strip == identity."""
    mesh = _FakeMesh((r, c))
    spec = LayoutSpec("grid", row_axes=("data",), col_axes=("model",))
    pr, pc = pad_amounts((m, n), spec, mesh)
    # pads are minimal and make the physical shape exactly divisible
    assert 0 <= pr < r and 0 <= pc < c
    assert (m + pr) % r == 0 and (n + pc) % c == 0

    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((m, n)) * 8).astype(dtype)
    phys = np.pad(x, ((0, pr), (0, pc)))

    rows = shard_intervals(m + pr, r)
    cols = shard_intervals(n + pc, c)
    # every shard of the padded matrix is full-size (what device_put needs)
    assert {int(e - s) for s, e in rows} == {(m + pr) // r}
    assert {int(e - s) for s, e in cols} == {(n + pc) // c}

    reassembled = np.block(
        [[phys[rs:re, cs:ce] for cs, ce in cols] for rs, re in rows]
    )
    np.testing.assert_array_equal(reassembled[:m, :n], x)  # bit-exact strip


def _worker_count_pad(m: int, w: int) -> None:
    """ROW staging pads dim0 to the next worker-count multiple (dim1 free)."""
    mesh = _FakeMesh((w, 1), axis_names=("data", "model"))
    spec = LayoutSpec("row", row_axes=("data", "model"), col_axes=())
    pr, pc = pad_amounts((m, 7), spec, mesh)
    assert pc == 0
    assert (m + pr) % w == 0 and pr < w
    if m % w == 0:
        assert pr == 0  # divisible shapes stay byte-identical to before


# -- hypothesis properties --------------------------------------------------

@given(
    m=st.integers(min_value=1, max_value=64),
    n=st.integers(min_value=1, max_value=32),
    r=st.integers(min_value=1, max_value=8),
    c=st.integers(min_value=1, max_value=8),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=150, deadline=None)
def test_pad_shard_strip_roundtrip(m, n, r, c, dtype, seed):
    _roundtrip(m, n, r, c, dtype, seed)


@given(m=st.integers(min_value=1, max_value=128), w=st.integers(min_value=1, max_value=16))
@settings(max_examples=150, deadline=None)
def test_row_staging_pads_to_worker_multiple(m, w):
    _worker_count_pad(m, w)


# -- deterministic fallback cases -------------------------------------------

@pytest.mark.parametrize(
    "m,n,r,c",
    [
        (6, 6, 2, 2),  # the ROADMAP's 6x6-to-4-workers case
        (1, 1, 8, 8),  # single element, m < worker count
        (2, 5, 4, 2),  # m < row shards
        (7, 3, 3, 5),  # nothing divides anything
        (16, 8, 4, 2),  # already divisible: zero pads
        (5, 5, 1, 1),  # single worker: zero pads
    ],
)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pad_shard_strip_roundtrip_cases(m, n, r, c, dtype):
    _roundtrip(m, n, r, c, dtype, seed=m * 1000 + n)


@pytest.mark.parametrize("m,w", [(6, 4), (1, 8), (12, 4), (13, 8), (128, 16)])
def test_row_staging_cases(m, w):
    _worker_count_pad(m, w)


def test_grid_layout_pad_amounts_on_fake_mesh():
    mesh = _FakeMesh((2, 2))
    assert pad_amounts((6, 6), GRID, mesh) == (0, 0)  # 6 % 2 == 0 both dims
    assert pad_amounts((6, 6), ROW, mesh) == (2, 0)  # row shards = 4
    assert pad_amounts((5, 3), GRID, mesh) == (1, 1)


# -- fused pad/strip kernels (DESIGN.md §10) ---------------------------------


def _fused_roundtrip(m: int, n: int, r: int, c: int, dtype: str, seed: int) -> None:
    """The Pallas pad/strip kernels agree bit-exactly with the kernels/ref.py
    oracles and round-trip as the identity — arbitrary grids, m < workers
    included. Interpret mode: the same kernel body the TPU path compiles,
    executed on any backend."""
    import jax.numpy as jnp

    from repro.kernels import ref as kref
    from repro.kernels import relayout_pad as krp

    mesh = _FakeMesh((r, c))
    spec = LayoutSpec("grid", row_axes=("data",), col_axes=("model",))
    pr, pc = pad_amounts((m, n), spec, mesh)
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((m, n)) * 8).astype(dtype)
    xd = jnp.asarray(x)  # canonicalized as the device sees it (f64 -> f32)
    physical = (m + pr, n + pc)

    fused = krp.pad_to(xd, physical, interpret=True)
    oracle = kref.pad_to(xd, physical)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(oracle))

    back = krp.strip_to(fused, (m, n), interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(xd))
    np.testing.assert_array_equal(
        np.asarray(kref.strip_to(oracle, (m, n))), np.asarray(xd)
    )


@given(
    m=st.integers(min_value=1, max_value=16),
    n=st.integers(min_value=1, max_value=16),
    r=st.integers(min_value=1, max_value=4),
    c=st.integers(min_value=1, max_value=4),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_fused_pad_strip_matches_ref(m, n, r, c, dtype, seed):
    _fused_roundtrip(m, n, r, c, dtype, seed)


@pytest.mark.parametrize(
    "m,n,r,c",
    [
        (6, 6, 2, 2),  # pads (0, 0): the kernels must pass through untouched
        (1, 1, 8, 8),  # single element, m < worker count
        (2, 5, 4, 2),  # m < row shards
        (7, 3, 3, 5),  # nothing divides anything
        (5, 5, 1, 1),  # single worker: zero pads
    ],
)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_pad_strip_cases(m, n, r, c, dtype):
    _fused_roundtrip(m, n, r, c, dtype, seed=m * 100 + n)


def test_fused_kernels_refuse_impossible_directions():
    from repro.kernels import ref as kref
    from repro.kernels import relayout_pad as krp

    x = np.ones((4, 4), np.float32)
    for mod in (krp, kref):
        with pytest.raises(ValueError):
            mod.pad_to(x, (2, 4))  # pad may never shrink
        with pytest.raises(ValueError):
            mod.strip_to(x, (8, 4))  # strip may never grow


def test_cyclic_layouts_refuse_padding():
    # The cyclic emulation permutes rows as a function of the physical
    # length: appended zero rows would interleave into the interior and
    # silently corrupt logical reads. Uneven + cyclic must fail loudly.
    mesh = _FakeMesh((2, 2))
    cyc = GRID.with_cyclic()
    assert pad_amounts((6, 6), cyc, mesh) == (0, 0)  # divisible: fine
    with pytest.raises(LayoutError, match="cyclic"):
        pad_amounts((5, 6), cyc, mesh)
