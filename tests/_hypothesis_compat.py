"""Optional-hypothesis shim.

`hypothesis` is a dev-only dependency (see requirements-dev.txt). When it is
installed, this module re-exports the real `given`/`settings`/`st`. When it
is missing, property tests are skipped individually — the rest of each test
module still runs, instead of the whole module dying at collection with
ModuleNotFoundError.
"""

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Chainable stand-in: any attribute/call/compose returns a strategy."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):
            return self

    st = _Strategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
