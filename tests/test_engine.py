"""Engine behaviour tests: sessions, handles, transfers, library calls.

Single-device here; the multi-device engine semantics (worker groups,
genuine relayout traffic) are covered by tests/multidevice/.
"""

import numpy as np
import pytest

import repro
from repro.core.errors import (
    HandleError,
    LibraryError,
    SessionError,
    WorkerAllocationError,
)


@pytest.fixture()
def engine():
    return repro.AlchemistEngine()


@pytest.fixture()
def ac(engine):
    ctx = repro.AlchemistContext(engine, num_workers=1, name="test_app")
    yield ctx
    ctx.stop()


class TestSessions:
    def test_connect_allocates_workers(self, engine):
        ac = repro.AlchemistContext(engine, num_workers=1)
        assert engine.available_workers == engine.num_workers - 1
        ac.stop()
        assert engine.available_workers == engine.num_workers

    def test_overallocation_raises(self, engine):
        with pytest.raises(WorkerAllocationError):
            repro.AlchemistContext(engine, num_workers=engine.num_workers + 1)

    def test_stopped_context_rejects_use(self, engine):
        ac = repro.AlchemistContext(engine, num_workers=1)
        ac.stop()
        with pytest.raises(SessionError):
            ac.send(np.eye(3))

    def test_double_stop_is_idempotent(self, engine):
        ac = repro.AlchemistContext(engine, num_workers=1)
        ac.stop()
        ac.stop()

    def test_context_manager(self, engine):
        with repro.AlchemistContext(engine, num_workers=1) as ac:
            ac.send(np.eye(2))
        assert engine.available_workers == engine.num_workers


class TestHandles:
    def test_send_collect_roundtrip(self, ac, rng):
        a = rng.standard_normal((37, 19)).astype(np.float32)
        h = ac.send(a, name="A")
        assert h.shape == (37, 19)
        assert h.name == "A"
        back = np.asarray(ac.collect(h))
        np.testing.assert_allclose(back, a, rtol=1e-6)

    def test_handles_are_session_scoped(self, engine, rng):
        # paper: each application has its own matrix namespace
        ac1 = repro.AlchemistContext(engine, num_workers=1)
        h = ac1.send(rng.standard_normal((4, 4)))
        ac1.stop()
        ac2 = repro.AlchemistContext(engine, num_workers=1)
        with pytest.raises(HandleError):
            ac2.collect(h)
        ac2.stop()

    def test_freed_handle_rejected(self, ac, rng):
        h = ac.send(rng.standard_normal((4, 4)))
        ac.free(h)
        with pytest.raises(HandleError):
            ac.collect(h)

    def test_send_requires_2d(self, ac):
        with pytest.raises(SessionError):
            ac.send(np.zeros(5))

    def test_transfer_stats_accumulate(self, ac, rng):
        a = rng.standard_normal((16, 8)).astype(np.float32)
        h = ac.send(a)
        ac.collect(h)
        s = ac.stats.summary()
        assert s["num_sends"] == 1
        assert s["num_receives"] == 1
        assert s["send_bytes"] == a.nbytes
        assert s["recv_bytes"] == a.nbytes


class TestLibraries:
    def test_register_by_import_path(self, ac):
        # the "dlopen at runtime" analogue
        lib = ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        assert "truncated_svd" in lib.routine_names()

    def test_unknown_library_raises(self, ac):
        with pytest.raises(LibraryError):
            ac.run("nope", "gemm")

    def test_unknown_routine_raises(self, ac):
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        with pytest.raises(LibraryError):
            ac.run("elemental", "not_a_routine")

    def test_bad_import_path(self, ac):
        with pytest.raises(LibraryError):
            ac.register_library("x", "repro.not_a_module:Nothing")
        with pytest.raises(LibraryError):
            ac.register_library("x", "repro.linalg.library:NotAClass")

    def test_gemm_via_engine(self, ac, rng):
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        a = rng.standard_normal((24, 16)).astype(np.float32)
        b = rng.standard_normal((16, 8)).astype(np.float32)
        ha, hb = ac.send(a), ac.send(b)
        hc = ac.run("elemental", "gemm", ha, hb)
        np.testing.assert_allclose(np.asarray(ac.collect(hc)), a @ b, atol=1e-4)

    def test_chained_calls_do_not_transfer(self, ac, rng):
        # the AlMatrix residency contract: only collect() moves bulk data
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        a = rng.standard_normal((16, 16)).astype(np.float32)
        ha = ac.send(a)
        before = ac.stats.num_sends + ac.stats.num_receives
        h2 = ac.run("elemental", "gemm", ha, ha)
        h3 = ac.run("elemental", "gemm", h2, ha)
        assert (ac.stats.num_sends + ac.stats.num_receives) == before
        np.testing.assert_allclose(
            np.asarray(ac.collect(h3)), a @ a @ a, atol=1e-3
        )

    def test_scalar_outputs_return_to_driver(self, ac, rng):
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        a = rng.standard_normal((32, 8)).astype(np.float32)
        ha = ac.send(a)
        norm = ac.run("elemental", "normest", ha)
        assert isinstance(norm, np.ndarray)
        np.testing.assert_allclose(float(norm), np.linalg.norm(a), rtol=1e-4)

    def test_compute_time_recorded(self, ac, rng):
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        ha = ac.send(rng.standard_normal((16, 16)).astype(np.float32))
        ac.run("elemental", "gemm", ha, ha)
        assert ac.stats.compute_seconds > 0
        assert ac.stats.num_runs == 1
