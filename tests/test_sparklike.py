"""Spark-baseline framework tests: RDD mechanics, shuffle accounting,
BlockMatrix multiply, MLlib-style computeSVD."""

import numpy as np
import pytest

from repro.sparklike import (
    ClusterModel,
    IndexedRowMatrix,
    SparkLikeContext,
    mllib,
)
from repro.sparklike import mllib


@pytest.fixture()
def ctx():
    return SparkLikeContext(num_partitions=4)


class TestRDD:
    def test_parallelize_partitions(self, ctx, rng):
        a = rng.standard_normal((100, 8))
        rdd = ctx.parallelize(a)
        assert rdd.num_partitions == 4
        got = np.concatenate(rdd.collect())
        np.testing.assert_array_equal(got, a)

    def test_map_partitions_counts_stage(self, ctx, rng):
        rdd = ctx.parallelize(rng.standard_normal((16, 2)))
        before = ctx.stats.stages
        rdd.map_partitions(lambda p: p * 2)
        assert ctx.stats.stages == before + 1
        assert ctx.stats.tasks >= 4

    def test_reduce_syncs_driver(self, ctx, rng):
        rdd = ctx.parallelize(rng.standard_normal((16, 2)))
        before = ctx.stats.driver_syncs
        total = rdd.reduce(lambda a, b: a + b)
        assert ctx.stats.driver_syncs == before + 1
        assert total.shape == (4, 2)  # per-partition blocks summed

    def test_broadcast_charges_bytes(self, ctx):
        v = np.zeros(1000)
        before = ctx.stats.broadcast_bytes
        ctx.broadcast(v)
        assert ctx.stats.broadcast_bytes - before == v.nbytes * 4


class TestMatrices:
    def test_indexed_row_roundtrip(self, ctx, rng):
        a = rng.standard_normal((50, 12))
        ir = IndexedRowMatrix.from_numpy(ctx, a)
        np.testing.assert_allclose(ir.to_numpy(), a)

    def test_block_conversion_preserves_matrix(self, ctx, rng):
        a = rng.standard_normal((37, 23))
        bm = IndexedRowMatrix.from_numpy(ctx, a).to_block_matrix(block_size=10)
        np.testing.assert_allclose(bm.to_numpy(), a)

    def test_block_conversion_charges_triple_explosion(self, ctx, rng):
        # paper §4.1: the (i, j, v) explosion costs 24 B/elem on the wire
        a = rng.standard_normal((64, 64))
        ctx.reset_stats()
        IndexedRowMatrix.from_numpy(ctx, a).to_block_matrix(block_size=16)
        assert ctx.stats.shuffle_bytes >= 64 * 64 * 16  # at least the premium

    def test_block_matrix_roundtrip_to_rows(self, ctx, rng):
        a = rng.standard_normal((30, 20))
        bm = IndexedRowMatrix.from_numpy(ctx, a).to_block_matrix(block_size=8)
        back = bm.to_indexed_row_matrix()
        np.testing.assert_allclose(back.to_numpy(), a)

    @pytest.mark.parametrize("m,k,n,bs", [(32, 24, 16, 8), (33, 17, 9, 10)])
    def test_multiply_correct(self, ctx, rng, m, k, n, bs):
        a = rng.standard_normal((m, k))
        b = rng.standard_normal((k, n))
        c = mllib.multiply(
            IndexedRowMatrix.from_numpy(ctx, a),
            IndexedRowMatrix.from_numpy(ctx, b),
            block_size=bs,
        )
        np.testing.assert_allclose(c.to_numpy(), a @ b, atol=1e-8)

    def test_multiply_dimension_mismatch(self, ctx, rng):
        a = IndexedRowMatrix.from_numpy(ctx, rng.standard_normal((8, 4)))
        b = IndexedRowMatrix.from_numpy(ctx, rng.standard_normal((5, 8)))
        with pytest.raises(ValueError):
            a.to_block_matrix(4).multiply(b.to_block_matrix(4))


class TestComputeSVD:
    def _decaying(self, rng, m, n, decay=0.8):
        u, _ = np.linalg.qr(rng.standard_normal((m, n)))
        v, _ = np.linalg.qr(rng.standard_normal((n, n)))
        s = decay ** np.arange(n) * 100
        return (u * s) @ v.T

    def test_sigmas_match_numpy(self, ctx, rng):
        a = self._decaying(rng, 200, 32)
        u, s, v = mllib.compute_svd(IndexedRowMatrix.from_numpy(ctx, a), 8)
        s_ref = np.linalg.svd(a, compute_uv=False)[:8]
        np.testing.assert_allclose(s, s_ref, rtol=1e-6)

    def test_u_orthonormal(self, ctx, rng):
        a = self._decaying(rng, 150, 24)
        u, s, v = mllib.compute_svd(IndexedRowMatrix.from_numpy(ctx, a), 6)
        un = u.to_numpy()
        np.testing.assert_allclose(un.T @ un, np.eye(6), atol=1e-8)

    def test_driver_roundtrips_scale_with_iterations(self, ctx, rng):
        # the MLlib pathology the paper measures: one driver sync per matvec
        a = self._decaying(rng, 100, 16)
        ctx.reset_stats()
        mllib.compute_svd(IndexedRowMatrix.from_numpy(ctx, a), 4, oversample=4)
        # >= 2 syncs per Lanczos iteration (broadcast + reduce), 8 iterations
        assert ctx.stats.driver_syncs >= 16


class TestClusterModel:
    def test_modeled_time_monotonic_in_overheads(self):
        from repro.sparklike.rdd import DriverStats

        m = ClusterModel(num_executors=8)
        s1 = DriverStats(stages=10, tasks=100, shuffle_bytes=10**9)
        s2 = DriverStats(stages=20, tasks=100, shuffle_bytes=10**9)
        assert m.modeled_seconds(s2) > m.modeled_seconds(s1)

    def test_anti_scaling_of_task_overhead(self):
        """The paper's [2] anti-scaling: with more executors, fixed work
        splits into more tasks and the driver-serial dispatch grows."""
        from repro.sparklike.rdd import DriverStats

        def time_at(n_exec):
            m = ClusterModel(num_executors=n_exec)
            # more executors -> more partitions -> more tasks per stage
            s = DriverStats(stages=30, tasks=30 * n_exec, shuffle_bytes=0)
            return m.modeled_seconds(s, flops=1e12)

        assert time_at(64) > time_at(8)  # overheads eventually dominate
