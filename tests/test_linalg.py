"""linalg routine tests (single-device; distributed semantics in
tests/multidevice/). Uses decaying-spectrum matrices where Krylov methods
are expected to converge (flat random spectra are out-of-contract for
truncated methods, as they are for ARPACK)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.linalg import gemm, pca, solvers, svd, tsqr


def spectrum_matrix(key, m, n, decay=0.8, scale=100.0):
    ku, kv = jax.random.split(key)
    u, _ = jnp.linalg.qr(jax.random.normal(ku, (m, n)))
    v, _ = jnp.linalg.qr(jax.random.normal(kv, (n, n)))
    s = decay ** jnp.arange(n) * scale
    return (u * s[None, :]) @ v.T


class TestGemm:
    @pytest.mark.parametrize("schedule", ["summa", "allgather", "xla"])
    def test_single_device_matches(self, mesh1, key, schedule):
        a = jax.random.normal(key, (48, 32))
        b = jax.random.normal(jax.random.PRNGKey(1), (32, 24))
        with mesh1:
            c = gemm.multiply(a, b, mesh1, schedule=schedule)
        np.testing.assert_allclose(np.asarray(c), np.asarray(a @ b), atol=1e-4)

    def test_unknown_schedule(self, mesh1, key):
        a = jax.random.normal(key, (8, 8))
        with pytest.raises(ValueError):
            gemm.multiply(a, a, mesh1, schedule="nope")

    def test_shape_mismatch(self, mesh1, key):
        a = jax.random.normal(key, (8, 9))
        with pytest.raises(ValueError):
            gemm.summa(a, a, mesh1)


class TestTSQR:
    @pytest.mark.parametrize("shape", [(256, 8), (100, 13), (64, 64)])
    def test_qr_properties(self, mesh1, key, shape):
        a = jax.random.normal(key, shape)
        with mesh1:
            q, r = tsqr.tsqr(a, mesh1)
        np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(q.T @ q), np.eye(shape[1]), atol=1e-4
        )
        np.testing.assert_allclose(np.asarray(r), np.triu(np.asarray(r)), atol=1e-5)


class TestTruncatedSVD:
    def test_lanczos_sigmas(self, mesh1, key):
        a = spectrum_matrix(key, 200, 64)
        s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)[:8]
        with mesh1:
            u, s, v = svd.truncated_svd(a, 8)
        np.testing.assert_allclose(np.asarray(s), s_ref, rtol=1e-3)
        # singular triples: A v ≈ u s
        av = np.asarray(a) @ np.asarray(v)
        np.testing.assert_allclose(av, np.asarray(u) * np.asarray(s), atol=0.05)

    def test_randomized_sigmas(self, mesh1, key):
        a = spectrum_matrix(key, 200, 64)
        s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)[:8]
        with mesh1:
            u, s, v = svd.randomized_svd(a, 8, power_iters=2)
        np.testing.assert_allclose(np.asarray(s), s_ref, rtol=1e-2)

    def test_reconstruction_error_near_optimal(self, mesh1, key):
        a = spectrum_matrix(key, 150, 40, decay=0.7)
        with mesh1:
            u, s, v = svd.truncated_svd(a, 10)
            err = svd.svd_reconstruction_error(a, u, s, v)
        s_all = np.linalg.svd(np.asarray(a), compute_uv=False)
        optimal = np.linalg.norm(s_all[10:]) / np.linalg.norm(s_all)
        assert float(err) < optimal * 1.05 + 1e-4

    @given(k=st.integers(1, 6), decay=st.floats(0.3, 0.85))
    @settings(max_examples=10, deadline=None)
    def test_sigma_ordering_property(self, k, decay):
        a = spectrum_matrix(jax.random.PRNGKey(3), 80, 24, decay=decay)
        u, s, v = svd.truncated_svd(a, k)
        s = np.asarray(s)
        assert (np.diff(s) <= 1e-4).all(), "singular values must be non-increasing"
        assert (s > 0).all()


class TestSolvers:
    def test_power_iteration(self, mesh1, key):
        a = spectrum_matrix(key, 100, 30, decay=0.5)
        with mesh1:
            sigma, vec = solvers.power_iteration(a, num_iters=100)
        s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)[0]
        np.testing.assert_allclose(float(sigma), s_ref, rtol=1e-3)

    def test_condest(self, mesh1, key):
        a = spectrum_matrix(key, 120, 16, decay=0.9)
        with mesh1:
            c = solvers.condest(a, num_iters=80, cg_iters=200)
        sv = np.linalg.svd(np.asarray(a), compute_uv=False)
        np.testing.assert_allclose(float(c), sv[0] / sv[-1], rtol=0.05)

    def test_ridge_solves_normal_equations(self, mesh1, key):
        a = jax.random.normal(key, (80, 20))
        b = jax.random.normal(jax.random.PRNGKey(2), (80,))
        lam = 0.1
        with mesh1:
            x = solvers.ridge(a, b, lam, num_iters=200)
        an, bn = np.asarray(a), np.asarray(b)
        x_ref = np.linalg.solve(an.T @ an + lam * np.eye(20), an.T @ bn)
        np.testing.assert_allclose(np.asarray(x), x_ref, atol=1e-3)

    def test_cg_on_spd(self, key):
        m = jax.random.normal(key, (16, 16))
        spd = m @ m.T + 16 * jnp.eye(16)
        b = jax.random.normal(jax.random.PRNGKey(5), (16,))
        x = solvers.cg(lambda v: spd @ v, b, num_iters=64)
        np.testing.assert_allclose(
            np.asarray(spd @ x), np.asarray(b), atol=1e-4
        )


class TestPCA:
    def test_components_orthonormal_and_variance_ordered(self, mesh1, key):
        a = spectrum_matrix(key, 300, 32, decay=0.75)
        with mesh1:
            comps, scores, var = pca.pca(a, 5)
        c = np.asarray(comps)
        np.testing.assert_allclose(c.T @ c, np.eye(5), atol=1e-4)
        v = np.asarray(var)
        assert (np.diff(v) <= 1e-5).all()

    def test_scores_match_projection(self, mesh1, key):
        a = spectrum_matrix(key, 120, 16, decay=0.6)
        with mesh1:
            comps, scores, _ = pca.pca(a, 4)
        centered = np.asarray(a) - np.asarray(a).mean(0)
        proj = centered @ np.asarray(comps)
        # scores defined up to sign per component
        for j in range(4):
            s, p = np.asarray(scores)[:, j], proj[:, j]
            assert min(np.abs(s - p).max(), np.abs(s + p).max()) < 0.05
