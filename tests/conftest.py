"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; multi-device tests spawn subprocesses that set the flag
themselves (see tests/multidevice/)."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def mesh1():
    from repro.core.sharding import single_device_mesh

    return single_device_mesh()


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
