"""The v2 client surface (DESIGN.md §9): connect()/Session/AlArray, pluggable
execution policies, admission-aware placement, and the v1 deprecation shim.

Runs warning-clean: CI executes this module (plus the API snapshot test)
with ``-W error::DeprecationWarning``, so nothing here may lean on the
deprecated AlchemistContext surface except the shim tests, which catch the
warning explicitly.
"""

import asyncio
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

import repro
from repro.core.errors import AdmissionTimeout, SessionError, WorkerAllocationError
from repro.core.expr import content_key
from repro.core.futures import AlFuture
from repro.core.handles import AlMatrix
from repro.core.layouts import GRID
from repro.core.policy import Eager, ExecutionPolicy, Pipelined, Planned, as_policy
from repro.linalg.wrappers import Elemental

ELEMENTAL = "repro.linalg.library:ElementalLib"


def _session(engine, **kw):
    s = repro.connect(engine, **kw)
    s.register_library("elemental", ELEMENTAL)
    return s


@pytest.fixture()
def engine():
    return repro.AlchemistEngine()


@pytest.fixture()
def data():
    rng = np.random.default_rng(7)
    a = rng.standard_normal((48, 32)).astype(np.float32)
    b = rng.standard_normal((32, 24)).astype(np.float32)
    return a, b


# ---------------------------------------------------------------------------
# the uniform AlArray handle
# ---------------------------------------------------------------------------


class TestAlArray:
    def test_send_run_data_roundtrip(self, engine, data):
        a, b = data
        with _session(engine, name="v2") as s:
            la = s.send(a, name="A")
            assert isinstance(la, repro.AlArray)
            assert la.shape == a.shape
            assert la.state == "deferred"  # Planned default: nothing ran
            lc = la @ s.send(b)
            out = lc.data()
            np.testing.assert_allclose(np.asarray(out), a @ b, atol=1e-4)
            assert lc.state in ("materialized", "spilled")

    def test_result_is_data_and_takes_timeout(self, engine, data):
        a, b = data
        with _session(engine) as s:
            lc = s.send(a) @ s.send(b)
            r1 = np.asarray(lc.result(timeout=60))
            r2 = np.asarray(lc.data())
            np.testing.assert_array_equal(r1, r2)

    def test_multi_output_run(self, engine, data):
        a, _ = data
        with _session(engine) as s:
            u, sv, v = s.run("elemental", "truncated_svd", s.send(a), n_outputs=3, k=4)
            assert isinstance(u, repro.AlArray)
            assert np.asarray(u.data()).shape == (48, 4)
            assert np.asarray(sv.data()).shape == (4,)
            assert np.asarray(v.data()).shape == (32, 4)

    def test_scalar_routine_returns_driver_value(self, engine, data):
        a, _ = data
        with _session(engine) as s:
            n = s.run("elemental", "normest", s.send(a))
            assert float(n.data()) == pytest.approx(
                float(np.linalg.norm(a)), rel=1e-3
            )

    def test_await_forces(self, engine, data):
        a, b = data
        with _session(engine) as s:

            async def go():
                return await (s.send(a) @ s.send(b))

            out = asyncio.run(go())
            np.testing.assert_allclose(np.asarray(out), a @ b, atol=1e-4)

    def test_alfuture_await(self, engine, data):
        a, _ = data
        with _session(engine) as s:
            fut = s.send_async(a)
            assert isinstance(fut, AlFuture)

            async def go():
                return await fut

            h = asyncio.run(go())
            assert isinstance(h, AlMatrix)

    def test_free_then_reforce_resends(self, engine, data):
        a, _ = data
        with _session(engine) as s:
            la = s.send(a)
            first = np.asarray(la.data())
            la.free()
            assert la.state == "freed"
            again = np.asarray(la.data())  # transparent re-send
            np.testing.assert_array_equal(first, again)

    def test_free_of_deferred_node_is_noop(self, engine, data):
        a, _ = data
        with _session(engine) as s:
            la = s.send(a)
            la.free()  # never lowered: nothing to release
            assert la.state == "deferred"

    def test_session_collect_and_free_accept_alarray(self, engine, data):
        a, _ = data
        with _session(engine) as s:
            la = s.send(a)
            np.testing.assert_array_equal(np.asarray(s.collect(la)), a)
            s.free(la)
            assert la.state == "freed"


# ---------------------------------------------------------------------------
# execution policies
# ---------------------------------------------------------------------------


class TestPolicies:
    def _roundtrip(self, policy, data):
        """connect → send → gemm → svd → .data() under one policy."""
        a, b = data
        engine = repro.AlchemistEngine()
        with _session(engine, policy=policy, name=f"p_{policy}") as s:
            lc = s.send(a, name="A") @ s.send(b, name="B")
            u, sv, v = s.run("elemental", "truncated_svd", lc, n_outputs=3, k=4)
            out = (
                np.asarray(lc.data()),
                np.asarray(u.data()),
                np.asarray(sv.data()),
                np.asarray(v.data()),
            )
        engine.shutdown()
        return out

    def test_roundtrip_identical_under_all_policies(self, data):
        eager = self._roundtrip("eager", data)
        pipelined = self._roundtrip("pipelined", data)
        planned = self._roundtrip("planned", data)
        for e, p in zip(eager, pipelined):
            np.testing.assert_array_equal(e, p)  # bit-exact vs eager
        for e, p in zip(eager, planned):
            np.testing.assert_array_equal(e, p)

    def test_eager_policy_materializes_at_build(self, engine, data):
        a, _ = data
        with _session(engine, policy="eager") as s:
            la = s.send(a)
            assert la.state in ("materialized", "spilled")

    def test_pipelined_policy_dispatches_without_blocking(self, engine, data):
        a, _ = data
        with _session(engine, policy=Pipelined()) as s:
            la = s.send(a)
            assert la.state in ("pending", "materialized")
            s.wait()
            assert la.state == "materialized"

    def test_policy_scope_restores(self, engine, data):
        a, _ = data
        with _session(engine) as s:
            assert isinstance(s.execution_policy, Planned)
            with s.policy("eager"):
                assert isinstance(s.execution_policy, Eager)
                le = s.send(a)
                assert le.state in ("materialized", "spilled")
            assert isinstance(s.execution_policy, Planned)

    def test_as_policy_spellings(self):
        assert isinstance(as_policy(None), Planned)
        assert isinstance(as_policy("eager"), Eager)
        assert isinstance(as_policy(Pipelined), Pipelined)
        p = Planned()
        assert as_policy(p) is p
        with pytest.raises(SessionError):
            as_policy("warp-speed")
        with pytest.raises(SessionError):
            as_policy(42)

    def test_policies_share_one_dag_and_counters(self, engine, data):
        a, b = data
        with _session(engine, policy="planned") as s:
            lc = s.send(a) @ s.send(b)
            lc.data()
            stats = s.stats.summary()
            assert stats["planned_ops"] == 1
            assert stats["num_sends"] == 2


# ---------------------------------------------------------------------------
# policy-routed library wrappers (the per-kind closures are gone)
# ---------------------------------------------------------------------------


class TestWrapperPolicies:
    def test_three_kinds_route_through_policies(self, engine, data):
        a, _ = data
        sq = a.T @ a  # square, so gemm(h, h) composes
        with _session(engine) as s:
            el = Elemental(s)
            assert isinstance(el._eager._policy, Eager)
            assert isinstance(el.submit._policy, Pipelined)
            assert isinstance(el.lazy._policy, Planned)

            h = s.send(sq).materialize()  # an engine-side AlMatrix
            eager_out = el.gemm(h, h)
            assert isinstance(eager_out, AlMatrix)

            fut = el.submit.gemm(h, h)
            assert isinstance(fut, AlFuture)
            assert isinstance(fut.result(60), AlMatrix)

            lazy_out = el.lazy.gemm(sq, sq)
            np.testing.assert_allclose(
                np.asarray(lazy_out.collect()), sq @ sq, atol=1e-2
            )

    def test_eager_and_submit_reject_n_outputs(self, engine, data):
        a, _ = data
        with _session(engine) as s:
            el = Elemental(s)
            h = s.send(a).materialize()
            with pytest.raises(SessionError, match="n_outputs"):
                el.truncated_svd(h, n_outputs=3, k=2)
            with pytest.raises(SessionError, match="n_outputs"):
                el.submit.truncated_svd(h, n_outputs=3, k=2)
            u, sv, v = el.lazy.truncated_svd(a, n_outputs=3, k=2)
            assert np.asarray(u.collect()).shape == (48, 2)

    def test_unknown_routine_still_fails_fast(self, engine):
        with _session(engine) as s:
            el = Elemental(s)
            with pytest.raises(AttributeError):
                el.not_a_routine
            with pytest.raises(AttributeError):
                el.submit.not_a_routine
            with pytest.raises(AttributeError):
                el.lazy.not_a_routine


# ---------------------------------------------------------------------------
# admission-aware connect()
# ---------------------------------------------------------------------------


class TestAdmission:
    def test_queued_then_placed(self, engine, data):
        a, _ = data
        hog = repro.connect(engine, workers=engine.num_workers, name="hog")

        def release_later():
            time.sleep(0.25)
            hog.close()

        t = threading.Thread(target=release_later)
        t.start()
        t0 = time.perf_counter()
        s = _session(
            engine, placement=repro.PlacementRequest(workers=1, deadline=30), name="queued"
        )
        waited = time.perf_counter() - t0
        t.join()
        assert waited >= 0.2, waited  # genuinely queued, not failed
        assert engine.admissions["queued"] == 1
        np.testing.assert_array_equal(np.asarray(s.send(a).data()), a)
        s.close()

    def test_timeout_raises_cleanly_no_leaks(self, engine):
        hog = repro.connect(engine, workers=engine.num_workers, name="hog")
        gov_sessions = set(engine.memgov._sessions)
        t0 = time.perf_counter()
        with pytest.raises(AdmissionTimeout):
            repro.connect(
                engine,
                placement=repro.PlacementRequest(workers=1, deadline=0.2),
                hbm_budget=1 << 20,
            )
        assert time.perf_counter() - t0 < 5
        # nothing leaked: no worker group, no governor registration, no
        # session table entry, no waiter left behind
        assert engine.available_workers == 0
        assert set(engine.memgov._sessions) == gov_sessions
        assert len(engine.sessions) == 1
        assert engine.queued_connects == 0
        assert engine.admissions["timeouts"] == 1
        hog.close()
        # the pool recovered: a later connect is immediate
        s = repro.connect(engine, workers=1)
        s.close()

    def test_admission_timeout_is_a_worker_allocation_error(self):
        assert issubclass(AdmissionTimeout, WorkerAllocationError)

    def test_impossible_request_fails_fast_even_queued(self, engine):
        t0 = time.perf_counter()
        with pytest.raises(WorkerAllocationError, match="only has"):
            repro.connect(
                engine,
                placement=repro.PlacementRequest(workers=engine.num_workers + 1, deadline=30),
            )
        assert time.perf_counter() - t0 < 5  # did not sit in the queue

    def test_deadline_zero_preserves_v1_fail_fast(self, engine):
        hog = repro.connect(engine, workers=engine.num_workers)
        with pytest.raises(WorkerAllocationError):
            repro.connect(engine, placement=repro.PlacementRequest(workers=1, deadline=0))
        hog.close()

    def test_nonpositive_request_fails_fast_even_queued(self, engine):
        # must never sit in the admission queue waiting for 0 workers
        t0 = time.perf_counter()
        with pytest.raises(WorkerAllocationError, match="0 workers"):
            repro.connect(engine, workers=0)  # queue=True default, no timeout
        with pytest.raises(WorkerAllocationError):
            repro.connect(engine, workers=-2)
        with pytest.raises(WorkerAllocationError, match="grid"):
            repro.connect(engine, grid=(0, 3))
        assert time.perf_counter() - t0 < 5

    def test_derived_expression_dataset_rejected(self, engine, data):
        a, b = data
        with _session(engine) as s:
            derived = s.send(a) @ s.send(b)  # RunExpr: no content key
            with pytest.raises(WorkerAllocationError, match="derived expression"):
                repro.connect(
                    engine,
                    placement=repro.PlacementRequest(workers=1, affinity=(derived,), deadline=0),
                )
            # a send node's key, by contrast, is declared for free
            engine._pick_block(1, [])  # engine still consistent
            assert repro.core.engine._dataset_keys([s.send(a)]) == [content_key(a)]

    def test_datasets_not_hashed_when_store_disabled(self, monkeypatch):
        engine = repro.AlchemistEngine(share_residents=False)

        def boom(_array):
            raise AssertionError("content_key must not run with the store disabled")

        monkeypatch.setattr(repro.core.engine, "content_key", boom)
        s = repro.connect(
            engine,
            placement=repro.PlacementRequest(workers=1, affinity=(np.ones((256, 256)),)),
        )
        s.close()


class TestPlacementSurface:
    """The declarative admission API (DESIGN.md §12): PlacementRequest in,
    resolved PlacementTicket out via ``Session.placement``."""

    def test_session_exposes_resolved_ticket(self, engine):
        with repro.connect(
            engine, placement=repro.PlacementRequest(workers=1, priority=3, deadline=5)
        ) as s:
            ticket = s.placement
            assert ticket is not None
            assert ticket.state == "placed"
            assert ticket.n == 1
            assert ticket.priority == 3
            assert not ticket.shared
            summary = ticket.summary()
            assert summary["workers"] == 1 and summary["state"] == "placed"
            # the resolved ticket also rides along in engine.stats()
            (sess,) = engine.stats()["sessions"].values()
            assert sess["placement"] == summary

    def test_placement_mixed_with_legacy_kwargs_rejected(self, engine):
        with pytest.raises(SessionError, match="placement"):
            repro.connect(engine, workers=1, placement=repro.PlacementRequest(workers=1))

    def test_pressure_is_sampled_at_queue_and_placement(self, engine):
        with repro.connect(engine, placement=repro.PlacementRequest(workers=1)) as s:
            ticket = s.placement
            assert ticket.pressure_at_placement is not None
            assert engine.admissions["pressure_at_placement"] == ticket.pressure_at_placement

    def test_affine_connect_joins_shared_worker_group(self, engine, data):
        a, _ = data
        with _session(engine, name="writer") as s1:
            ref = s1.send(a).data()
            with repro.connect(
                engine,
                name="reader",
                placement=repro.PlacementRequest(affinity=(a,), deadline=10),
            ) as s2:
                assert s2.placement.shared
                got = s2.send(a).data()
                np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
                stats = s2.session.stats.summary()
                assert stats["placement_bytes"] == 0
                assert stats["shared_views"] == 1
            assert engine.stats()["scheduler"]["shared_joins"] == 1

    def test_scheduler_stats_section(self, engine):
        import json

        with repro.connect(engine, workers=1):
            snap = engine.stats()["scheduler"]
            json.dumps(snap)
            assert snap["free_workers"] == engine.num_workers - 1
            assert snap["groups"] == 1
            assert snap["placed"] == 1
            assert snap["aging_bound"] == 4
            assert snap["watermarks"] is None


class _FakeDev(SimpleNamespace):
    def __init__(self, i):
        super().__init__(id=i)

    def __hash__(self):
        return hash(("fake", self.id))


class TestContentAffinity:
    """Placement prefers the free block whose resident-store content the
    declared datasets can reuse. Unit-level (fake device pool) — the
    end-to-end path runs on a real 8-device mesh in
    tests/multidevice/_engine_script.py."""

    def _store_with_placement(self, engine, devs, payload):
        key = content_key(payload)
        handle = AlMatrix(
            shape=payload.shape, dtype=payload.dtype, layout=GRID, session_id=99
        )
        fake_session = SimpleNamespace(id=99, worker_devices=devs)
        engine.residents.register(key, handle, fake_session, payload=payload)
        return key

    def test_affinity_picks_reuse_bearing_block(self):
        devs = [_FakeDev(i) for i in range(8)]
        engine = repro.AlchemistEngine(devices=devs)
        payload = np.arange(12, dtype=np.float32).reshape(3, 4)
        key = self._store_with_placement(engine, devs[4:8], payload)

        # default pick is the canonical first block ...
        assert [d.id for d in engine._pick_block(4, [])] == [0, 1, 2, 3]
        # ... but a declared dataset steers to the warm block
        assert [d.id for d in engine._pick_block(4, [key])] == [4, 5, 6, 7]
        assert engine.admissions["affinity_hits"] == 1
        # ndarray datasets hash to the same key engine-side
        from repro.core.engine import _dataset_keys

        assert _dataset_keys([payload]) == [key]

    def test_unknown_key_keeps_canonical_placement(self):
        devs = [_FakeDev(i) for i in range(8)]
        engine = repro.AlchemistEngine(devices=devs)
        other = content_key(np.ones((2, 2), dtype=np.float32))
        assert [d.id for d in engine._pick_block(4, [other])] == [0, 1, 2, 3]
        assert engine.admissions["affinity_hits"] == 0

    def test_device_affinity_skips_unusable_entries(self):
        devs = [_FakeDev(i) for i in range(4)]
        engine = repro.AlchemistEngine(devices=devs)
        payload = np.ones((2, 2), dtype=np.float32)
        key = self._store_with_placement(engine, devs, payload)
        assert engine.residents.device_affinity([key]) == [frozenset({0, 1, 2, 3})]
        assert engine.residents.device_affinity([("no", "such", "key")]) == []


# ---------------------------------------------------------------------------
# engine.stats(): the merged observability snapshot
# ---------------------------------------------------------------------------


class TestEngineStats:
    def test_merged_snapshot(self, engine, data):
        a, _ = data
        with _session(engine, name="obs") as s:
            s.send(a).data()
            snap = engine.stats()
            assert set(snap) == {
                "engine", "sessions", "memgov", "residents", "scheduler", "wire",
            }
            eng = snap["engine"]
            assert eng["workers"] == engine.num_workers
            assert eng["live_sessions"] == 1
            assert eng["queued_connects"] == 0
            assert eng["admissions"]["immediate"] == 1
            (sess,) = snap["sessions"].values()
            assert sess["name"] == "obs"
            assert sess["num_sends"] == 1
            # data-plane counters (DESIGN.md §10) ride along in every summary
            for key in (
                "spill_copy_ns",
                "spill_overlap_ns",
                "transfer_queue_depth",
                "fused_relayouts",
            ):
                assert isinstance(sess[key], int)
            assert snap["memgov"]["pressure"] == snap["memgov"]["used"]
            assert snap["memgov"]["high_water"] > 0
            assert snap["residents"]["entries"] >= 1
            # the wire section is always present — zeros when no server runs
            w = snap["wire"]
            for key in (
                "inflight",
                "max_inflight",
                "vectored_writes",
                "shard_direct_receives",
                "reassembly_receives",
                "streamed_fetches",
                "gathered_fetches",
                "overlap_ns",
                "put_ns",
                "version_rejects",
            ):
                assert isinstance(w[key], int), key
            assert isinstance(w["server"], bool)
        after = engine.stats()
        assert after["engine"]["live_sessions"] == 0
        assert after["sessions"] == {}

    def test_snapshot_is_json_serializable(self, engine, data):
        import json

        a, _ = data
        with _session(engine) as s:
            s.send(a).data()
            json.dumps(engine.stats())

    def test_snapshot_carries_supervision_anchors(self, engine):
        """The fleet scraper's staleness fields (DESIGN.md §14): wall-clock
        birth, monotonic uptime, and a snapshot sequence that strictly
        advances per stats() call — all JSON-serializable."""
        import json
        import time as _time

        first = engine.stats()["engine"]
        assert first["snapshot_seq"] == 1
        assert first["uptime_s"] >= 0.0
        assert 0 < first["started_at"] <= _time.time() + 1.0
        second = engine.stats()["engine"]
        assert second["snapshot_seq"] == 2  # strictly advancing
        assert second["uptime_s"] >= first["uptime_s"]  # monotonic, no drift
        assert second["started_at"] == first["started_at"]
        json.dumps({"engine": second})


# ---------------------------------------------------------------------------
# the v1 deprecation shim
# ---------------------------------------------------------------------------


class TestV1Shim:
    def test_alchemist_context_warns_and_works(self, engine, data):
        a, b = data
        with pytest.warns(DeprecationWarning, match="repro.connect"):
            ac = repro.AlchemistContext(engine, num_workers=1, name="v1")
        ac.register_library("elemental", ELEMENTAL)
        ha = ac.send(a)
        hb = ac.send(b)
        hc = ac.run("elemental", "gemm", ha, hb)
        np.testing.assert_allclose(np.asarray(ac.collect(hc)), a @ b, atol=1e-4)
        ac.stop()

    def test_shim_and_v2_share_the_transport_core(self):
        from repro.core.client import ClientCore

        assert issubclass(repro.AlchemistContext, ClientCore)
        assert issubclass(repro.Session, ClientCore)
        # the v1 verbs are literally the core's eager methods
        assert repro.AlchemistContext.send is ClientCore.send_eager
        assert repro.AlchemistContext.run is ClientCore.run_eager

    def test_legacy_queue_kwarg_warns(self, engine):
        with pytest.warns(DeprecationWarning, match="queue"):
            s = repro.connect(engine, workers=1, queue=False)
        s.close()

    def test_legacy_timeout_kwarg_warns(self, engine):
        with pytest.warns(DeprecationWarning, match="timeout"):
            s = repro.connect(engine, workers=1, timeout=30)
        s.close()

    def test_legacy_datasets_kwarg_warns(self, engine):
        with pytest.warns(DeprecationWarning, match="datasets"):
            s = repro.connect(engine, workers=1, datasets=[np.ones((8, 8))])
        s.close()

    def test_legacy_kwargs_map_to_v1_semantics(self, engine):
        # queue=False -> fail fast, exactly the v1 behaviour
        hog = repro.connect(engine, workers=engine.num_workers)
        with pytest.warns(DeprecationWarning):
            with pytest.raises(WorkerAllocationError):
                repro.connect(engine, workers=1, queue=False)
        with pytest.warns(DeprecationWarning):
            with pytest.raises(AdmissionTimeout):
                repro.connect(engine, workers=1, queue=True, timeout=0.2)
        hog.close()

    def test_v2_session_emits_no_deprecation_warning(self, engine, data):
        import warnings

        a, _ = data
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            with _session(engine) as s:
                s.send(a).data()
                with s.policy("eager"):
                    s.send(np.ones((4, 4), dtype=np.float32))


# ---------------------------------------------------------------------------
# v2 + the offload / sparklike layers
# ---------------------------------------------------------------------------


class TestV2Offload:
    def test_offloaded_accepts_v2_session(self, engine, data):
        from repro.sparklike import offload

        a, _ = data
        with _session(engine) as s:
            with offload.offloaded(s) as planner:
                assert planner is s.planner
                u, sv, v = offload.compute_svd(planner, a, k=3)
                assert u.num_rows == a.shape[0] and u.num_cols == 3
                assert sv.shape == (3,)
            assert offload.active() is None

    def test_lazyrowmatrix_state_matches_alarray_vocab(self, engine, data):
        from repro.sparklike import offload

        a, _ = data
        with _session(engine) as s:
            with offload.offloaded(s) as planner:
                u, _, _ = offload.compute_svd(planner, a, k=3)
                assert u.state in (
                    "deferred",
                    "pending",
                    "materialized",
                    "spilled",
                )


class TestPolicyProtocol:
    def test_custom_policy_plugs_in(self, engine, data):
        """The policy surface is genuinely pluggable: a user-defined policy
        (here: lower after every N nodes) drives the same DAG."""
        a, b = data

        class EveryOther(ExecutionPolicy):
            name = "every-other"

            def __init__(self):
                self.n = 0

            def apply(self, planner, lazy):
                self.n += 1
                if self.n % 2 == 0:
                    planner.lower(lazy)

        with _session(engine, policy=EveryOther()) as s:
            lc = s.send(a) @ s.send(b)
            np.testing.assert_allclose(np.asarray(lc.data()), a @ b, atol=1e-4)
