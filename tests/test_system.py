"""End-to-end behaviour tests for the paper's system: the full
Spark-application workflow against the engine, and framework-level wiring."""

import numpy as np

import repro
from repro.configs import get_config, list_configs
from repro.sparklike import IndexedRowMatrix, SparkLikeContext, mllib


def test_paper_section_3_3_workflow(rng):
    """The complete §3.3 listing: connect, registerLibrary, AlMatrix, run,
    collect, stop — with correctness checked against numpy."""
    engine = repro.AlchemistEngine()
    ac = repro.AlchemistContext(engine, num_workers=1, name="spark_app")
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")

    a = rng.standard_normal((512, 64)).astype(np.float32)
    al_a = ac.send(a, name="A")

    cond = ac.run("elemental", "condest", al_a)
    assert abs(float(cond) - np.linalg.cond(a)) / np.linalg.cond(a) < 0.25

    al_u, s, al_v = ac.run("elemental", "truncated_svd", al_a, k=5)
    s_ref = np.linalg.svd(a, compute_uv=False)[:5]
    np.testing.assert_allclose(np.asarray(s), s_ref, rtol=0.05)

    u = np.asarray(ac.collect(al_u))
    assert u.shape == (512, 5)
    ac.stop()
    assert engine.available_workers == engine.num_workers


def test_spark_and_engine_agree_on_gemm(rng):
    """The Table-1 experiment's correctness core: both paths, same answer."""
    a = rng.standard_normal((96, 40))
    b = rng.standard_normal((40, 56))

    ctx = SparkLikeContext(num_partitions=4)
    c_spark = mllib.multiply(
        IndexedRowMatrix.from_numpy(ctx, a),
        IndexedRowMatrix.from_numpy(ctx, b),
        block_size=16,
    ).to_numpy()

    engine = repro.AlchemistEngine()
    with repro.AlchemistContext(engine, num_workers=1) as ac:
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        ha = ac.send(a.astype(np.float32))
        hb = ac.send(b.astype(np.float32))
        c_alch = np.asarray(ac.collect(ac.run("elemental", "gemm", ha, hb)))

    np.testing.assert_allclose(c_spark, a @ b, atol=1e-8)
    np.testing.assert_allclose(c_alch, a @ b, atol=1e-3)


def test_spark_and_engine_agree_on_svd(rng):
    """The Fig-3/4 experiment's correctness core."""
    u, _ = np.linalg.qr(rng.standard_normal((300, 32)))
    v, _ = np.linalg.qr(rng.standard_normal((32, 32)))
    a = (u * (0.8 ** np.arange(32) * 50)) @ v.T

    ctx = SparkLikeContext(num_partitions=4)
    _, sig_spark, _ = mllib.compute_svd(IndexedRowMatrix.from_numpy(ctx, a), 6)

    engine = repro.AlchemistEngine()
    with repro.AlchemistContext(engine, num_workers=1) as ac:
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        ha = ac.send(a.astype(np.float32))
        _, sig_alch, _ = ac.run("elemental", "truncated_svd", ha, k=6)

    np.testing.assert_allclose(sig_spark, np.asarray(sig_alch), rtol=5e-3)


def test_every_assigned_arch_is_registered():
    archs = set(list_configs())
    expected = {
        "whisper-large-v3", "qwen2-1.5b", "deepseek-coder-33b", "qwen3-14b",
        "internvl2-26b", "olmoe-1b-7b", "mamba2-130m", "jamba-v0.1-52b",
        "arctic-480b", "deepseek-7b",
    }
    assert expected <= archs
    for a in expected:
        cfg = get_config(a)
        assert cfg.source, f"{a} missing its citation"
        smoke = get_config(a, smoke=True)
        assert smoke.n_layers <= 4 and smoke.d_model <= 512
        if smoke.moe:
            assert smoke.moe.num_experts <= 4


def test_assigned_dims_match_assignment():
    """Spot-check the exact assigned dimensions."""
    cases = {
        "qwen2-1.5b": dict(n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
                           d_ff=8960, vocab=151936, qkv_bias=True),
        "deepseek-coder-33b": dict(n_layers=62, d_model=7168, n_heads=56,
                                   n_kv_heads=8, d_ff=19200, vocab=32256),
        "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
                            d_ff=4864, vocab=32000),
        "mamba2-130m": dict(n_layers=24, d_model=768, vocab=50280),
        "jamba-v0.1-52b": dict(n_layers=32, d_model=4096, n_heads=32,
                               n_kv_heads=8, d_ff=14336, vocab=65536),
    }
    for arch, dims in cases.items():
        cfg = get_config(arch)
        for k, v in dims.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
    assert get_config("mamba2-130m").ssm.d_state == 128
    assert get_config("olmoe-1b-7b").moe.num_experts == 64
    assert get_config("olmoe-1b-7b").moe.top_k == 8
    assert get_config("arctic-480b").moe.num_experts == 128
    assert get_config("arctic-480b").moe.dense_residual
    assert get_config("jamba-v0.1-52b").attn_period == 8
