"""The supervised engine fleet (DESIGN.md §14).

Tier-1: the health state machine, session re-admission descriptors, the
resident store's recovery enumeration/adoption APIs, and supervisor basics
(wire HEALTH scrapes, fleet stats). Tier-2 (the CI chaos lane): heartbeat-
detected death, kill/recovery mid-pipeline with bit-identical replay, and
the autoscaler. Multi-engine tests duplicate the host's device list across
slots so they run on a single-device tier-1 environment unchanged — each
engine's scheduler owns its *own copy* of the device handle.
"""

import json
import socket
import threading
import time

import jax
import numpy as np
import pytest

import repro
from repro.core import transport as wire
from repro.core.errors import SessionError
from repro.fleet import (
    DEAD,
    DEGRADED,
    HEALTHY,
    AutoscalePolicy,
    EngineHealth,
    FleetSupervisor,
    HealthPolicy,
    suffix_bytes,
)

ELEMENTAL = "repro.linalg.library:ElementalLib"


def _fleet(n=2, **kw):
    kw.setdefault("devices", list(jax.devices()) * n)
    kw.setdefault("engines", n)
    return FleetSupervisor(**kw)


def _snap(seq, uptime=None, pressure=0, budget=None):
    return {
        "engine": {"snapshot_seq": seq, "uptime_s": uptime if uptime is not None else seq},
        "memgov": {"pressure": pressure, "budget": budget},
    }


# ---------------------------------------------------------------------------
# health state machine
# ---------------------------------------------------------------------------


class TestEngineHealth:
    def test_fresh_scrapes_stay_healthy(self):
        h = EngineHealth(HealthPolicy(miss_threshold=2))
        assert h.observe(_snap(1)) == HEALTHY
        assert h.observe(_snap(2)) == HEALTHY
        assert h.heartbeats == 2 and h.misses == 0

    def test_stale_or_reordered_scrape_counts_as_miss(self):
        h = EngineHealth(HealthPolicy(miss_threshold=3))
        h.observe(_snap(5))
        assert h.observe(_snap(5)) == HEALTHY  # same seq: stale, 1 miss
        assert h.observe(_snap(3)) == HEALTHY  # reordered: stale, 2 misses
        assert h.stale == 2 and h.consecutive_misses == 2
        assert h.observe(_snap(2)) == DEAD  # third consecutive

    def test_uptime_running_backwards_is_stale(self):
        """A restarted process answering with a fresh counter must not
        masquerade as the engine we were monitoring."""
        h = EngineHealth(HealthPolicy(miss_threshold=1))
        h.observe(_snap(7, uptime=100.0))
        assert h.observe(_snap(8, uptime=0.5)) == DEAD

    def test_miss_threshold_is_consecutive(self):
        h = EngineHealth(HealthPolicy(miss_threshold=3))
        h.observe(_snap(1))
        h.miss()
        h.miss()
        h.observe(_snap(2))  # fresh scrape resets the consecutive count
        h.miss()
        h.miss()
        assert h.state == HEALTHY
        assert h.miss() == DEAD

    def test_pressure_degrades_and_recovers(self):
        h = EngineHealth(HealthPolicy(degraded_pressure=0.8))
        assert h.observe(_snap(1, pressure=900, budget=1000)) == DEGRADED
        assert h.observe(_snap(2, pressure=100, budget=1000)) == HEALTHY
        # budgetless engines never degrade on pressure
        assert h.observe(_snap(3, pressure=10**12, budget=None)) == HEALTHY

    def test_dead_is_terminal_until_revived(self):
        h = EngineHealth(HealthPolicy(miss_threshold=1))
        h.miss()
        assert h.state == DEAD
        assert h.observe(_snap(99)) == DEAD  # flapping engine stays dead
        assert h.revive() == HEALTHY
        assert h.observe(_snap(1)) == HEALTHY  # seq ledger was reset

    def test_summary_is_json_serializable(self):
        h = EngineHealth()
        h.observe(_snap(1))
        h.miss()
        json.dumps(h.summary())


# ---------------------------------------------------------------------------
# session re-admission descriptors
# ---------------------------------------------------------------------------


class TestSessionDescriptor:
    def test_descriptor_names_placement_and_libraries(self):
        engine = repro.AlchemistEngine()
        s = repro.connect(engine, name="app1")
        s.register_library("el", ELEMENTAL)
        d = s.session.descriptor()
        assert d["name"] == "app1"
        assert d["workers"] == s.session.num_workers
        assert d["libraries"] == {"el": ELEMENTAL}
        json.dumps(d)
        s.close()

    def test_descriptor_survives_close(self):
        """The drain runs before the recovery reads the descriptor: the
        fields must not be cleared by Session.close."""
        engine = repro.AlchemistEngine()
        s = repro.connect(engine)
        s.register_library("el", ELEMENTAL)
        sess = s.session
        s.close()
        d = sess.descriptor()
        assert d["libraries"] == {"el": ELEMENTAL}
        assert d["workers"] >= 1

    def test_instance_registered_library_records_import_path(self):
        from repro.linalg.library import ElementalLib

        engine = repro.AlchemistEngine()
        s = repro.connect(engine)
        s.register_library("el", ElementalLib())
        spec = s.session.descriptor()["libraries"]["el"]
        assert spec == "repro.linalg.library:ElementalLib"
        s.close()


# ---------------------------------------------------------------------------
# resident store: recovery enumeration + adoption
# ---------------------------------------------------------------------------


class TestStoreRecovery:
    def test_recoverable_for_live_session(self):
        engine = repro.AlchemistEngine()
        s = repro.connect(engine)
        a = np.arange(64, dtype=np.float32).reshape(8, 8)
        s.send(a).materialize()
        got = engine.residents.recoverable_for(s.session.id)
        assert len(got) == 1
        (entry,) = got.values()
        np.testing.assert_array_equal(entry.payload, a)
        s.close()

    def test_recoverable_after_drain_via_former_sessions(self):
        """The drain migrates placements out before the recovery enumerates;
        migrated content must still be found under the dead session's id."""
        engine = repro.AlchemistEngine()
        s = repro.connect(engine)
        sid = s.session.id
        a = np.arange(64, dtype=np.float32).reshape(8, 8)
        s.send(a).materialize()
        s.close()  # migration-on-close: payload orphaned host-side
        got = engine.residents.recoverable_for(sid)
        assert len(got) == 1
        np.testing.assert_array_equal(list(got.values())[0].payload, a)

    def test_explicit_free_is_not_recoverable(self):
        """A user free means the content is done — never resurrected."""
        engine = repro.AlchemistEngine()
        s = repro.connect(engine)
        sid = s.session.id
        la = s.send(np.ones((8, 8), dtype=np.float32))
        la.materialize()
        la.free()
        assert engine.residents.recoverable_for(sid) == {}
        s.close()

    def test_adopt_seeds_attach_path_with_zero_bridge_bytes(self):
        src = repro.AlchemistEngine()
        dst = repro.AlchemistEngine()
        s1 = repro.connect(src)
        a = np.arange(256, dtype=np.float32).reshape(16, 16)
        s1.send(a).materialize()
        for entry in src.residents.recoverable_for(s1.session.id).values():
            assert dst.residents.adopt(entry)
        s1.close()
        s2 = repro.connect(dst)
        lb = s2.send(a)  # byte-identical content: must attach, not send
        lb.materialize()
        s2.wait()
        stats = s2.stats.summary()
        assert stats["cross_session_reuses"] == 1
        assert stats["send_bytes"] == 0
        s2.close()

    def test_adopt_is_idempotent_and_payloadless_entries_refused(self):
        from repro.core.resident import ResidentEntry

        engine = repro.AlchemistEngine()
        bare = ResidentEntry(("k",), (4, 4), "float32", None)
        assert not engine.residents.adopt(bare)  # nothing to refill from
        bare.payload = np.zeros((4, 4), dtype=np.float32)
        assert engine.residents.adopt(bare)
        assert not engine.residents.adopt(bare)  # second adopt: no-op


# ---------------------------------------------------------------------------
# supervisor basics (tier-1: single engine)
# ---------------------------------------------------------------------------


class TestSupervisorBasics:
    def test_health_verb_scrapes_over_the_wire(self):
        with _fleet(1) as sup:
            slot = next(iter(sup.engines.values()))
            sock = socket.create_connection(slot.server.address, timeout=5)
            try:
                wire.send_frame(sock, wire.T_HEALTH, {"__rid": 3})
                ftype, reply, _ = wire.recv_frame(sock)
                assert ftype == wire.T_OK
                assert reply["__rid"] == 3
                snap = json.loads(str(reply["__stats_json"]))
                assert snap["engine"]["snapshot_seq"] >= 1
                assert reply["__seq"] == snap["engine"]["snapshot_seq"]
            finally:
                sock.close()

    def test_heartbeat_classifies_healthy_and_stats_serialize(self):
        with _fleet(1) as sup:
            states = sup.heartbeat_once()
            assert list(states.values()) == [HEALTHY]
            states = sup.heartbeat_once()  # seq advanced: still healthy
            assert list(states.values()) == [HEALTHY]
            st = sup.stats()
            assert st["heartbeats"] == 2 and st["scrape_failures"] == 0
            json.dumps(st)

    def test_connect_places_and_registers_binding(self):
        with _fleet(1) as sup:
            s = sup.connect(name="app")
            (name,) = sup.engines
            assert sup.clients_of(name) == [s]
            s.close()
            sup.heartbeat_once()  # beats prune stopped clients
            assert sup.clients_of(name) == []

    def test_dead_engine_refused_for_admission(self):
        with _fleet(1) as sup:
            (name,) = sup.engines
            sup.slot(name).health.force_dead()
            with pytest.raises(RuntimeError, match="dead|no live engine"):
                sup.connect(engine=name)
            with pytest.raises(RuntimeError, match="no live engine"):
                sup.connect()

    def test_background_heartbeat_thread_runs(self):
        with _fleet(1, heartbeat_interval=0.05) as sup:
            sup.start()
            deadline = time.monotonic() + 10
            while sup.heartbeats < 3 and time.monotonic() < deadline:
                time.sleep(0.02)
            sup.stop()
            assert sup.heartbeats >= 3
            assert sup.scrape_failures == 0


# ---------------------------------------------------------------------------
# chaos: death detection, drain, lineage-replay recovery (tier2 — CI chaos lane)
# ---------------------------------------------------------------------------


@pytest.mark.tier2
class TestKillRecovery:
    def _pipeline(self, s, a, b):
        # Every send is an input of the pre-kill collect so that all content
        # is resident (hence host-recoverable) when the engine dies.
        la, lb = s.send(a), s.send(b)
        lc = s.run("el", "gemm", la, lb)
        ld = s.run("el", "gemm", lc, lb)
        return la, lb, lc, ld

    def test_kill_mid_pipeline_replays_bit_identical(self, rng):
        a = rng.standard_normal((48, 32)).astype(np.float32)
        b = rng.standard_normal((32, 32)).astype(np.float32)
        # control: the same pipeline on an unkilled fleet
        with _fleet(1) as ctrl_sup:
            ctrl = ctrl_sup.connect(name="ctrl")
            ctrl.register_library("el", ELEMENTAL)
            *_, ld = self._pipeline(ctrl, a, b)
            ref = np.asarray(ctrl.collect(ld))
            ctrl.close()
        with _fleet(2) as sup:
            victim_slot = list(sup.engines)[0]
            s = sup.connect(name="victim", engine=victim_slot)
            s.register_library("el", ELEMENTAL)
            la, lb, lc, ld = self._pipeline(s, a, b)
            np.asarray(s.collect(lc))  # materialize a prefix pre-kill
            recs = sup.kill(victim_slot)
            assert len(recs) == 1
            out = np.asarray(s.collect(ld))  # forces replay on the survivor
            np.testing.assert_array_equal(out, ref)
            # refills attach by content key: zero bridge re-sends
            stats = s.stats.summary()
            assert stats["send_bytes"] == 0
            assert stats["cross_session_reuses"] >= 1
            # replay is bounded by the lost suffix, analytically
            rec = recs[0]
            sup.recovery.account_replay(rec, [la, lb, lc, ld], s.planner)
            lost_bytes = suffix_bytes([la, lb, lc, ld], rec.lost_ids)
            assert 0 < rec.replayed_bytes <= lost_bytes
            s.close()

    def test_heartbeat_detects_silent_death_and_recovers(self, rng):
        """No chaos hook: the server is stopped out from under the
        supervisor; consecutive scrape misses must classify the engine dead
        and trigger the same drain/recover path."""
        a = rng.standard_normal((16, 16)).astype(np.float32)
        with _fleet(2, health_policy=HealthPolicy(miss_threshold=2)) as sup:
            victim = list(sup.engines)[0]
            s = sup.connect(name="app", engine=victim)
            s.register_library("el", ELEMENTAL)
            x = s.run("el", "gemm", s.send(a), s.send(a))
            ref = np.asarray(s.collect(x))
            sup.slot(victim).server.stop()  # silent death
            for _ in range(3):
                sup.heartbeat_once()
            assert sup.slot(victim).state == DEAD
            assert sup.recovery.recovered_sessions == 1
            out = np.asarray(s.collect(x))
            np.testing.assert_array_equal(out, ref)
            s.close()

    def test_recovery_target_grows_from_spares_when_no_survivor(self, rng):
        a = rng.standard_normal((8, 8)).astype(np.float32)
        devs = list(jax.devices()) * 2
        with FleetSupervisor(devices=devs, engines=1, devices_per_engine=1) as sup:
            (victim,) = list(sup.engines)
            s = sup.connect(name="app")
            s.register_library("el", ELEMENTAL)
            x = s.run("el", "gemm", s.send(a), s.send(a))
            ref = np.asarray(s.collect(x))
            recs = sup.kill(victim)  # only engine dies: must scale up
            assert len(recs) == 1 and sup.scale_ups == 1
            np.testing.assert_array_equal(np.asarray(s.collect(x)), ref)
            s.close()

    def test_tcp_client_fails_over_to_survivor_server(self, rng):
        """A TCP-transport client is re-pointed at the survivor's server."""
        from repro.serve.wire import TcpTransport, server_for

        a = rng.standard_normal((8, 8)).astype(np.float32)
        with _fleet(2) as sup:
            victim = list(sup.engines)[0]
            vslot = sup.slot(victim)
            s = sup.connect(
                name="app", engine=victim, transport=TcpTransport(vslot.server)
            )
            s.register_library("el", ELEMENTAL)
            x = s.run("el", "gemm", s.send(a), s.send(a))
            ref = np.asarray(s.collect(x))
            sup.kill(victim)
            assert isinstance(s.transport, TcpTransport)
            survivor = s.engine
            assert s.transport.server is server_for(survivor)
            np.testing.assert_array_equal(np.asarray(s.collect(x)), ref)
            s.close()


@pytest.mark.tier2
class TestAutoscale:
    def test_pressure_triggers_scale_up_from_spares(self):
        devs = (list(jax.devices()) * 3)[:3]
        with FleetSupervisor(
            devices=devs, engines=2, devices_per_engine=1,
            autoscale=AutoscalePolicy(pressure_high=0.8, idle_beats=10**6),
        ) as sup:
            assert sup.stats()["spare_devices"] == 1
            for slot in sup.engines.values():
                slot.health.pressure = 0.9  # as observed by the last beat
            sup._autoscale_once()
            assert sup.scale_ups == 1
            assert len(sup.engines) == 3
            assert sup.stats()["spare_devices"] == 0

    def test_idle_engines_shrink_back_to_spares(self):
        devs = (list(jax.devices()) * 2)[:2]
        with FleetSupervisor(
            devices=devs, engines=2, devices_per_engine=1,
            autoscale=AutoscalePolicy(min_engines=1, idle_beats=2),
        ) as sup:
            for _ in range(4):
                sup.heartbeat_once()
            assert sup.scale_downs >= 1
            assert len(sup.engines) == 1  # never below min_engines
            assert sup.stats()["spare_devices"] == 1

    def test_scale_down_refuses_busy_engine(self):
        with _fleet(1) as sup:
            (name,) = sup.engines
            s = sup.connect(name="busy")
            assert not sup.scale_down(name)
            s.close()
