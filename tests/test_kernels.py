"""Per-kernel allclose sweeps: Pallas bodies (interpret mode) vs jnp oracles,
across shapes and dtypes, plus hypothesis property tests of the oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul, vmem_bytes
from repro.kernels.ssd_scan import ssd_scan


# ---------------------------------------------------------------------------
# Tiled matmul
# ---------------------------------------------------------------------------

MATMUL_SHAPES = [
    (16, 16, 16),
    (100, 70, 50),     # ragged: exercises padding
    (128, 256, 64),
    (33, 129, 65),
    (1, 64, 1),
]


@pytest.mark.parametrize("m,k,n", MATMUL_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_vs_oracle(m, k, n, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(m * 31 + n))
    a = jax.random.normal(ka, (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(kb, (k, n), jnp.float32).astype(dtype)
    got = matmul(a, b, bm=32, bn=32, bk=32, interpret=True)
    want = ref.matmul(a, b)
    # f32 tolerance scales with contraction depth: the blocked kernel and the
    # oracle accumulate in different orders (observed ~5e-5 at k=256)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("blocks", [(16, 16, 16), (32, 16, 64), (64, 64, 32)])
def test_matmul_block_shape_sweep(blocks):
    bm, bn, bk = blocks
    a = jax.random.normal(jax.random.PRNGKey(0), (96, 80))
    b = jax.random.normal(jax.random.PRNGKey(1), (80, 112))
    got = matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), atol=1e-4)


def test_matmul_rejects_bad_shapes():
    a = jnp.zeros((4, 5))
    with pytest.raises(ValueError):
        matmul(a, jnp.zeros((6, 4)), interpret=True)
    with pytest.raises(ValueError):
        matmul(jnp.zeros(4), jnp.zeros((4, 4)), interpret=True)


def test_vmem_estimate_default_blocks_fit():
    # default production tiles must fit v5e VMEM (128 MiB) comfortably
    assert vmem_bytes(512, 512, 512, jnp.bfloat16) < 16 * 2**20


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    dict(b=2, hq=4, hkv=4, lq=64, lk=64, d=32),            # MHA
    dict(b=1, hq=8, hkv=2, lq=64, lk=64, d=16),            # GQA 4:1
    dict(b=2, hq=4, hkv=1, lq=32, lk=128, d=32),           # MQA, cross-len
    dict(b=1, hq=2, hkv=2, lq=1, lk=64, d=64),             # decode-like
]


def _qkv(case, dtype=jnp.float32):
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(keys[0], (case["b"], case["hq"], case["lq"], case["d"]), dtype)
    k = jax.random.normal(keys[1], (case["b"], case["hkv"], case["lk"], case["d"]), dtype)
    v = jax.random.normal(keys[2], (case["b"], case["hkv"], case["lk"], case["d"]), dtype)
    return q, k, v


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize(
    "mask", [dict(), dict(causal=True), dict(causal=True, window=16), dict(window=9)]
)
def test_flash_kernel_vs_oracle(case, mask):
    if case["lq"] < 2 and mask.get("causal"):
        mask = dict(mask, q_offset=case["lk"] - 1)
    q, k, v = _qkv(case)
    got = flash_attention(q, k, v, bq=16, bk=16, interpret=True, **mask)
    want = ref.attention(q, k, v, **mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5), (jnp.bfloat16, 3e-2)])
def test_flash_dtypes(dtype, tol):
    q, k, v = _qkv(ATTN_CASES[0], dtype)
    got = flash_attention(q, k, v, causal=True, bq=16, bk=16, interpret=True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


def test_chunked_attention_matches_reference():
    q, k, v = _qkv(dict(b=2, hq=4, hkv=2, lq=256, lk=256, d=16))
    got = ref.attention_chunked(q, k, v, causal=True, q_chunk=32)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_chunked_attention_grad_matches():
    q, k, v = _qkv(dict(b=1, hq=2, hkv=2, lq=128, lk=128, d=16))

    def f_chunk(q):
        return ref.attention_chunked(q, k, v, causal=True, q_chunk=32).sum()

    def f_ref(q):
        return ref.attention(q, k, v, causal=True).sum()

    g1 = jax.grad(f_chunk)(q)
    g2 = jax.grad(f_ref)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=2e-4)


@given(
    b=st.integers(1, 2),
    hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    lq=st.sampled_from([8, 16, 32]),
    d=st.sampled_from([8, 16]),
)
@settings(max_examples=30, deadline=None)
def test_attention_softmax_rows_sum_to_one_property(b, hkv, group, lq, d):
    """Oracle invariant: output is a convex combination of V rows, so with
    V == const c the output must be exactly c everywhere (unmasked rows)."""
    keys = jax.random.split(jax.random.PRNGKey(b * 100 + lq), 2)
    q = jax.random.normal(keys[0], (b, hkv * group, lq, d))
    k = jax.random.normal(keys[1], (b, hkv, lq, d))
    v = jnp.full((b, hkv, lq, d), 3.25)
    out = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), 3.25, atol=1e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    dict(b=2, l=64, h=4, p=8, g=2, n=16, chunk=16),
    dict(b=1, l=128, h=2, p=16, g=1, n=8, chunk=32),
    dict(b=2, l=96, h=6, p=8, g=3, n=4, chunk=32),   # chunk not dividing? 96/32=3 ok
]


def _ssd_inputs(case, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (case["b"], case["l"], case["h"], case["p"]))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (case["b"], case["l"], case["h"])))
    a = -jnp.exp(jax.random.normal(ks[2], (case["h"],)))
    bm = jax.random.normal(ks[3], (case["b"], case["l"], case["g"], case["n"])) * 0.3
    cm = jax.random.normal(ks[4], (case["b"], case["l"], case["g"], case["n"])) * 0.3
    return x, dt, a, bm, cm


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_vs_sequential_oracle(case):
    x, dt, a, bm, cm = _ssd_inputs(case)
    y_k, h_k = ssd_scan(x, dt, a, bm, cm, chunk=case["chunk"], interpret=True)
    y_r, h_r = ref.ssd_scan(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=5e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=5e-4)


@pytest.mark.parametrize("case", SSD_CASES[:2])
def test_ssd_kernel_with_initial_state(case):
    x, dt, a, bm, cm = _ssd_inputs(case, seed=3)
    h0 = (
        jax.random.normal(jax.random.PRNGKey(9), (case["b"], case["h"], case["p"], case["n"]))
        * 0.5
    )
    y_k, h_k = ssd_scan(x, dt, a, bm, cm, init_state=h0, chunk=case["chunk"], interpret=True)
    y_r, h_r = ref.ssd_scan(x, dt, a, bm, cm, init_state=h0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=5e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=5e-4)


def test_ssd_chunked_oracle_matches_sequential():
    case = SSD_CASES[0]
    x, dt, a, bm, cm = _ssd_inputs(case, seed=5)
    y_c, h_c = ref.ssd_chunked(x, dt, a, bm, cm, chunk=16)
    y_r, h_r = ref.ssd_scan(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), atol=5e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_r), atol=5e-4)


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunking (associativity of the
    state-passing) — the core invariant of the duality."""
    case = dict(b=1, l=64, h=2, p=4, g=1, n=8)
    x, dt, a, bm, cm = _ssd_inputs(case, seed=11)
    outs = [
        np.asarray(ref.ssd_chunked(x, dt, a, bm, cm, chunk=c)[0])
        for c in (8, 16, 32, 64)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=5e-4)


@given(decay=st.floats(0.05, 3.0), steps=st.integers(2, 16))
@settings(max_examples=25, deadline=None)
def test_ssd_state_decay_property(decay, steps):
    """With zero input, the state must decay exactly by exp(sum dt * a)."""
    b, h, p, n = 1, 2, 4, 8
    x = jnp.zeros((b, steps, h, p))
    dt = jnp.full((b, steps, h), decay)
    a = -jnp.ones((h,))
    bm = jnp.zeros((b, steps, 1, n))
    cm = jnp.zeros((b, steps, 1, n))
    h0 = jnp.ones((b, h, p, n))
    _, h_T = ref.ssd_scan(x, dt, a, bm, cm, init_state=h0)
    expected = np.exp(-decay * steps)
    np.testing.assert_allclose(np.asarray(h_T), expected, rtol=1e-4)
