"""Asynchronous task-queue engine tests: AlFuture, TaskQueue, the async ACI
(send_async/run_async/collect_async/wait), handle lifecycle states, task
failure propagation, and the relayout plan cache.

Single-device here; genuine cross-session overlap on disjoint worker groups
is measured in tests/multidevice/_concurrent_script.py. The tier2-marked
soak/stress classes at the bottom (session churn with injected failures,
leak checks) run in CI's dedicated step but are excluded from the tier-1
fast gate (pytest.ini).
"""

import threading
import time

import numpy as np
import pytest

import repro
from repro.core.errors import (
    HandleError,
    LibraryError,
    ParameterError,
    SessionError,
    TaskError,
)
from repro.core.futures import AlFuture, resolve, resolve_tree
from repro.core.handles import FAILED, FREED, MATERIALIZED
from repro.core.taskqueue import TaskQueue


@pytest.fixture()
def engine():
    return repro.AlchemistEngine()


@pytest.fixture()
def ac(engine):
    ctx = repro.AlchemistContext(engine, num_workers=1, name="async_app")
    ctx.register_library("elemental", "repro.linalg.library:ElementalLib")
    yield ctx
    ctx.stop()


# ---------------------------------------------------------------------------
# AlFuture
# ---------------------------------------------------------------------------

class TestAlFuture:
    def test_result_blocks_until_set(self):
        f = AlFuture("x")
        assert not f.done()
        threading.Timer(0.05, lambda: f._set_result(41)).start()
        assert f.result(timeout=5) == 41
        assert f.done() and f.state == "resolved"

    def test_exception_reraised_from_result(self):
        f = AlFuture("boom")
        f._set_exception(ValueError("boom"))
        with pytest.raises(ValueError, match="boom"):
            f.result()
        assert isinstance(f.exception(), ValueError)

    def test_timeout_raises_taskerror(self):
        f = AlFuture("never")
        with pytest.raises(TaskError):
            f.result(timeout=0.01)

    def test_double_resolution_rejected(self):
        f = AlFuture()
        f._set_result(1)
        with pytest.raises(TaskError):
            f._set_result(2)

    def test_done_callback_runs_on_resolution(self):
        f = AlFuture()
        seen = []
        f.add_done_callback(lambda fut: seen.append(fut.result()))
        f._set_result("v")
        assert seen == ["v"]
        # late registration fires immediately
        f.add_done_callback(lambda fut: seen.append("late"))
        assert seen == ["v", "late"]

    def test_resolve_helpers(self):
        f = AlFuture()
        f._set_result(7)
        assert resolve(f) == 7
        assert resolve(7) == 7
        g = AlFuture()
        g._set_result([f, 2, {"k": f}])
        assert resolve_tree(g) == [7, 2, {"k": 7}]


# ---------------------------------------------------------------------------
# TaskQueue
# ---------------------------------------------------------------------------

class TestTaskQueue:
    def test_fifo_ordering(self):
        q = TaskQueue("t")
        order = []
        futs = [q.submit(lambda i=i: order.append(i) or i) for i in range(20)]
        assert [f.result(5) for f in futs] == list(range(20))
        assert order == list(range(20))
        q.close()

    def test_failure_is_isolated_to_its_future(self):
        q = TaskQueue("t")

        def bad():
            raise RuntimeError("task died")

        f1 = q.submit(bad)
        f2 = q.submit(lambda: "fine")
        with pytest.raises(RuntimeError, match="task died"):
            f1.result(5)
        assert f2.result(5) == "fine"
        stats = q.stats()
        assert (stats["submitted"], stats["completed"], stats["failed"]) == (2, 1, 1)
        assert 0 <= stats["max_backlog"] <= 2  # racy: worker may drain eagerly
        q.close()

    def test_barrier_waits_for_all(self):
        q = TaskQueue("t")
        done = []
        q.submit(lambda: (time.sleep(0.05), done.append(1)))
        q.submit(lambda: done.append(2))
        q.barrier(timeout=10)
        assert done == [1, 2]
        q.close()

    def test_submit_after_close_rejected(self):
        q = TaskQueue("t")
        q.submit(lambda: None).result(5)
        q.close()
        with pytest.raises(TaskError):
            q.submit(lambda: None)
        q.close()  # idempotent

    def test_close_drains_queued_tasks(self):
        q = TaskQueue("t")
        futs = [q.submit(lambda i=i: i) for i in range(5)]
        q.close(wait=True)
        assert [f.result(5) for f in futs] == list(range(5))


# ---------------------------------------------------------------------------
# Async ACI
# ---------------------------------------------------------------------------

class TestAsyncContext:
    def test_send_async_roundtrip(self, ac, rng):
        a = rng.standard_normal((37, 19)).astype(np.float32)
        f = ac.send_async(a, name="A")
        assert isinstance(f, repro.AlFuture)
        h = f.result(30)
        assert h.shape == (37, 19) and h.name == "A"
        np.testing.assert_allclose(np.asarray(ac.collect(h)), a, rtol=1e-6)

    def test_futures_chain_without_waiting(self, ac, rng):
        a = rng.standard_normal((24, 16)).astype(np.float32)
        b = rng.standard_normal((16, 8)).astype(np.float32)
        fa = ac.send_async(a)
        fb = ac.send_async(b)
        fc = ac.run_async("elemental", "gemm", fa, fb)
        fd = ac.collect_async(fc)
        np.testing.assert_allclose(np.asarray(fd.result(60)), a @ b, atol=1e-4)

    def test_sync_api_unchanged_on_top_of_queue(self, ac, rng):
        # the original paper-listing flow, now riding the task queue
        a = rng.standard_normal((16, 16)).astype(np.float32)
        ha = ac.send(a)
        hc = ac.run("elemental", "gemm", ha, ha)
        np.testing.assert_allclose(np.asarray(ac.collect(hc)), a @ a, atol=1e-3)
        s = ac.stats.summary()
        assert s["num_sends"] == 1 and s["num_receives"] == 1 and s["num_runs"] == 1

    def test_pending_handle_states(self, ac, rng):
        a = rng.standard_normal((64, 32)).astype(np.float32)
        f = ac.send_async(a)
        h = f.result(30)
        assert h.state == MATERIALIZED
        ac.free(h)
        assert h.state == FREED
        with pytest.raises(HandleError):
            ac.collect(h)

    def test_metadata_available_before_materialization(self, ac, rng):
        # shape/dtype are known at submit time — the AlMatrix proxy contract
        a = rng.standard_normal((128, 8)).astype(np.float32)
        f = ac.send_async(a, name="meta")
        h = f.result(30)
        assert h.num_rows == 128 and h.num_cols == 8
        assert h.nbytes() == a.nbytes

    def test_run_async_failure_propagates(self, ac, rng):
        ha = ac.send(rng.standard_normal((8, 8)).astype(np.float32))
        f = ac.run_async("elemental", "gemm", ha, object())
        with pytest.raises(ParameterError):
            f.result(30)
        # queue survives the failure
        np.testing.assert_allclose(
            np.asarray(ac.collect(ha)).shape, (8, 8)
        )

    def test_failed_send_marks_handle_failed(self, ac, monkeypatch):
        import repro.core.client as client_mod

        def boom(*a, **k):
            raise RuntimeError("transfer died")

        monkeypatch.setattr(client_mod, "timed_relayout", boom)
        f = ac.send_async(np.zeros((4, 4), dtype=np.float32))
        with pytest.raises(RuntimeError, match="transfer died"):
            f.result(30)
        # the eagerly-created handle carries the failure too
        h = ac.session.handles[max(ac.session.handles)]
        assert h.state == FAILED
        with pytest.raises(TaskError):
            h.data()

    def test_collect_freed_handle_fails_in_future(self, ac, rng):
        h = ac.send(rng.standard_normal((4, 4)).astype(np.float32))
        ac.free(h)
        assert h.state == FREED
        with pytest.raises(HandleError):
            ac.collect_async(h).result(30)

    def test_unknown_routine_fails_fast(self, ac):
        with pytest.raises(LibraryError):
            ac.run_async("elemental", "not_a_routine")
        with pytest.raises(LibraryError):
            ac.run_async("nope", "gemm")

    def test_wait_is_a_barrier(self, ac, rng):
        a = rng.standard_normal((32, 32)).astype(np.float32)
        futs = [ac.run_async("elemental", "gemm", ac.send_async(a), ac.send_async(a))
                for _ in range(3)]
        ac.wait(timeout=120)
        assert all(f.done() for f in futs)
        assert ac.stats.num_runs == 3

    def test_stop_drains_queue(self, engine, rng):
        ac = repro.AlchemistContext(engine, num_workers=1)
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        a = rng.standard_normal((16, 16)).astype(np.float32)
        f = ac.run_async("elemental", "gemm", ac.send_async(a), ac.send_async(a))
        ac.stop()
        assert f.done()  # queued work resolved before release
        assert engine.available_workers == engine.num_workers
        with pytest.raises(SessionError):
            ac.send(a)

    def test_async_error_does_not_block_stop(self, engine):
        ac = repro.AlchemistContext(engine, num_workers=1)
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        f = ac.run_async("elemental", "gemm", 1.0, 2.0)  # scalars: routine error
        ac.stop()
        assert f.exception() is not None


# ---------------------------------------------------------------------------
# Relayout plan cache
# ---------------------------------------------------------------------------

class TestRelayoutPlanCache:
    def test_repeat_sends_hit_cache(self, ac, rng):
        a = rng.standard_normal((64, 16)).astype(np.float32)
        ac.send(a)
        ac.send(a + 1)
        ac.send(a * 2)
        s = ac.stats.summary()
        assert s["relayout_cache_hits"] == 2
        assert s["relayout_cache_misses"] == 1

    def test_repeat_collects_hit_cache(self, ac, rng):
        a = rng.standard_normal((32, 8)).astype(np.float32)
        h1, h2 = ac.send(a), ac.send(a)
        ac.collect(h1)
        ac.collect(h2)
        s = ac.stats.summary()
        # sends: 1 miss + 1 hit; collects (reverse direction): 1 miss + 1 hit
        assert s["relayout_cache_hits"] == 2
        assert s["relayout_cache_misses"] == 2

    def test_distinct_shapes_or_dtypes_miss(self, ac, rng):
        ac.send(rng.standard_normal((16, 4)).astype(np.float32))
        ac.send(rng.standard_normal((16, 8)).astype(np.float32))
        ac.send(rng.standard_normal((16, 4)).astype(np.float16))
        assert ac.stats.relayout_cache_hits == 0
        assert ac.stats.relayout_cache_misses == 3

    def test_cached_relayout_is_correct(self, ac, rng):
        for _ in range(3):
            a = rng.standard_normal((41, 13)).astype(np.float32)
            np.testing.assert_allclose(np.asarray(ac.collect(ac.send(a))), a, rtol=1e-6)

    def test_cache_is_session_scoped(self, engine, rng):
        # Distinct payloads per session: equal bytes would attach through the
        # engine's resident store (DESIGN.md §8) and never consult the plan
        # cache via the send path at all.
        a = rng.standard_normal((16, 16)).astype(np.float32)
        ac1 = repro.AlchemistContext(engine, num_workers=1)
        ac1.send(a)
        ac1.stop()
        ac2 = repro.AlchemistContext(engine, num_workers=1)
        ac2.send(a + 1.0)
        assert ac2.stats.relayout_cache_misses == 1  # fresh cache, no hit
        ac2.stop()


# ---------------------------------------------------------------------------
# Device-pool ordering (regression: release used to fragment the pool)
# ---------------------------------------------------------------------------

class _FakeDevice:
    def __init__(self, i):
        self.id = i

    def __repr__(self):
        return f"dev{self.id}"


class _FakeSession:
    _next = iter(range(10_000, 20_000))

    def __init__(self, devs):
        self.id = next(self._next)
        self.worker_devices = devs

    def close(self):
        pass


class TestPoolOrdering:
    def _engine(self, n=8):
        return repro.AlchemistEngine(devices=[_FakeDevice(i) for i in range(n)])

    def _take(self, eng, k):
        """Allocation bookkeeping only (no Mesh — fake devices)."""
        from repro.core.scheduler import PlacementRequest

        ticket = eng.scheduler.submit(PlacementRequest(workers=k, deadline=0))
        s = _FakeSession(ticket.devices)
        eng.scheduler.bind(ticket, s.id)
        eng.sessions[s.id] = s
        return s

    def test_release_restores_canonical_order(self):
        eng = self._engine()
        s1 = self._take(eng, 2)   # devs 0-1
        s2 = self._take(eng, 3)   # devs 2-4
        s3 = self._take(eng, 3)   # devs 5-7
        # release out of allocation order
        eng.release(s2)
        eng.release(s1)
        eng.release(s3)
        assert [d.id for d in eng._free] == list(range(8))

    def test_next_allocation_gets_contiguous_prefix(self):
        eng = self._engine()
        s1 = self._take(eng, 4)
        s2 = self._take(eng, 4)
        eng.release(s1)           # devs 0-3 come back while 4-7 are out
        assert [d.id for d in eng._free] == [0, 1, 2, 3]
        eng.release(s2)
        s3 = self._take(eng, 8)
        assert [d.id for d in s3.worker_devices] == list(range(8))

    def test_interleaved_churn_never_scrambles(self):
        eng = self._engine()
        live = []
        rng = np.random.default_rng(0)
        for step in range(30):
            if live and (len(live) > 2 or rng.random() < 0.5):
                eng.release(live.pop(int(rng.integers(len(live)))))
            else:
                k = int(rng.integers(1, max(2, eng.available_workers)))
                if k <= eng.available_workers:
                    live.append(self._take(eng, k))
            ids = [d.id for d in eng._free]
            assert ids == sorted(ids), f"pool scrambled at step {step}: {ids}"
        for s in live:
            eng.release(s)
        assert [d.id for d in eng._free] == list(range(8))


# ---------------------------------------------------------------------------
# Soak / stress (tier2): many sessions churning with injected failures.
# The invariants under test: no leaked device-pool entries, no leaked
# handles, and a failed task never wedges the session's worker.
# ---------------------------------------------------------------------------

@pytest.mark.tier2
class TestTaskQueueSoak:
    def test_queue_survives_many_injected_failures(self):
        q = TaskQueue("soak")
        rng = np.random.default_rng(1)
        futs = []
        for i in range(300):
            if rng.random() < 0.3:
                def bad(i=i):
                    raise RuntimeError(f"injected-{i}")
                futs.append((q.submit(bad), True))
            else:
                futs.append((q.submit(lambda i=i: i), False))
        # every future resolves — failures isolated to their own future
        for f, should_fail in futs:
            assert (f.exception(timeout=30) is not None) == should_fail
        q.barrier(timeout=30)  # worker not wedged
        s = q.stats()
        assert s["submitted"] == 301  # 300 tasks + the barrier no-op
        assert s["completed"] + s["failed"] == s["submitted"]
        assert s["failed"] == sum(1 for _, bad in futs if bad)
        q.close(wait=True, timeout=30)
        assert not q._thread.is_alive()

    def test_failure_storm_keeps_fifo_order(self):
        q = TaskQueue("storm")
        order = []
        futs = []
        for i in range(100):
            if i % 3 == 0:
                def bad(i=i):
                    order.append(i)
                    raise ValueError(f"boom-{i}")
                futs.append(q.submit(bad))
            else:
                futs.append(q.submit(lambda i=i: order.append(i)))
        q.barrier(timeout=30)
        assert order == list(range(100))
        q.close(wait=True, timeout=30)


@pytest.mark.tier2
class TestSessionChurnSoak:
    """Sessions connecting/stopping under injected routine failures — the
    regression surface for leaked pool entries and wedged workers."""

    ROUNDS = 20

    def test_churn_with_injected_routine_failures(self, rng):
        engine = repro.AlchemistEngine()
        n_workers = engine.num_workers
        a = rng.standard_normal((16, 16)).astype(np.float32)
        bad_shape = rng.standard_normal((7, 16)).astype(np.float32)  # (16,16)@(7,16) mismatches
        sessions = []

        for i in range(self.ROUNDS):
            ac = repro.AlchemistContext(engine, num_workers=1, name=f"soak{i}")
            ac.register_library("elemental", "repro.linalg.library:ElementalLib")
            sessions.append(ac.session)
            futs, injected = [], []
            h = ac.send_async(a)
            futs.append(ac.run_async("elemental", "gemm", h, h))
            if i % 2 == 0:
                # injected failure: unpackable argument dies in the codec
                injected.append(ac.run_async("elemental", "gemm", h, object()))
            if i % 3 == 0:
                # injected failure: raises inside the queue worker itself
                injected.append(ac.session.tasks.submit(self._boom, label="injected"))
            if i % 4 == 0:
                # injected failure: shape mismatch inside the routine
                injected.append(
                    ac.run_async("elemental", "gemm", h, ac.send_async(bad_shape))
                )
            futs.append(ac.collect_async(futs[0]))
            ac.stop()

            # every future resolved (worker never wedged); good work
            # succeeded and every injected failure genuinely failed
            assert all(f.done() for f in futs + injected)
            assert all(f.exception() is None for f in futs)
            assert all(f.exception() is not None for f in injected)
            # no leaked device-pool entries, in canonical order
            assert engine.available_workers == n_workers
            assert engine._free == engine.devices
            assert ac.session.id not in engine.sessions
            # no leaked handles
            assert ac.session.closed and not ac.session.handles

        assert not engine.sessions
        # the pool is still fully allocatable after the churn
        ac = repro.AlchemistContext(engine, num_workers=n_workers, name="final")
        assert engine.available_workers == 0
        ac.stop()
        assert engine.available_workers == n_workers

    @staticmethod
    def _boom():
        raise RuntimeError("injected worker failure")

    def test_churn_with_planner_sessions(self, rng):
        """Planner-carrying sessions (resident caches holding handles) must
        release everything on stop too."""
        engine = repro.AlchemistEngine()
        n_workers = engine.num_workers
        a = rng.standard_normal((12, 12)).astype(np.float32)
        for i in range(8):
            ac = repro.AlchemistContext(engine, num_workers=1, name=f"plsoak{i}")
            ac.register_library("elemental", "repro.linalg.library:ElementalLib")
            pl = ac.planner
            lc = pl.run("elemental", "gemm", pl.send(a), pl.send(a.copy()))
            if i % 2 == 0:
                # failing DAG: the lowered future fails, the session must not
                pl.lower(pl.run("elemental", "gemm", pl.send(a), "nonsense"))
            np.testing.assert_allclose(np.asarray(pl.collect(lc)), a @ a, atol=1e-3)
            ac.stop()
            assert engine.available_workers == n_workers
            assert not ac.session.handles
        assert not engine.sessions
