"""Engine-level resident store tests (DESIGN.md §8): content-addressed
cross-session placement, refcounted pins, migration-on-close, the shared
engine-wide HBM budget, the shape-rule registration hook, and planner CSE.

Single-device here (sessions are sequential: close-migrate-attach is the
cross-session path exercised); concurrent multi-session semantics run on a
real worker-group mesh in tests/multidevice/ and benchmarks/cross_session.py,
and the tier2 stress below goes concurrent whenever the host exposes the
devices for it.
"""

import threading

import numpy as np
import pytest

import repro
from repro.core.errors import HandleError, LibraryError, ShapeError
from repro.core.expr import SHAPE_RULES, content_key, register_shape_rule
from repro.core.handles import AlMatrix, MATERIALIZED
from repro.core.layouts import GRID
from repro.core.registry import Library
from repro.core.resident import ResidentStore

MAT = 32 * 32 * 4  # bytes of one 32x32 float32


@pytest.fixture()
def engine():
    return repro.AlchemistEngine()


def _connect(engine, name="app", budget=None):
    ac = repro.AlchemistContext(engine, num_workers=1, name=name, hbm_budget=budget)
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")
    return ac


def _mats(n, rng, shape=(32, 32)):
    return [rng.standard_normal(shape).astype(np.float32) for _ in range(n)]


# ---------------------------------------------------------------------------
# Cross-session placement
# ---------------------------------------------------------------------------

class TestCrossSessionPlacement:
    def test_second_session_attaches_zero_bridge(self, engine, rng):
        a = _mats(1, rng)[0]
        ac1 = _connect(engine, "s1")
        ac1.send(a)
        ac1.stop()  # uniquely referenced: migrated, not freed
        assert engine.residents.stats()["migrations"] == 1

        ac2 = _connect(engine, "s2")
        h = ac2.send(a.copy())  # equal bytes, different ndarray
        np.testing.assert_array_equal(np.asarray(ac2.collect(h)), a)
        s2 = ac2.stats.summary()
        assert s2["num_sends"] == 0 and s2["send_bytes"] == 0
        assert s2["cross_session_reuses"] == 1
        ac2.stop()

    def test_planner_send_attaches_through_engine_index(self, engine, rng):
        a = _mats(1, rng)[0]
        ac1 = _connect(engine, "s1")
        np.testing.assert_array_equal(np.asarray(ac1.planner.collect(ac1.planner.send(a))), a)
        ac1.stop()

        ac2 = _connect(engine, "s2")
        out = np.asarray(ac2.planner.collect(ac2.planner.send(a)))
        np.testing.assert_array_equal(out, a)
        s2 = ac2.stats.summary()
        assert s2["num_sends"] == 0 and s2["cross_session_reuses"] == 1
        assert s2["resident_reuses"] == 0  # engine-level, not session-level
        ac2.stop()

    def test_spilled_then_migrated_content_refills_bit_exact(self, engine, rng):
        mats = _mats(3, rng)
        ac1 = _connect(engine, "s1", budget=MAT)  # 1-matrix budget: spills
        for m in mats:
            ac1.planner.lower(ac1.planner.send(m))
        ac1.wait()
        assert ac1.stats.spills >= 2
        ac1.stop()  # migration must stage the spilled payloads host-side

        ac2 = _connect(engine, "s2")
        for m in mats:
            h = ac2.send(m.copy())
            np.testing.assert_array_equal(np.asarray(ac2.collect(h)), m)
            # engine-side consumption exercises the refill path too
            norm = float(ac2.run("elemental", "normest", h))
            assert abs(norm - np.linalg.norm(m)) < 1e-3
        assert ac2.stats.num_sends == 0
        assert ac2.stats.cross_session_reuses == 3
        ac2.stop()

    def test_attach_survives_different_worker_group_shape(self, rng):
        # Content placed by a 1-worker session refills into a session whose
        # grid needs different divisibility padding.
        if len(repro.AlchemistEngine().devices) < 4:
            pytest.skip("needs 4 devices")
        engine = repro.AlchemistEngine()
        a = rng.standard_normal((6, 6)).astype(np.float32)  # pads on 4 workers
        ac1 = _connect(engine, "s1")
        ac1.send(a)
        ac1.stop()
        ac2 = repro.AlchemistContext(engine, num_workers=4, name="s2")
        np.testing.assert_array_equal(np.asarray(ac2.collect(ac2.send(a))), a)
        assert ac2.stats.cross_session_reuses == 1
        ac2.stop()

    def test_explicit_free_drops_entry_for_good(self, engine, rng):
        a = _mats(1, rng)[0]
        ac1 = _connect(engine, "s1")
        h = ac1.send(a)
        ac1.free(h)
        assert len(engine.residents) == 0  # user free != migration
        with pytest.raises(HandleError):
            ac1.collect(h)
        # a re-send is a genuine transfer again
        h2 = ac1.send(a)
        np.testing.assert_array_equal(np.asarray(ac1.collect(h2)), a)
        assert ac1.stats.num_sends == 2
        ac1.stop()

    def test_duplicate_eager_send_keeps_classic_semantics(self, engine, rng):
        # Within one session, eager sends stay independent full transfers
        # (the planner is the intra-session dedup layer): freeing one copy
        # must not kill the other.
        a = _mats(1, rng)[0]
        ac = _connect(engine)
        h1, h2 = ac.send(a), ac.send(a)
        assert h1.id != h2.id
        assert ac.stats.num_sends == 2
        ac.free(h1)
        np.testing.assert_array_equal(np.asarray(ac.collect(h2)), a)
        ac.stop()

    def test_share_residents_false_restores_baseline(self, rng):
        engine = repro.AlchemistEngine(share_residents=False)
        a = _mats(1, rng)[0]
        ac1 = _connect(engine, "s1")
        ac1.send(a)
        ac1.stop()
        ac2 = _connect(engine, "s2")
        ac2.send(a)
        s2 = ac2.stats.summary()
        assert s2["num_sends"] == 1 and s2["cross_session_reuses"] == 0
        assert len(engine.residents) == 0
        ac2.stop()

    def test_cyclic_layouts_bypass_store(self, engine, rng):
        a = _mats(1, rng, shape=(8, 8))[0]
        ac = repro.AlchemistContext(
            engine, num_workers=1, name="cyc", engine_layout=GRID.with_cyclic()
        )
        np.testing.assert_array_equal(np.asarray(ac.collect(ac.send(a))), a)
        assert len(engine.residents) == 0  # never published
        ac.stop()

    def test_attach_falls_back_to_send_when_content_vanishes(self, rng):
        # The attach decision and the attach task are separated by the queue:
        # if the producer's placement is freed in between (and no payload was
        # ever captured — eager sends publish none), the task must fall back
        # to a genuine bridge send of the caller's bytes, not hang on its own
        # pending placement and not fail the future.
        if len(repro.AlchemistEngine().devices) < 2:
            pytest.skip("needs 2 devices for two live sessions")
        import time

        engine = repro.AlchemistEngine()
        a = _mats(1, rng)[0]
        ac1 = _connect(engine, "s1")
        h1 = ac1.send(a)  # eager: entry has a live placement, no payload
        ac2 = _connect(engine, "s2")
        ac2.session.tasks.submit(lambda: time.sleep(0.3), label="stall")
        fut = ac2.send_async(a)  # attach decided now, runs after the stall
        ac1.free(h1)  # the only payload source dies before the task runs
        h2 = fut.result(30)
        np.testing.assert_array_equal(np.asarray(ac2.collect(h2)), a)
        s2 = ac2.stats.summary()
        assert s2["num_sends"] == 1 and s2["send_bytes"] == a.nbytes  # honest
        assert s2["cross_session_reuses"] == 0
        # the fallback republished the payload: a third session attaches
        ac1.stop()
        ac3 = _connect(engine, "s3")
        np.testing.assert_array_equal(np.asarray(ac3.collect(ac3.send(a))), a)
        assert ac3.stats.cross_session_reuses == 1
        ac3.stop()
        ac2.stop()

    def test_offloaded_override_restores_engine_base_budget(self, engine, rng):
        # Regression: offloaded() used to save the *effective* budget (which
        # folds in this session's own request) and restore it into the base —
        # permanently clamping the engine for every later session.
        from repro.sparklike import offload

        ac = _connect(engine, budget=2 * MAT)
        with offload.offloaded(ac):  # no hbm_budget arg: must not touch it
            pass
        with offload.offloaded(ac, hbm_budget=MAT):
            assert engine.memgov.budget == MAT
        assert engine.memgov.budget == 2 * MAT  # session request only
        ac.stop()
        assert engine.memgov.budget is None  # base never absorbed the request

    def test_engine_shutdown_clears_everything(self, engine, rng):
        ac = _connect(engine)
        ac.send(_mats(1, rng)[0])
        engine.shutdown()
        assert len(engine.residents) == 0
        assert engine.memgov.used == 0
        assert engine.available_workers == engine.num_workers

    def test_retention_cap_evicts_oldest_orphans(self, rng):
        engine = repro.AlchemistEngine(host_retention_bytes=2 * MAT)
        mats = _mats(4, rng)
        for i, m in enumerate(mats):
            ac = _connect(engine, f"s{i}")
            ac.send(m)
            ac.stop()  # each close migrates one entry
        s = engine.residents.stats()
        assert s["entries"] == 2 and s["evictions"] == 2
        # the newest content survived and still attaches
        ac = _connect(engine, "reader")
        np.testing.assert_array_equal(np.asarray(ac.collect(ac.send(mats[-1]))), mats[-1])
        assert ac.stats.cross_session_reuses == 1
        ac.stop()


# ---------------------------------------------------------------------------
# Refcount / pin mechanics on the store itself
# ---------------------------------------------------------------------------

class _StubSession:
    _ids = iter(range(50_000, 60_000))

    def __init__(self):
        self.id = next(self._ids)


def _stub_handle(sid, payload):
    return AlMatrix(
        shape=payload.shape,
        dtype=np.float32,
        layout=GRID,
        session_id=sid,
        _state=MATERIALIZED,
    )


class TestStoreMechanics:
    def test_refcount_and_session_pins(self):
        store = ResidentStore()
        payload = np.ones((4, 4), np.float32)
        key = content_key(payload)
        s1, s2 = _StubSession(), _StubSession()
        h1 = _stub_handle(s1.id, payload)
        h2 = _stub_handle(s2.id, payload)
        entry = store.register(key, h1, s1, payload=payload)
        store.register(key, h2, s2)
        assert entry.refcount == 2
        assert entry.sessions == tuple(sorted((s1.id, s2.id)))
        store.release(key, s1.id, h1)
        assert entry.refcount == 1 and entry.sessions == (s2.id,)
        # releasing the same placement twice is a no-op, never a double-free
        store.release(key, s1.id, h1)
        assert entry.refcount == 1
        store.release(key, s2.id, h2)
        assert len(store) == 0

    def test_register_is_idempotent_per_handle(self):
        store = ResidentStore()
        payload = np.ones((2, 2), np.float32)
        key = content_key(payload)
        s = _StubSession()
        h = _stub_handle(s.id, payload)
        store.register(key, h, s, payload=payload)
        store.register(key, h, s)
        assert store.lookup(key).refcount == 1

    def test_disabled_store_never_indexes(self):
        store = ResidentStore(enabled=False)
        payload = np.ones((2, 2), np.float32)
        key = content_key(payload)
        s = _StubSession()
        store.register(key, _stub_handle(s.id, payload), s, payload=payload)
        assert store.lookup(key) is None and len(store) == 0


# ---------------------------------------------------------------------------
# Shared engine-wide budget
# ---------------------------------------------------------------------------

class TestSharedBudget:
    def test_effective_budget_is_min_of_engine_and_session(self, rng):
        engine = repro.AlchemistEngine(hbm_budget=4 * MAT)
        assert engine.memgov.budget == 4 * MAT
        ac = _connect(engine, budget=2 * MAT)
        assert engine.memgov.budget == 2 * MAT  # session tightened the ceiling
        ac.stop()
        assert engine.memgov.budget == 4 * MAT  # request dropped with the session

    def test_engine_budget_spills_without_session_budget(self, rng):
        engine = repro.AlchemistEngine(hbm_budget=2 * MAT, share_residents=False)
        ac = _connect(engine)  # no per-session budget at all
        mats = _mats(4, rng)
        hs = [ac.send(m) for m in mats]
        ac.wait()
        s = ac.stats.summary()
        assert s["spills"] == 2
        assert s["hbm_high_water"] <= 2 * MAT
        assert engine.memgov.high_water <= 2 * MAT
        for m, h in zip(mats, hs):
            np.testing.assert_array_equal(np.asarray(ac.collect(h)), m)
        ac.stop()

    def test_invalid_session_budget_leaves_no_ghost_state(self, engine):
        # Regression: the governor used to register the session before
        # validating its budget, and connect() leaked the allocated devices.
        before = engine.available_workers
        with pytest.raises(ValueError):
            repro.AlchemistContext(engine, num_workers=1, hbm_budget=-5)
        assert engine.available_workers == before
        assert engine.memgov.snapshot()["sessions"] == 0

    def test_interleaved_offloaded_scopes_compose(self, rng):
        # Regression: per-session override requests replace a shared-base
        # save/restore that baked a stale budget into the engine when scopes
        # in two sessions closed out of LIFO order.
        from repro.sparklike import offload

        engine = repro.AlchemistEngine()
        if engine.num_workers < 2:
            pytest.skip("needs 2 devices for two live sessions")
        ac1, ac2 = _connect(engine, "s1"), _connect(engine, "s2")
        try:
            scope1 = offload.offloaded(ac1, hbm_budget=3 * MAT)
            scope2 = offload.offloaded(ac2, hbm_budget=4 * MAT)
            scope1.__enter__()
            scope2.__enter__()
            assert engine.memgov.budget == 3 * MAT  # min of both requests
            scope1.__exit__(None, None, None)  # non-LIFO on purpose
            assert engine.memgov.budget == 4 * MAT
            scope2.__exit__(None, None, None)
            assert engine.memgov.budget is None  # nothing baked in
        finally:
            offload.disable()
            ac1.stop()
            ac2.stop()

    def test_padded_store_refill_respects_budget(self, rng):
        # Regression: refill claimed the logical store-payload bytes but
        # charged the padded physical footprint, overshooting the budget by
        # the pad bytes without attempting a spill.
        engine = repro.AlchemistEngine()
        if engine.num_workers < 4:
            pytest.skip("needs 4 devices for a padding grid")
        from repro.core.handles import SPILLED

        budget = 232  # phys(7x6 -> 8x6x4 = 192) + 40: filler must be evicted
        ac = repro.AlchemistContext(engine, num_workers=4, name="pad", hbm_budget=budget)
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        pl = ac.planner
        a = rng.standard_normal((7, 6)).astype(np.float32)  # pads to 8x6
        ha = pl.materialize(pl.send(a))
        ac.wait()
        hf = pl.materialize(pl.send(rng.standard_normal((4, 3)).astype(np.float32)))
        ac.wait()  # filler's admission spilled the padded matrix
        assert ac.session.resolve(ha).state == SPILLED and hf.state != SPILLED
        norm = float(ac.run("elemental", "normest", ha))  # refill under budget
        assert abs(norm - np.linalg.norm(a)) < 1e-3
        assert ac.stats.hbm_high_water <= budget, ac.stats.summary()
        assert engine.memgov.high_water <= budget
        ac.stop()

    def test_sequential_sessions_share_one_ledger(self, rng):
        engine = repro.AlchemistEngine(hbm_budget=2 * MAT, share_residents=False)
        for i in range(3):
            ac = _connect(engine, f"s{i}")
            for m in _mats(3, rng):
                ac.send(m)
            ac.stop()
            assert engine.memgov.used == 0  # close discharged everything
        assert engine.memgov.high_water <= 2 * MAT


# ---------------------------------------------------------------------------
# Shape-rule registration hook (third-party libraries)
# ---------------------------------------------------------------------------

def _rule_double(shapes, params):
    a = shapes[0] if shapes else None
    if a is None:
        return (None,)
    return ((a[0], 2 * a[1]),)


class TestShapeRuleRegistration:
    def _lib(self, **register_kwargs):
        import jax.numpy as jnp

        def widen(x):
            return jnp.concatenate([x, x], axis=1)

        class ThirdParty(Library):
            name = "third"

            def __init__(self):
                super().__init__()
                self.register("widen", widen, **register_kwargs)

        return ThirdParty

    def test_register_with_rule_validates_and_prices(self, engine, rng):
        try:
            ac = _connect(engine)
            ac.register_library("third", self._lib(shape_rule=_rule_double))
            assert SHAPE_RULES["widen"] is _rule_double
            la = ac.planner.send(_mats(1, rng, shape=(8, 4))[0])
            out = ac.planner.run("third", "widen", la)
            assert out.shape == (8, 8)  # the rule drives graph-build inference
            ac.stop()
        finally:
            SHAPE_RULES.pop("widen", None)

    def test_register_without_rule_or_opt_out_rejected(self):
        with pytest.raises(LibraryError, match="shape rule"):
            self._lib()()

    def test_register_with_explicit_opt_out(self, engine, rng):
        ac = _connect(engine)
        ac.register_library("third", self._lib(unchecked_shapes=True))
        assert "widen" not in SHAPE_RULES
        a = _mats(1, rng, shape=(8, 4))[0]
        out = np.asarray(ac.collect(ac.run("third", "widen", ac.send(a))))
        np.testing.assert_array_equal(out, np.concatenate([a, a], axis=1))
        ac.stop()

    def test_builtin_routine_names_need_no_rule_argument(self):
        class Alias(Library):
            name = "alias"

            def __init__(self):
                super().__init__()
                self.register("gemm", lambda a, b: a @ b)  # rule already known

        assert "gemm" in Alias().routine_names()

    def test_library_with_inline_rule_reregisters_across_sessions(self, engine):
        # Regression: the conflict check compared rule identity, so a library
        # defining its rule inline (fresh function object per instantiation)
        # raised ShapeError on its second session's register_library.
        try:
            class Inline(Library):
                name = "inline"

                def __init__(self):
                    super().__init__()
                    self.register("twice", lambda x: x + x, shape_rule=lambda s, p: (s[0],))

            ac1 = _connect(engine, "s1")
            ac1.register_library("inline", Inline)
            ac1.stop()
            ac2 = _connect(engine, "s2")
            ac2.register_library("inline", Inline)  # fresh instance, same rule
            ac2.stop()
        finally:
            SHAPE_RULES.pop("twice", None)

    def test_conflicting_rule_rejected_unless_override(self):
        try:
            register_shape_rule("widen", _rule_double)
            with pytest.raises(ShapeError, match="already has a shape rule"):
                register_shape_rule("widen", lambda s, p: (None,))
            register_shape_rule("widen", lambda s, p: (None,), override=True)
        finally:
            SHAPE_RULES.pop("widen", None)

    def test_rule_must_be_callable(self):
        with pytest.raises(TypeError):
            register_shape_rule("nope", "not-a-rule")


# ---------------------------------------------------------------------------
# Planner common-subexpression elimination
# ---------------------------------------------------------------------------

class TestPlannerCSE:
    def test_identical_runs_memoize(self, engine, rng):
        ac = _connect(engine)
        pl = ac.planner
        la = pl.send(_mats(1, rng)[0])
        c1 = pl.run("elemental", "gemm", la, la)
        c2 = pl.run("elemental", "gemm", la, la)
        assert c2 is c1  # same LazyMatrix: the DAG holds one node
        assert ac.stats.cse_hits == 1
        pl.collect(c1)
        pl.collect(c2)
        assert ac.stats.planned_ops == 1  # lowered once
        ac.stop()

    def test_params_and_arity_distinguish(self, engine, rng):
        ac = _connect(engine)
        pl = ac.planner
        la = pl.send(_mats(1, rng, shape=(16, 8))[0])
        s1 = pl.run("elemental", "truncated_svd", la, n_outputs=3, k=4)
        s2 = pl.run("elemental", "truncated_svd", la, n_outputs=3, k=4)
        s3 = pl.run("elemental", "truncated_svd", la, n_outputs=3, k=2)
        assert s2 is s1 and s3 is not s1
        assert ac.stats.cse_hits == 1
        ac.stop()

    def test_distinct_nodes_with_equal_bytes_do_not_cse(self, engine, rng):
        # CSE keys on node identity: content dedup is the send layer's job,
        # so equal-byte sends stay distinct nodes and the runs over them
        # re-execute (matching the documented planner counters).
        ac = _connect(engine)
        pl = ac.planner
        a = _mats(1, rng)[0]
        c1 = pl.run("elemental", "gemm", pl.send(a), pl.send(a))
        c2 = pl.run("elemental", "gemm", pl.send(a), pl.send(a))
        assert c2 is not c1
        assert ac.stats.cse_hits == 0
        ac.stop()

    def test_opt_out(self, engine, rng):
        ac = _connect(engine)
        pl = ac.planner
        la = pl.send(_mats(1, rng)[0])
        c1 = pl.run("elemental", "gemm", la, la, cse=False)
        c2 = pl.run("elemental", "gemm", la, la, cse=False)
        assert c2 is not c1
        assert ac.stats.cse_hits == 0
        ac.stop()

    def test_freed_cse_result_reruns_transparently(self, engine, rng):
        ac = _connect(engine)
        pl = ac.planner
        a = _mats(1, rng)[0]
        la = pl.send(a)
        c1 = pl.run("elemental", "gemm", la, la)
        ac.free(pl.materialize(c1))
        c2 = pl.run("elemental", "gemm", la, la)  # CSE hit on a freed result
        assert c2 is c1
        np.testing.assert_allclose(np.asarray(pl.collect(c2)), a @ a, atol=1e-3)
        ac.stop()

    def test_ndarray_params_key_by_content_not_repr(self, engine, rng):
        # Regression: repr() truncates big ndarrays, so two different arrays
        # could collide into one memo entry. Content-keying disambiguates;
        # identity-equal content still memoizes.
        from repro.core.planner import _Uncacheable, _canon_params

        big1 = np.zeros(2048, np.float64)
        big2 = big1.copy()
        big2[1000] = 5.0  # differs only inside repr's "..." elision
        assert repr(big1) == repr(big2)
        assert _canon_params({"w": big1}) != _canon_params({"w": big2})
        assert _canon_params({"w": big1}) == _canon_params({"w": big1.copy()})
        with pytest.raises(_Uncacheable):
            _canon_params({"w": {1, 2}})  # no canonical identity: opt out

    def test_uncacheable_param_opts_out_of_cse(self, engine, rng):
        ac = _connect(engine)
        pl = ac.planner
        la = pl.send(_mats(1, rng)[0])
        c1 = pl.run("elemental", "gemm", la, la, weird={1, 2})
        c2 = pl.run("elemental", "gemm", la, la, weird={1, 2})
        assert c2 is not c1 and ac.stats.cse_hits == 0
        ac.stop()

    def test_summary_exposes_counters(self, engine):
        ac = _connect(engine)
        s = ac.stats.summary()
        assert s["cse_hits"] == 0 and s["cross_session_reuses"] == 0
        ac.stop()


# ---------------------------------------------------------------------------
# Soak / stress (tier2): refcount lifecycle under churn + injected failures
# ---------------------------------------------------------------------------

@pytest.mark.tier2
class TestResidentStoreStress:
    ROUNDS = 16
    CONTENT = 5

    def _verify_engine_clean(self, engine):
        snap = engine.memgov.snapshot()
        assert snap["used"] == 0, snap
        assert snap["resident_handles"] == 0 and snap["spilled_handles"] == 0, snap
        assert snap["host_store_bytes"] == 0, snap
        for info in engine.residents.snapshot().values():
            assert info["refcount"] == 0, info  # no pin survived its session
            assert info["payload"], info  # migrated content kept its bytes

    def test_churn_overlapping_content_never_leaks(self, rng):
        engine = repro.AlchemistEngine()
        payloads = _mats(self.CONTENT, rng)
        refs = [np.array(p) for p in payloads]

        for i in range(self.ROUNDS):
            budget = [None, 2 * MAT, MAT][i % 3]  # rotate spill pressure
            ac = _connect(engine, f"churn{i}", budget=budget)
            pl = ac.planner
            picks = rng.choice(self.CONTENT, size=3, replace=False)
            handles = {}
            for j in picks:
                if j % 2 == 0:
                    handles[j] = pl.materialize(pl.send(payloads[j]))
                else:
                    handles[j] = ac.send(payloads[j])
            # injected failures: codec garbage + a task raising in the worker
            bad = ac.run_async("elemental", "gemm", handles[picks[0]], object())
            boom = ac.session.tasks.submit(self._boom, label="injected")
            # engine-side consumption (may refill spilled placements) …
            for j in picks:
                norm = float(ac.run("elemental", "normest", handles[j]))
                assert abs(norm - np.linalg.norm(refs[j])) < 1e-3
            # … and bit-exact collects, wherever the bytes currently live
            for j in picks:
                np.testing.assert_array_equal(np.asarray(ac.collect(handles[j])), refs[j])
            if i % 4 == 0:  # explicit frees mixed into the churn
                ac.free(handles[picks[0]])
            assert bad.exception(timeout=30) is not None
            assert boom.exception(timeout=30) is not None
            ac.stop()
            assert engine.memgov.used == 0, f"round {i} leaked charges"

        self._verify_engine_clean(engine)
        # after all that churn the payloads in the store are still bit-exact
        ac = _connect(engine, "final")
        for p, ref in zip(payloads, refs):
            np.testing.assert_array_equal(np.asarray(ac.collect(ac.send(p))), ref)
        assert ac.stats.cross_session_reuses > 0
        ac.stop()
        engine.shutdown()
        assert len(engine.residents) == 0 and engine.memgov.used == 0

    def test_concurrent_sessions_share_and_churn(self, rng):
        engine = repro.AlchemistEngine(hbm_budget=6 * MAT)
        if engine.num_workers < 2:
            pytest.skip("needs 2 devices for concurrent sessions")
        payloads = _mats(self.CONTENT, rng)
        refs = [np.array(p) for p in payloads]
        errors = []

        def churn(tag):
            try:
                local = np.random.default_rng(hash(tag) % 2**32)
                for i in range(6):
                    ac = _connect(engine, f"{tag}{i}")
                    pl = ac.planner
                    picks = local.choice(self.CONTENT, size=2, replace=False)
                    for j in picks:
                        out = np.asarray(pl.collect(pl.send(payloads[j])))
                        np.testing.assert_array_equal(out, refs[j])
                    if i % 2 == 0:
                        with pytest.raises(Exception):
                            ac.run("elemental", "gemm", object(), object())
                    ac.stop()
            except BaseException as exc:  # surfaced after join
                errors.append((tag, exc))

        threads = [threading.Thread(target=churn, args=(t,)) for t in ("a", "b")]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors
        self._verify_engine_clean(engine)
        assert engine.memgov.high_water <= 6 * MAT

    @staticmethod
    def _boom():
        raise RuntimeError("injected worker failure")
