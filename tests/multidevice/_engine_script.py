"""Subprocess body: engine + linalg semantics on a real 2x4 device mesh.
Run by test_multidevice.py with XLA_FLAGS set for 8 host devices."""

import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import numpy as np
import jax
import jax.numpy as jnp

import repro
from repro.core.layouts import GRID, ROW
from repro.core.relayout import transfer_cost

assert len(jax.devices()) == 8

engine = repro.AlchemistEngine()

# --- concurrent sessions get disjoint worker groups (paper §2.4) ---------
ac1 = repro.AlchemistContext(engine, num_workers=4, name="app1")
ac2 = repro.AlchemistContext(engine, num_workers=4, name="app2")
d1 = {d.id for d in ac1.session.worker_devices}
d2 = {d.id for d in ac2.session.worker_devices}
assert d1.isdisjoint(d2), "worker groups overlap"
assert engine.available_workers == 0

rng = np.random.default_rng(0)
a = rng.standard_normal((128, 64)).astype(np.float32)
b = rng.standard_normal((64, 32)).astype(np.float32)

ac1.register_library("elemental", "repro.linalg.library:ElementalLib")
ac2.register_library("elemental", "repro.linalg.library:ElementalLib")

# both sessions compute independently and correctly
h1 = ac1.send(a)
h2 = ac2.send(a)
g1 = ac1.run("elemental", "gemm", h1, ac1.send(b))
g2 = ac2.run("elemental", "gemm", h2, ac2.send(b), schedule="allgather")
np.testing.assert_allclose(np.asarray(ac1.collect(g1)), a @ b, atol=1e-3)
np.testing.assert_allclose(np.asarray(ac2.collect(g2)), a @ b, atol=1e-3)

# engine-resident data is actually distributed over the session grid
live = ac1.session.resolve(h1).data()
n_shards = len({s.device.id for s in live.addressable_shards})
assert n_shards == 4, f"expected 4 shards, got {n_shards}"

# the analytic transfer model predicts real movement on this mesh
cost = transfer_cost((128, 64), "float32", ROW, GRID, ac1.mesh)
assert cost.bytes_moved > 0 and cost.messages > 0

# SVD on a worker group
u, s, v = ac1.run("elemental", "truncated_svd", h1, k=4)
s_ref = np.linalg.svd(a, compute_uv=False)[:4]
np.testing.assert_allclose(np.asarray(s), s_ref, rtol=0.05)

ac1.stop()
ac2.stop()
assert engine.available_workers == 8
print("MULTIDEVICE_ENGINE_OK")
