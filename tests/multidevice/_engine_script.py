"""Subprocess body: engine + linalg semantics on a real 2x4 device mesh.
Run by test_multidevice.py with XLA_FLAGS set for 8 host devices."""

import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import threading

import numpy as np
import jax

import repro
from repro.core.layouts import GRID, ROW
from repro.core.relayout import transfer_cost

assert len(jax.devices()) == 8

engine = repro.AlchemistEngine()

# --- concurrent sessions get disjoint worker groups (paper §2.4) ---------
ac1 = repro.AlchemistContext(engine, num_workers=4, name="app1")
ac2 = repro.AlchemistContext(engine, num_workers=4, name="app2")
d1 = {d.id for d in ac1.session.worker_devices}
d2 = {d.id for d in ac2.session.worker_devices}
assert d1.isdisjoint(d2), "worker groups overlap"
assert engine.available_workers == 0

rng = np.random.default_rng(0)
a = rng.standard_normal((128, 64)).astype(np.float32)
b = rng.standard_normal((64, 32)).astype(np.float32)

ac1.register_library("elemental", "repro.linalg.library:ElementalLib")
ac2.register_library("elemental", "repro.linalg.library:ElementalLib")

# both sessions compute independently and correctly
h1 = ac1.send(a)
h2 = ac2.send(a)
g1 = ac1.run("elemental", "gemm", h1, ac1.send(b))
g2 = ac2.run("elemental", "gemm", h2, ac2.send(b), schedule="allgather")
np.testing.assert_allclose(np.asarray(ac1.collect(g1)), a @ b, atol=1e-3)
np.testing.assert_allclose(np.asarray(ac2.collect(g2)), a @ b, atol=1e-3)

# engine-resident data is actually distributed over the session grid
live = ac1.session.resolve(h1).data()
n_shards = len({s.device.id for s in live.addressable_shards})
assert n_shards == 4, f"expected 4 shards, got {n_shards}"

# the analytic transfer model predicts real movement on this mesh
cost = transfer_cost((128, 64), "float32", ROW, GRID, ac1.mesh)
assert cost.bytes_moved > 0 and cost.messages > 0

# SVD on a worker group
u, s, v = ac1.run("elemental", "truncated_svd", h1, k=4)
s_ref = np.linalg.svd(a, compute_uv=False)[:4]
np.testing.assert_allclose(np.asarray(s), s_ref, rtol=0.05)

# TSQR on a 2x2 grid (regression: _flat_rank used jax.lax.axis_size, which
# jax 0.4.x lacks — multi-axis meshes crashed)
hq, hr = ac1.run("elemental", "tsqr", h1)
r_np = np.asarray(ac1.collect(hr))
np.testing.assert_allclose(r_np.T @ r_np, a.T @ a, atol=2e-2)

# lazy offload planner on a worker group (DESIGN.md §6): chained routines
# elide the bridge, equal sends dedup, numerics match the eager path above
pl = ac1.planner
lc = pl.run("elemental", "gemm", pl.send(a), pl.send(b))
lr = pl.run("elemental", "tsqr", lc, n_outputs=2)[1]        # elided: lc
# elided: lr
r2 = np.asarray(pl.collect(pl.run("elemental", "gemm", lr, np.eye(32, dtype=np.float32))))
np.testing.assert_allclose(r2.T @ r2, (a @ b).T @ (a @ b), rtol=1e-2)
lc2 = pl.run("elemental", "gemm", pl.send(a.copy()), pl.send(b.copy()))  # both dedup
assert isinstance(pl.materialize(lc2), repro.AlMatrix)
ps = ac1.stats.summary()
assert ps["elided_crossings"] >= 2, ps
assert ps["resident_reuses"] >= 2, ps

ac1.stop()
ac2.stop()
assert engine.available_workers == 8

# --- memory governor on a real worker group (DESIGN.md §7) ----------------
# Working set of 6 matrices against a 3-matrix HBM budget: the governor
# spills genuinely sharded resident arrays to host and refills them with
# identical bytes; high water stays bounded on the real mesh too.
mat_bytes = 128 * 64 * 4
ac3 = repro.AlchemistContext(engine, num_workers=4, name="gov", hbm_budget=3 * mat_bytes)
ac3.register_library("elemental", "repro.linalg.library:ElementalLib")
mats = [rng.standard_normal((128, 64)).astype(np.float32) for _ in range(6)]
handles = [ac3.send(m) for m in mats]
# collects of spilled matrices are served from the host store, bit-exactly
for m, h in zip(mats, handles):
    np.testing.assert_array_equal(np.asarray(ac3.collect(h)), m)
gs = ac3.stats.summary()
assert gs["spills"] > 0, gs
assert gs["hbm_high_water"] <= 3 * mat_bytes, gs
# engine-side consumption refills spilled matrices onto the real mesh
for m, h in zip(mats, handles):
    norm = float(ac3.run("elemental", "normest", h))
    assert abs(norm - np.linalg.norm(m)) < 1e-2
gs = ac3.stats.summary()
assert gs["refills"] > 0, gs
assert gs["hbm_high_water"] <= 3 * mat_bytes, gs
ac3.stop()
assert engine.available_workers == 8

# --- v2 admission-aware connect on a real mesh (DESIGN.md §9) -------------
# Content-affinity placement end-to-end: content X was last placed on the
# SECOND half of the device pool; a new session declaring X must be steered
# there (the canonical default pick would be devices 0-3), and its send of X
# must attach with zero bridge bytes.
aff_engine = repro.AlchemistEngine()
s_a = repro.connect(aff_engine, workers=4, name="aff_a")  # devices 0-3
s_b = repro.connect(aff_engine, workers=4, name="aff_b")  # devices 4-7
assert {d.id for d in s_b.session.worker_devices} == {4, 5, 6, 7}
x_payload = rng.standard_normal((64, 32)).astype(np.float32)
s_b.send(x_payload, name="X").materialize()  # placed (and published) on 4-7
s_a.close()
s_b.close()  # uniquely-referenced content migrates host-side, keyed by X
assert aff_engine.available_workers == 8
s_c = repro.connect(
    aff_engine,
    name="aff_c",
    placement=repro.PlacementRequest(workers=4, affinity=(x_payload,)),
)
assert {d.id for d in s_c.session.worker_devices} == {4, 5, 6, 7}, (
    "content affinity should pick the reuse-bearing group"
)
assert aff_engine.admissions["affinity_hits"] == 1
with s_c.policy("eager"):
    s_c.send(x_payload, name="X")
summ = s_c.stats.summary()
assert summ["cross_session_reuses"] == 1 and summ["send_bytes"] == 0, summ

# Queued admission under real contention: a connect for the whole pool waits
# for the running session instead of failing, then is placed.
threading.Timer(0.3, s_c.close).start()
s_d = repro.connect(
    aff_engine, name="aff_d", placement=repro.PlacementRequest(workers=8, deadline=60)
)
assert aff_engine.admissions["queued"] == 1
assert len(s_d.session.worker_devices) == 8
s_d.close()
snap = aff_engine.stats()
assert snap["engine"]["admissions"]["queued"] == 1, snap
print("MULTIDEVICE_ENGINE_OK")
