"""Queued admission pins the request size at request time.

``connect(placement=PlacementRequest())`` on a drained pool means "all of the
engine's devices". The request size must be pinned when the wait begins:
re-deriving it at each wakeup would degrade the request to "whatever the
first release freed" — here, a 4-device group instead of the full engine.
"""

import threading
import time

import repro

engine = repro.AlchemistEngine()
assert engine.num_workers == 8, engine.num_workers

# Drain the pool with two 4-device holders.
s1 = repro.connect(engine, workers=4)
s2 = repro.connect(engine, workers=4)
assert engine.available_workers == 0

got = {}


def queued_all_free():
    s = repro.connect(engine, placement=repro.PlacementRequest(deadline=60))
    got["n"] = s.session.num_workers
    s.close()


t = threading.Thread(target=queued_all_free)
t.start()
while engine.queued_connects == 0:
    time.sleep(0.01)

# Free one 4-device group: the pinned all-free request (8 devices) must keep
# waiting rather than settling for the partial pool.
s1.close()
time.sleep(0.5)
assert "n" not in got, f"queued all-free request degraded to {got['n']} workers"
assert engine.queued_connects == 1

# Free the second group: now the full engine is available.
s2.close()
t.join(60)
assert got.get("n") == 8, f"expected all 8 workers, got {got.get('n')}"
assert engine.available_workers == 8
assert engine.admissions["queued"] == 1

print("MULTIDEVICE_ADMISSION_OK")
