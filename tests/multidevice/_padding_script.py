"""End-to-end padded-send property: send → collect round-trips arbitrary
(m, n, worker_count) shapes bit-exactly on an 8-emulated-device engine,
including m < worker_count (DESIGN.md §7). Run via tests/test_multidevice.py.

Uses hypothesis when installed (CI); otherwise falls back to a deterministic
sweep that still covers every worker count and the awkward-shape corners.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402

import repro  # noqa: E402

engine = repro.AlchemistEngine()
assert engine.num_workers == 8, engine.num_workers

checked = 0
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def roundtrip(ac, workers: int, m: int, n: int, seed: int) -> None:
    global checked
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((m, n)) * 8).astype(np.float32)
    h = ac.send(x)
    live = ac.session.resolve(h)
    # physical residency is put-legal; logical metadata is the true shape
    assert live.shape == (m, n)
    assert (live.shape[0] + live.pads[0]) % workers == 0 or live.pads[0] == 0
    got = np.asarray(ac.collect(h))
    assert got.shape == (m, n)
    np.testing.assert_array_equal(got, x)  # bit-exact through pad + strip
    ac.free(h)
    checked += 1


# One worker-group size at a time (a 2+4+8 split would oversubscribe the
# 8-device pool); the session is reused across examples for speed.
for workers in (2, 4, 8):
    ac = repro.AlchemistContext(engine, num_workers=workers, name=f"pad{workers}")
    if HAVE_HYPOTHESIS:

        @given(
            m=st.integers(min_value=1, max_value=24),
            n=st.integers(min_value=1, max_value=12),
            seed=st.integers(min_value=0, max_value=2**31 - 1),
        )
        @settings(max_examples=25, deadline=None)
        def prop(m, n, seed, ac=ac, workers=workers):
            roundtrip(ac, workers, m, n, seed)

        prop()
    else:
        for m, n in [(1, 1), (2, 5), (6, 6), (7, 3), (13, 9), (16, 8), (workers - 1, 3)]:
            roundtrip(ac, workers, m, n, seed=m * 100 + n)
    if workers == 4:
        # The ROADMAP's headline case, spelled out: 6x6 onto a 2x2 group.
        roundtrip(ac, 4, 6, 6, seed=0)
    ac.stop()

assert engine.available_workers == 8  # no leaked worker-group devices

# Cyclic engine layouts are never pre-padded (the emulation's permutation
# would interleave the zero rows): divisible shapes round-trip exactly,
# uneven ones fail loudly instead of silently corrupting.
from repro.core.layouts import GRID  # noqa: E402

ac = repro.AlchemistContext(engine, num_workers=4, engine_layout=GRID.with_cyclic())
x8 = np.arange(48, dtype=np.float32).reshape(8, 6)
np.testing.assert_array_equal(np.asarray(ac.collect(ac.send(x8))), x8)
try:
    ac.send(np.ones((6, 6), np.float32))  # 6 % 4 != 0 on the ROW staging
    raise SystemExit("uneven cyclic send unexpectedly succeeded")
except Exception as exc:  # jax raises ValueError at the staging device_put
    assert "divisible" in str(exc), exc
ac.stop()
assert engine.available_workers == 8

# Fused pad/strip (DESIGN.md §10), deterministic interpret-mode case: force
# the Pallas kernel dispatch (interpret mode runs the same kernel body the
# TPU path compiles) and round-trip an uneven matrix through a real 4-worker
# session — bit-exact, and the session must count the fused relayouts.
from repro.kernels import ops as kops  # noqa: E402

_saved_backend = kops._BACKEND
kops._BACKEND = "pallas-interpret"
try:
    ac = repro.AlchemistContext(engine, num_workers=4, name="fused")
    xf = (np.random.default_rng(7).standard_normal((6, 7)) * 8).astype(np.float32)
    hf = ac.send(xf)  # 6 % 4 != 0: the ROW staging pad runs through the kernel
    np.testing.assert_array_equal(np.asarray(ac.collect(hf)), xf)
    fused_count = ac.stats.summary()["fused_relayouts"]
    assert fused_count >= 1, f"expected fused relayouts, got {fused_count}"
    ac.stop()
finally:
    kops._BACKEND = _saved_backend
assert engine.available_workers == 8

print(f"checked {checked} shapes via {'hypothesis' if HAVE_HYPOTHESIS else 'deterministic'}")
print("MULTIDEVICE_PADDING_OK")
