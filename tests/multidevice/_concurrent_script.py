"""Subprocess body: two sessions on disjoint worker groups genuinely overlap.

Run by test_multidevice.py with XLA_FLAGS set for 8 host devices. This is the
paper's multi-application claim (§2, §3.3: transfers and compute for one
connected application proceed while another computes) made measurable.

Two parts:

1. Structural: two 4-worker sessions driven simultaneously through their
   task queues — disjoint device groups, both complete correctly, stats are
   recorded per-session, pool restored in canonical order after stop().

2. Wall clock: combined concurrent time measurably below the serial sum.
   Measured on two *1-worker* sessions running transfer-dominated streams
   (pipelined send_async/collect_async of 16 MB matrices). On emulated host
   devices every session shares this container's physical cores, and XLA's
   CPU matmul already multithreads a single stream — so compute-bound
   workloads cannot show overlap here (on real hardware each worker group
   owns its devices outright). Host<->device copies are single-threaded and
   GIL-releasing, which makes concurrent transfer streams the faithful
   stand-in for the paper's claim: one application's communication overlaps
   another's work.
"""

import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import threading
import time

import numpy as np
import jax

import repro

assert len(jax.devices()) == 8

rng = np.random.default_rng(0)
# Session-scoped residency on purpose: both parts drive identical payloads
# through concurrent sessions to measure genuine per-session transfer
# streams; the engine content store (DESIGN.md §8) would attach the second
# session's sends and erase the traffic this script exists to overlap.
engine = repro.AlchemistEngine(share_residents=False)


def connect(n, name):
    ac = repro.AlchemistContext(engine, num_workers=n, name=name)
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")
    return ac


def workload(ac, h, rounds):
    """Chained gemms, pipelined through the session's queue."""
    cur = h
    for _ in range(rounds):
        cur = ac.run_async("elemental", "gemm", cur, h)
    ac.collect(cur)  # force full materialization


# --- part 1: simultaneous 4-worker sessions --------------------------------
ac1 = connect(4, "app1")
ac2 = connect(4, "app2")
d1 = {d.id for d in ac1.session.worker_devices}
d2 = {d.id for d in ac2.session.worker_devices}
assert d1.isdisjoint(d2), "worker groups overlap"
assert engine.available_workers == 0

n = 256
a = (rng.standard_normal((n, n)) / np.sqrt(n)).astype(np.float32)
h1, h2 = ac1.send(a), ac2.send(a)

threads = [
    threading.Thread(target=workload, args=(ac1, h1, 3)),
    threading.Thread(target=workload, args=(ac2, h2, 3)),
]
for t in threads:
    t.start()
for t in threads:
    t.join()

for ac in (ac1, ac2):
    s = ac.stats.summary()
    assert s["num_runs"] == 3, s
    assert s["num_sends"] == 1 and s["num_receives"] == 1, s
    assert s["compute_seconds"] > 0 and s["send_bytes"] == a.nbytes, s

# numerical sanity: the concurrent chains computed the right thing
expect = a
for _ in range(3):
    expect = expect @ a
np.testing.assert_allclose(np.asarray(ac1.collect(h1)), a, rtol=1e-5)
got1 = np.asarray(ac1.collect(ac1.run_async("elemental", "gemm",
                                            ac1.run_async("elemental", "gemm",
                       ac1.run_async("elemental", "gemm", h1, h1),
                                                          h1),
                                            h1)))
np.testing.assert_allclose(got1, expect, atol=1e-2)

ac1.stop()
ac2.stop()
assert engine.available_workers == 8
# regression: pool must return to canonical device order after session churn
assert [d.id for d in engine._free] == [d.id for d in engine.devices]

# --- part 2: wall-clock overlap of transfer streams -------------------------
N, ROUNDS = 2048, 6
b1 = connect(1, "bench1")
b2 = connect(1, "bench2")
assert {d.id for d in b1.session.worker_devices}.isdisjoint(
    {d.id for d in b2.session.worker_devices}
)
big = (rng.standard_normal((N, N)) / np.sqrt(N)).astype(np.float32)


def xfer_stream(ac):
    """ROUNDS pipelined send->collect round trips of a 16 MB matrix."""
    last = None
    for _ in range(ROUNDS):
        last = ac.collect_async(ac.send_async(big))
    last.result(300)


# warm caches (jit, relayout plans): one-off server state, not per-call cost
xfer_stream(b1)
xfer_stream(b2)

REPEATS = 4  # best-of-k: the container's 2 shared cores are noisy


def timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def concurrent_once() -> float:
    threads = [
        threading.Thread(target=xfer_stream, args=(b1,)),
        threading.Thread(target=xfer_stream, args=(b2,)),
    ]

    def run_all():
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    return timed(run_all)


# Up to 3 full measurement attempts: shared CI runners can be scheduler-bound
# for a whole best-of-k window, and a wall-clock assertion must not turn
# noisy-neighbor minutes into a suite failure.
for attempt in range(3):
    t_s1 = min(timed(lambda: xfer_stream(b1)) for _ in range(REPEATS))
    t_s2 = min(timed(lambda: xfer_stream(b2)) for _ in range(REPEATS))
    serial = t_s1 + t_s2
    combined = min(concurrent_once() for _ in range(REPEATS))
    print(f"attempt {attempt}: serial={serial:.3f}s (s1={t_s1:.3f} s2={t_s2:.3f}) "
          f"combined={combined:.3f}s overlap_ratio={combined / serial:.2f}")
    if combined < 0.85 * serial:
        break
else:
    raise AssertionError(
        f"no overlap after 3 attempts: combined {combined:.3f}s vs serial {serial:.3f}s"
    )

# repeated same-shape transfers hit each session's relayout plan cache
assert b1.stats.relayout_cache_hits >= 2, b1.stats.summary()
assert b2.stats.relayout_cache_hits >= 2, b2.stats.summary()

b1.stop()
b2.stop()
assert engine.available_workers == 8

print("MULTIDEVICE_CONCURRENT_OK")
