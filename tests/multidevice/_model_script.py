"""Subprocess body: sharded model train/decode on a 2x2x2 pod mesh, checking
that results match the single-device reference."""

import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import dataclasses
import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding

from repro.configs import InputShape, get_config
from repro.core.layouts import AXIS_DATA, AXIS_MODEL, AXIS_POD
from repro.models import build_model
from repro.models.registry import make_batch

mesh = jax.make_mesh((2, 2, 2), (AXIS_POD, AXIS_DATA, AXIS_MODEL))
single = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), (AXIS_DATA, AXIS_MODEL))

shape = InputShape("md", seq_len=32, global_batch=4, kind="train")

for arch in ("qwen2-1.5b", "olmoe-1b-7b", "mamba2-130m"):
    cfg = dataclasses.replace(get_config(arch, smoke=True), compute_dtype="float32")
    if cfg.moe is not None:  # drop-free so 1-dev and 8-dev routing agree
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.num_experts))
        )
    batch = make_batch(cfg, shape, jax.random.PRNGKey(1))

    # reference on one device
    model_1 = build_model(cfg, single)
    params = model_1.init(jax.random.PRNGKey(0))
    with single:
        ref_loss, _ = jax.jit(model_1.loss)(params, batch)

    # sharded on the pod mesh
    model_8 = build_model(cfg, mesh)
    specs = model_8.param_partition_specs()
    sharded = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs
    )
    with mesh:
        loss_8, _ = jax.jit(model_8.loss)(sharded, batch)

    err = abs(float(ref_loss) - float(loss_8))
    assert err < 1e-3, f"{arch}: sharded loss differs by {err}"
    print(f"{arch}: 1-dev {float(ref_loss):.5f} vs 8-dev {float(loss_8):.5f} OK")

print("MULTIDEVICE_MODEL_OK")
