"""Lazy offload planner tests (DESIGN.md §6): deferred-op DAG construction,
bridge-crossing elision, content-keyed resident-matrix dedup, multi-output
projection, the sparklike auto-offload drop-in, and the wrapper's lazy view.
"""

import numpy as np
import pytest

import repro
from repro.core.errors import SessionError, ShapeError
from repro.core.expr import LazyMatrix, ProjExpr, RunExpr, content_key, iter_nodes
from repro.core.futures import AlFuture
from repro.linalg.wrappers import Elemental
from repro.sparklike import IndexedRowMatrix, SparkLikeContext, mllib, offload


@pytest.fixture()
def engine():
    return repro.AlchemistEngine()


@pytest.fixture()
def ac(engine):
    ctx = repro.AlchemistContext(engine, num_workers=1, name="plan_app")
    ctx.register_library("elemental", "repro.linalg.library:ElementalLib")
    yield ctx
    ctx.stop()


@pytest.fixture()
def pl(ac):
    return ac.planner


# ---------------------------------------------------------------------------
# DAG construction
# ---------------------------------------------------------------------------

class TestExprDag:
    def test_send_carries_metadata_and_content_key(self, pl, rng):
        a = rng.standard_normal((12, 6)).astype(np.float32)
        la = pl.send(a, name="A")
        assert isinstance(la, LazyMatrix)
        assert la.shape == (12, 6) and la.dtype == "float32"
        assert la.expr.key == content_key(a)
        assert la.expr.key == content_key(a.copy())  # content, not identity
        assert la.expr.key != content_key(a + 1)

    def test_send_rejects_non_2d(self, pl):
        with pytest.raises(ValueError):
            pl.send(np.zeros(5, dtype=np.float32))

    def test_run_builds_nodes_without_executing(self, pl, rng):
        a = rng.standard_normal((8, 8)).astype(np.float32)
        lc = pl.run("elemental", "gemm", pl.send(a), pl.send(a))
        assert isinstance(lc.expr, RunExpr)
        assert pl.ac.stats.num_runs == 0  # nothing dispatched yet
        assert lc.shape == (8, 8)  # gemm shape inference

    def test_matmul_operator(self, pl, rng):
        a = rng.standard_normal((6, 4)).astype(np.float32)
        b = rng.standard_normal((4, 3)).astype(np.float32)
        lc = pl.send(a) @ pl.send(b)
        assert isinstance(lc.expr, RunExpr)
        assert (lc.expr.library, lc.expr.routine) == ("elemental", "gemm")
        np.testing.assert_allclose(np.asarray(lc.collect()), a @ b, atol=1e-4)

    def test_rmatmul_with_host_ndarray(self, pl, rng):
        """ndarray @ LazyMatrix must reach __rmatmul__ (regression: numpy
        coerced the proxy to a 0-d object array and raised before the
        reflected operator ran)."""
        a = rng.standard_normal((6, 4)).astype(np.float32)
        b = rng.standard_normal((4, 3)).astype(np.float32)
        lc = a @ pl.send(b)  # host array on the LEFT
        assert isinstance(lc.expr, RunExpr)
        np.testing.assert_allclose(np.asarray(lc.collect()), a @ b, atol=1e-4)

    def test_multi_output_returns_projections(self, pl, rng):
        a = rng.standard_normal((16, 8)).astype(np.float32)
        outs = pl.run("elemental", "tsqr", pl.send(a), n_outputs=2)
        assert len(outs) == 2
        assert all(isinstance(o.expr, ProjExpr) for o in outs)
        assert outs[0].expr.parent is outs[1].expr.parent

    def test_iter_nodes_is_producers_first(self, pl, rng):
        a = rng.standard_normal((4, 4)).astype(np.float32)
        la = pl.send(a)
        lc = pl.run("elemental", "gemm", la, la)
        order = [n.id for n in iter_nodes(lc.expr)]
        assert order == [la.expr.id, lc.expr.id]

    def test_foreign_planner_rejected(self, ac, rng):
        other = repro.AlchemistContext(repro.AlchemistEngine(), num_workers=1, name="other")
        try:
            la = other.planner.send(rng.standard_normal((4, 4)).astype(np.float32))
            with pytest.raises(SessionError):
                ac.planner.run("elemental", "gemm", la, la)
        finally:
            other.stop()


# ---------------------------------------------------------------------------
# Execution: numerics + pipelining onto the task queue
# ---------------------------------------------------------------------------

class TestPlannerExecution:
    def test_gemm_chain_matches_numpy(self, pl, rng):
        a = rng.standard_normal((24, 16)).astype(np.float32)
        b = rng.standard_normal((16, 8)).astype(np.float32)
        c = rng.standard_normal((8, 8)).astype(np.float32)
        lab = pl.run("elemental", "gemm", pl.send(a), pl.send(b))
        ld = pl.run("elemental", "gemm", lab, pl.send(c))
        np.testing.assert_allclose(np.asarray(pl.collect(ld)), (a @ b) @ c, atol=1e-3)

    def test_projection_collects_each_output(self, pl, rng):
        a = rng.standard_normal((32, 8)).astype(np.float32)
        u, s, v = pl.run("elemental", "truncated_svd", pl.send(a), n_outputs=3, k=4)
        sig = np.asarray(pl.collect(s))
        ref = np.linalg.svd(a, compute_uv=False)[:4]
        np.testing.assert_allclose(sig, ref, rtol=1e-3)
        assert pl.collect(u).shape == (32, 4)
        assert pl.collect(v).shape == (8, 4)

    def test_scalar_output_passthrough(self, pl, rng):
        a = rng.standard_normal((16, 8)).astype(np.float32)
        norm = pl.collect(pl.run("elemental", "normest", pl.send(a)))
        np.testing.assert_allclose(float(norm), np.linalg.norm(a), rtol=1e-4)

    def test_lowering_is_async_until_collect(self, pl, rng):
        a = rng.standard_normal((8, 8)).astype(np.float32)
        lc = pl.run("elemental", "gemm", pl.send(a), pl.send(a))
        fut = pl.lower(lc)
        assert isinstance(fut, AlFuture)  # dispatched, not awaited
        np.testing.assert_allclose(np.asarray(pl.collect(lc)), a @ a, atol=1e-3)

    def test_materialize_yields_handle_without_receive(self, pl, rng):
        a = rng.standard_normal((8, 8)).astype(np.float32)
        h = pl.materialize(pl.run("elemental", "gemm", pl.send(a), pl.send(a)))
        assert isinstance(h, repro.AlMatrix)
        assert pl.ac.stats.num_receives == 0

    def test_n_outputs_too_high_fails_at_graph_build(self, pl, rng):
        # The per-routine shape rules catch the arity mismatch where the call
        # is written (PR 3) — previously this died at collect time, deep in
        # the task queue.
        a = rng.standard_normal((8, 8)).astype(np.float32)
        with pytest.raises(ShapeError, match="n_outputs"):
            pl.run("elemental", "gemm", pl.send(a), pl.send(a), n_outputs=2)

    def test_ndarray_args_autowrap(self, pl, rng):
        a = rng.standard_normal((8, 8)).astype(np.float32)
        lc = pl.run("elemental", "gemm", a, a)  # raw ndarrays, no explicit send
        np.testing.assert_allclose(np.asarray(pl.collect(lc)), a @ a, atol=1e-3)
        # both args deduped into one resident matrix
        assert pl.ac.stats.resident_reuses == 1
        assert pl.ac.stats.num_sends == 1


# ---------------------------------------------------------------------------
# Elision + resident-matrix dedup
# ---------------------------------------------------------------------------

class TestElisionAndDedup:
    def test_chained_runs_elide_crossings(self, pl, rng):
        a = rng.standard_normal((16, 16)).astype(np.float32)
        b = rng.standard_normal((16, 16)).astype(np.float32)
        lc = pl.run("elemental", "gemm", pl.send(a), pl.send(b))
        ld = pl.run("elemental", "gemm", lc, pl.send(a + b))
        pl.collect(ld)
        s = pl.ac.stats.summary()
        assert s["elided_crossings"] == 1  # lc consumed in place
        assert s["num_receives"] == 1  # only the final collect crossed back
        assert s["num_sends"] == 3

    def test_identical_sends_dedup(self, pl, rng):
        a = rng.standard_normal((16, 8)).astype(np.float32)
        l1, l2 = pl.send(a), pl.send(a.copy())  # distinct nodes, equal bytes
        r1 = pl.run("elemental", "tsqr", l1, n_outputs=2)[1]
        pl.collect(pl.run("elemental", "gemm", r1, np.zeros((8, 8), np.float32)))
        pl.collect(pl.run("elemental", "tsqr", l2, n_outputs=2)[1])
        s = pl.ac.stats.summary()
        assert s["resident_reuses"] == 1
        # the dataset moved once; zeros moved once
        assert s["num_sends"] == 2

    def test_planned_moves_fewer_bytes_than_naive(self, engine, rng):
        """The acceptance property at test scale: same pipeline, planned
        execution moves strictly fewer bytes across the bridge."""
        a = rng.standard_normal((64, 32)).astype(np.float32)

        naive = repro.AlchemistContext(engine, num_workers=1, name="naive")
        naive.register_library("elemental", "repro.linalg.library:ElementalLib")
        h = naive.send(a)
        q, r = naive.run("elemental", "tsqr", h)
        r_np = np.asarray(naive.collect(r))          # round trip the intermediate
        h_r = naive.send(r_np)
        out_naive = np.asarray(naive.collect(naive.run("elemental", "gemm", h_r, h_r)))
        s_naive = naive.stats.summary()
        naive.stop()

        planned = repro.AlchemistContext(engine, num_workers=1, name="planned")
        planned.register_library("elemental", "repro.linalg.library:ElementalLib")
        pl = planned.planner
        _, lr = pl.run("elemental", "tsqr", pl.send(a), n_outputs=2)
        out_planned = np.asarray(pl.collect(pl.run("elemental", "gemm", lr, lr)))
        s_planned = planned.stats.summary()
        planned.stop()

        np.testing.assert_allclose(out_planned, out_naive, atol=1e-3)
        naive_bytes = s_naive["send_bytes"] + s_naive["recv_bytes"]
        planned_bytes = s_planned["send_bytes"] + s_planned["recv_bytes"]
        assert s_planned["elided_crossings"] > 0
        assert planned_bytes < naive_bytes

    def test_freed_resident_matrix_is_resent(self, pl, rng):
        a = rng.standard_normal((8, 8)).astype(np.float32)
        h = pl.materialize(pl.send(a))
        pl.ac.free(h)
        lc = pl.run("elemental", "gemm", pl.send(a.copy()), np.eye(8, dtype=np.float32))
        np.testing.assert_allclose(np.asarray(pl.collect(lc)), a, atol=1e-5)
        s = pl.ac.stats.summary()
        assert s["num_sends"] == 3  # a, a again (cache entry dead), eye
        assert s["resident_reuses"] == 0

    def test_same_lazy_node_survives_free(self, pl, rng):
        """Reusing the SAME LazyMatrix after its handle was freed re-sends
        transparently (regression: the lowering memo used to hand back the
        stale future and the run died with HandleError)."""
        a = rng.standard_normal((8, 8)).astype(np.float32)
        la = pl.send(a)
        pl.ac.free(pl.materialize(la))
        out = pl.collect(pl.run("elemental", "gemm", la, np.eye(8, dtype=np.float32)))
        np.testing.assert_allclose(np.asarray(out), a, atol=1e-5)
        assert pl.ac.stats.num_sends == 3  # a, eye, a re-sent

    def test_freed_run_output_is_rerun(self, pl, rng):
        """A freed routine result consumed again re-runs the routine
        transparently (regression: the memo handed back the freed handle and
        later consumers died with HandleError)."""
        a = rng.standard_normal((8, 8)).astype(np.float32)
        lc = pl.run("elemental", "gemm", pl.send(a), np.eye(8, dtype=np.float32))
        pl.ac.free(pl.materialize(lc))
        out = pl.collect(pl.run("elemental", "gemm", lc, np.eye(8, dtype=np.float32)))
        np.testing.assert_allclose(np.asarray(out), a, atol=1e-4)
        assert pl.ac.stats.planned_ops == 3  # first gemm, the re-run, consumer

    def test_failed_run_keeps_propagating(self, pl, rng):
        """A FAILED routine is never silently retried: every later consumer
        of the node sees the original error."""
        a = rng.standard_normal((8, 8)).astype(np.float32)
        bad = pl.run("elemental", "gemm", pl.send(a), "nonsense")
        for _ in range(2):
            with pytest.raises(TypeError):
                pl.collect(pl.run("elemental", "gemm", bad, pl.send(a)))
        assert pl.ac.stats.planned_ops == 3  # bad ran once, two consumers

    def test_mutating_source_array_after_send_is_harmless(self, pl):
        """send() snapshots mutable host arrays (regression: an aliased
        mutation used to ship the new bytes under the old content key and
        poison the resident-matrix cache)."""
        b = np.ones((8, 8), dtype=np.float32)
        lb = pl.send(b)
        b[:] = 0.0  # mutate after graph build, before any lowering
        np.testing.assert_allclose(np.asarray(pl.collect(lb)), np.ones((8, 8)), atol=0)
        # and a fresh send of genuine ones still reuses the (correct) entry
        lb2 = pl.send(np.ones((8, 8), dtype=np.float32))
        np.testing.assert_allclose(
            np.asarray(pl.collect(lb2)), np.ones((8, 8)), atol=0
        )
        assert pl.ac.stats.resident_reuses == 1

    def test_reset_clears_caches(self, pl, rng):
        a = rng.standard_normal((8, 8)).astype(np.float32)
        pl.materialize(pl.send(a))
        assert pl.stats()["resident_entries"] == 1
        pl.reset()
        assert pl.stats() == {
            "resident_entries": 0,
            "cse_entries": 0,
            "lowered_nodes": 0,
            "tracked_last_uses": 0,
        }
        # The planner memo was genuinely dropped — but the engine's content
        # index (DESIGN.md §8) still holds this session's placement, so the
        # re-send reuses it instead of moving bytes again.
        pl.materialize(pl.send(a))
        assert pl.ac.stats.resident_reuses == 1
        assert pl.ac.stats.num_sends == 1

    def test_summary_exposes_planner_counters(self, ac):
        s = ac.stats.summary()
        for key in ("elided_crossings", "resident_reuses", "planned_ops"):
            assert key in s and s[key] == 0


# ---------------------------------------------------------------------------
# sparklike auto-offload (the arXiv:1805.11800 drop-in)
# ---------------------------------------------------------------------------

class TestSparklikeOffload:
    def _dataset(self, rng, m=96, n=24, k_true=6):
        low = rng.standard_normal((m, k_true)) @ rng.standard_normal((k_true, n))
        return (low + 0.05 * rng.standard_normal((m, n))).astype(np.float64)

    def test_compute_svd_drop_in(self, ac, rng):
        a = self._dataset(rng)
        ctx = SparkLikeContext(num_partitions=4)
        ir = IndexedRowMatrix.from_numpy(ctx, a)
        u_ref, s_ref, v_ref = mllib.compute_svd(ir, 4)
        with offload.offloaded(ac):
            u_off, s_off, v_off = mllib.compute_svd(ir, 4)
        assert isinstance(u_off, offload.LazyRowMatrix)
        assert (u_off.num_rows, u_off.num_cols) == (96, 4)
        np.testing.assert_allclose(s_off, s_ref, rtol=2e-2)
        # U stays engine-resident until explicitly collected
        assert ac.stats.num_receives == 1  # V only (sigmas are driver-side)
        u_np = u_off.to_numpy()
        np.testing.assert_allclose(
            np.abs(np.diag(u_np.T @ u_ref.to_numpy())), np.ones(4), atol=5e-2
        )

    def test_multiply_consumes_resident_u(self, ac, rng):
        a = self._dataset(rng)
        ctx = SparkLikeContext(num_partitions=4)
        ir = IndexedRowMatrix.from_numpy(ctx, a)
        w = rng.standard_normal((4, 8)).astype(np.float64)
        ir_w = IndexedRowMatrix.from_numpy(ctx, w)
        with offload.offloaded(ac):
            u_off, s_off, _ = mllib.compute_svd(ir, 4)
            prod = mllib.multiply(u_off, ir_w)  # u never crosses the bridge
            out = prod.to_numpy()
            u_np = u_off.to_numpy()
        assert ac.stats.elided_crossings >= 1
        # compare against the engine's own U (SVD column signs are
        # implementation-specific, so the sparklike U is not the reference)
        np.testing.assert_allclose(out, u_np @ w, atol=1e-4)

    def test_compute_svd_honors_max_iters(self, ac, rng):
        """max_iters must not be silently dropped on the offloaded path: a
        hard cap well under k+oversample degrades the trailing sigma, just
        like the baseline's capped Lanczos."""
        a = self._dataset(rng, m=128, n=32)
        ctx = SparkLikeContext(num_partitions=4)
        ir = IndexedRowMatrix.from_numpy(ctx, a)
        with offload.offloaded(ac):
            _, s_full, _ = mllib.compute_svd(ir, 8)
            _, s_capped, _ = mllib.compute_svd(ir, 8, max_iters=8)
        ref = np.linalg.svd(a, compute_uv=False)[:8]
        np.testing.assert_allclose(s_full, ref, rtol=2e-2)
        # the capped run is a genuinely different (worse) approximation
        assert abs(s_capped[-1] - ref[-1]) > abs(s_full[-1] - ref[-1])

    def test_offload_scope_restores_baseline(self, ac, rng):
        assert offload.active() is None
        with offload.offloaded(ac) as planner:
            assert offload.active() is planner
        assert offload.active() is None
        # outside the scope, multiply is the pure block-matrix path again
        a = rng.standard_normal((8, 4))
        ctx = SparkLikeContext(num_partitions=2)
        out = mllib.multiply(
            IndexedRowMatrix.from_numpy(ctx, a), IndexedRowMatrix.from_numpy(ctx, a.T)
        )
        assert isinstance(out, IndexedRowMatrix)
        np.testing.assert_allclose(out.to_numpy(), a @ a.T, atol=1e-10)

    def test_multiply_dimension_mismatch(self, ac, rng):
        ctx = SparkLikeContext(num_partitions=2)
        ir1 = IndexedRowMatrix.from_numpy(ctx, rng.standard_normal((8, 4)))
        ir2 = IndexedRowMatrix.from_numpy(ctx, rng.standard_normal((8, 4)))
        with offload.offloaded(ac):
            with pytest.raises(ValueError):
                mllib.multiply(ir1, ir2)


# ---------------------------------------------------------------------------
# LibraryWrapper.lazy
# ---------------------------------------------------------------------------

class TestWrapperLazy:
    def test_lazy_routines_chain(self, ac, rng):
        el = Elemental(ac)
        a = rng.standard_normal((16, 8)).astype(np.float32)
        q, r = el.lazy.tsqr(a, n_outputs=2)
        gram = el.lazy.gemm(r, r)
        out = np.asarray(gram.collect())
        assert out.shape == (8, 8)
        assert ac.stats.elided_crossings >= 1

    def test_lazy_unknown_routine(self, ac):
        el = Elemental(ac)
        with pytest.raises(AttributeError):
            el.lazy.not_a_routine
