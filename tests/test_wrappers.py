"""Library-wrapper (§3.4) tests: the MLlib-mimicking sugar."""

import numpy as np
import pytest

import repro
from repro.linalg.wrappers import Elemental


@pytest.fixture()
def ac():
    engine = repro.AlchemistEngine()
    ctx = repro.AlchemistContext(engine, num_workers=1)
    yield ctx
    ctx.stop()


def test_wrapper_registers_and_calls(ac, rng):
    el = Elemental(ac)
    a = rng.standard_normal((128, 32)).astype(np.float32)
    al_a = ac.send(a)
    cond = el.condest(al_a)
    assert float(cond) > 1.0


def test_wrapper_routines_discoverable(ac):
    el = Elemental(ac)
    assert "truncated_svd" in dir(el)
    with pytest.raises(AttributeError):
        el.not_a_routine


def test_wrapper_svd_matches_direct_call(ac, rng):
    el = Elemental(ac)
    a = rng.standard_normal((200, 24)).astype(np.float32)
    al_a = ac.send(a)
    _, s1, _ = el.truncated_svd(al_a, k=4)
    _, s2, _ = ac.run("elemental", "truncated_svd", al_a, k=4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5)


def test_wrapper_reuses_registered_library(ac):
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")
    el = Elemental(ac)  # must not double-register
    assert len(ac.session.libraries) == 1
