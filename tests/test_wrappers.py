"""Library-wrapper (§3.4) tests: the MLlib-mimicking sugar."""

import numpy as np
import pytest

import repro
from repro.linalg.wrappers import Elemental


@pytest.fixture()
def ac():
    engine = repro.AlchemistEngine()
    ctx = repro.AlchemistContext(engine, num_workers=1)
    yield ctx
    ctx.stop()


def test_wrapper_registers_and_calls(ac, rng):
    el = Elemental(ac)
    a = rng.standard_normal((128, 32)).astype(np.float32)
    al_a = ac.send(a)
    cond = el.condest(al_a)
    assert float(cond) > 1.0


def test_wrapper_routines_discoverable(ac):
    el = Elemental(ac)
    assert "truncated_svd" in dir(el)
    with pytest.raises(AttributeError):
        el.not_a_routine


def test_wrapper_svd_matches_direct_call(ac, rng):
    el = Elemental(ac)
    a = rng.standard_normal((200, 24)).astype(np.float32)
    al_a = ac.send(a)
    _, s1, _ = el.truncated_svd(al_a, k=4)
    _, s2, _ = ac.run("elemental", "truncated_svd", al_a, k=4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5)


def test_wrapper_reuses_registered_library(ac):
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")
    el = Elemental(ac)  # must not double-register
    assert len(ac.session.libraries) == 1


def test_wrapper_async_submit_returns_futures(ac, rng):
    el = Elemental(ac)
    a = rng.standard_normal((64, 16)).astype(np.float32)
    fa = ac.send_async(a)
    fb = ac.send_async(rng.standard_normal((16, 8)).astype(np.float32))
    g = el.submit.gemm(fa, fb)  # chains on unresolved futures
    assert isinstance(g, repro.AlFuture)
    h = g.result(60)
    assert h.shape == (64, 8)


def test_wrapper_async_matches_sync(ac, rng):
    el = Elemental(ac)
    a = rng.standard_normal((32, 32)).astype(np.float32)
    al_a = ac.send(a)
    sync_out = np.asarray(ac.collect(el.gemm(al_a, al_a)))
    async_out = np.asarray(ac.collect(el.submit.gemm(al_a, al_a)))
    np.testing.assert_allclose(async_out, sync_out, atol=1e-5)


def test_wrapper_async_unknown_routine(ac):
    el = Elemental(ac)
    with pytest.raises(AttributeError):
        el.submit.not_a_routine
