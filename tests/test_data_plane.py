"""Asynchronous data plane tests (DESIGN.md §10): the transfer executor's
double-buffer ring, busy-time accounting, the spill copy-out lifecycle
(install / refill-join / host_payload-wait), the staging pool's reuse and
escape rules, and the fused pad/strip dispatch paths.

Single-device like test_memgov.py: every matrix is 32x32 float32 = 4096
bytes, so budgets read as whole matrix counts. The overlap *ratio* itself is
measured on the 8-emulated-device runner by benchmarks/overlap_spill.py.
"""

import threading
import time

import numpy as np
import pytest

import repro
from repro.core.handles import MATERIALIZED, SPILLED
from repro.core.memgov import _StagingPool
from repro.core.taskqueue import TaskQueue, TransferExecutor

MAT = 32 * 32 * 4


@pytest.fixture()
def engine():
    return repro.AlchemistEngine()


def _ctx(engine, budget):
    return repro.AlchemistContext(engine, num_workers=1, name="dp", hbm_budget=budget)


def _mats(n, rng):
    return [rng.standard_normal((32, 32)).astype(np.float32) for _ in range(n)]


class _CapturingRing:
    """Transfer-ring stand-in that accepts jobs without running them, so a
    test controls exactly when (or whether) each copy-out lands."""

    _closed = False

    def __init__(self):
        self.jobs = []

    def try_submit(self, fn):
        self.jobs.append(fn)
        return True

    def depth(self):
        return len(self.jobs)

    def close(self, wait=True, timeout=None):
        self._closed = True


# ---------------------------------------------------------------------------
# TransferExecutor: the bounded double-buffer ring
# ---------------------------------------------------------------------------


class TestTransferExecutor:
    def test_ring_bounds_in_flight_jobs(self):
        ex = TransferExecutor(ring=2)
        gate = threading.Event()
        started = threading.Event()

        def blocker():
            started.set()
            gate.wait(10)

        try:
            assert ex.try_submit(blocker)
            assert started.wait(5)
            assert ex.try_submit(lambda: gate.wait(10))
            # both slots taken: the third submit must refuse, not block — the
            # governor calls this under its lock.
            assert not ex.try_submit(lambda: None)
            assert ex.rejected == 1 and ex.depth() == 2
        finally:
            gate.set()
            ex.close(wait=True, timeout=10)
        assert ex.stats() == {"submitted": 2, "rejected": 1, "max_depth": 2, "ring": 2}

    def test_job_exception_does_not_kill_the_ring(self):
        ex = TransferExecutor(ring=2)
        done = threading.Event()
        try:
            assert ex.try_submit(lambda: 1 / 0)
            assert ex.try_submit(done.set)
            assert done.wait(5)  # the worker survived the failing job
        finally:
            ex.close(wait=True, timeout=10)

    def test_closed_ring_refuses_jobs(self):
        ex = TransferExecutor(ring=2)
        ex.close(wait=True, timeout=10)
        assert not ex.try_submit(lambda: None)
        assert ex.rejected == 1


class TestBusyNs:
    def test_busy_time_accumulates_and_includes_live_task(self):
        q = TaskQueue(name="busy")
        try:
            assert q.busy_ns() == 0
            entered = threading.Event()
            gate = threading.Event()

            def task():
                entered.set()
                gate.wait(10)

            fut = q.submit(task)
            assert entered.wait(5)
            time.sleep(0.01)
            live = q.busy_ns()
            assert live > 0  # the running task counts
            gate.set()
            fut.result(5)
            settled = q.busy_ns()
            assert settled >= live >= 5_000_000
            assert q.busy_ns() >= settled  # monotone
        finally:
            q.close(wait=True, timeout=10)


# ---------------------------------------------------------------------------
# Staging pool: reuse + the read-only escape rule
# ---------------------------------------------------------------------------


class TestStagingPool:
    def test_reuses_shape_and_dtype_matches(self):
        pool = _StagingPool(max_buffers=2)
        a = pool.acquire((4, 4), np.float32)
        pool.release(a)
        b = pool.acquire((4, 4), np.float32)
        assert b is a and pool.reuses == 1
        # a mismatched request allocates fresh
        c = pool.acquire((8, 4), np.float32)
        assert c.shape == (8, 4) and pool.reuses == 1

    def test_escaped_read_only_buffers_are_never_recycled(self):
        pool = _StagingPool(max_buffers=2)
        a = pool.acquire((4, 4), np.float32)
        a.flags.writeable = False  # host_payload marked it: a client may hold it
        pool.release(a)
        b = pool.acquire((4, 4), np.float32)
        assert b is not a and pool.reuses == 0

    def test_pool_is_bounded(self):
        pool = _StagingPool(max_buffers=1)
        a = pool.acquire((2, 2), np.float32)
        b = pool.acquire((2, 2), np.float32)
        pool.release(a)
        pool.release(b)  # over capacity: dropped
        assert pool.acquire((2, 2), np.float32) is a
        assert pool.acquire((2, 2), np.float32) is not b


# ---------------------------------------------------------------------------
# Async spill lifecycle through a real engine
# ---------------------------------------------------------------------------


class TestAsyncSpill:
    def test_async_and_sync_spill_agree_bit_exactly(self, rng):
        mats = _mats(4, rng)
        outs = {}
        for mode in (True, False):
            eng = repro.AlchemistEngine(async_spill=mode, share_residents=False)
            ac = _ctx(eng, 2 * MAT)
            hs = [ac.send(m) for m in mats]
            ac.wait()
            outs[mode] = [np.asarray(ac.collect(h)) for h in hs]
            # Drain the ring before reading counters: record_spill_copy lands
            # after the job's event fires, so a collect can return first.
            ring = ac.session.memgov._transfer
            if ring is not None:
                ring.close(wait=True, timeout=10)
            s = ac.stats.summary()
            assert s["spills"] >= 2
            if mode:
                assert s["spill_copy_ns"] > 0
                assert s["spill_copy_ns"] >= s["spill_overlap_ns"] >= 0
                assert s["transfer_queue_depth"] >= 1
            else:
                # only ring copies record: the sync baseline is structurally 0
                assert s["spill_copy_ns"] == 0 and s["spill_overlap_ns"] == 0
                assert s["transfer_queue_depth"] == 0
            ac.stop()
        for a, b in zip(outs[True], outs[False]):
            np.testing.assert_array_equal(a, b)

    def test_in_flight_ledger_drains_to_zero(self, engine, rng):
        ac = _ctx(engine, 2 * MAT)
        hs = [ac.send(m) for m in _mats(4, rng)]
        ac.wait()
        for h in hs:  # a collect of an in-flight victim waits on its event
            ac.collect(h)
        snap = ac.session.memgov.snapshot()
        assert snap["in_flight_spill_bytes"] == 0
        ac.stop()

    def test_refill_joins_a_still_in_flight_copy(self, engine, rng):
        """A refill of a victim whose copy-out never ran must restore the
        retained device reference — zero copies, no host store entry."""
        ac = _ctx(engine, 2 * MAT)
        gov = ac.session.memgov
        stuck = _CapturingRing()
        gov._transfer = stuck

        mats = _mats(3, rng)
        hs = [ac.send(m) for m in mats]
        ac.wait()
        spilled = [h for h in hs if ac.session.resolve(h).state == SPILLED]
        assert spilled and stuck.jobs  # pressure produced in-flight copy-outs
        assert gov.snapshot()["in_flight_spill_bytes"] > 0

        victim = spilled[0]
        live = ac.session.resolve(victim)
        got = np.asarray(live.data())  # first consumption: refill joins
        np.testing.assert_array_equal(got, mats[hs.index(victim)])
        assert live.state == MATERIALIZED
        # the joined victim's bytes never reached the host store
        assert gov._host_store.get(victim.id) is None

        # Run the captured copy-outs: the joined (cancelled) job must no-op;
        # any job the join's own admission re-captured lands normally.
        for fn in stuck.jobs:
            fn()
        assert gov.snapshot()["in_flight_spill_bytes"] == 0
        assert gov._host_store.get(victim.id) is None
        ac.stop()

    def test_host_payload_waits_for_the_copy_to_land(self, engine, rng):
        ac = _ctx(engine, 2 * MAT)
        gov = ac.session.memgov
        ring = _CapturingRing()
        gov._transfer = ring

        mats = _mats(3, rng)
        hs = [ac.send(m) for m in mats]
        ac.wait()
        victim = next(h for h in hs if ac.session.resolve(h).state == SPILLED)
        live = ac.session.resolve(victim)

        t = threading.Timer(0.05, lambda: [fn() for fn in ring.jobs])
        t.start()
        try:
            host = gov.host_payload(live, timeout=10.0)
        finally:
            t.join()
        assert host is not None
        np.testing.assert_array_equal(
            host[: live.shape[0], : live.shape[1]], mats[hs.index(victim)]
        )
        # escaped to a caller: marked read-only so it is never recycled
        assert not host.flags.writeable
        ac.stop()

    def test_refilled_matrix_never_aliases_a_pool_buffer(self, engine, rng):
        """On CPU the refill's sharded/donated device_put is zero-copy: the
        placed array's backing store IS the staging buffer. That buffer must
        not re-enter the pool, or a later spill's gather would write a
        victim's bytes through the alias into the live matrix."""
        ac = _ctx(engine, None)
        gov = ac.session.memgov
        x = rng.standard_normal((32, 32)).astype(np.float32)
        h = ac.send(x)
        ac.wait()
        live = ac.session.resolve(h)
        gov.spill(live)
        job = gov._in_flight.get(live.id)
        if job is not None:
            assert job.event.wait(10)
        arr = live.data()  # refill replay
        for buf in gov._staging._free:
            base, end = buf.ctypes.data, buf.ctypes.data + buf.nbytes
            for shard in arr.addressable_shards:
                assert not base <= shard.data.unsafe_buffer_pointer() < end
        np.testing.assert_array_equal(np.asarray(arr), x)
        ac.stop()

    def test_refill_survives_later_spill_gathers(self, engine, rng):
        """End-to-end regression for the alias bug: refill a victim, then
        pile on pressure so later gathers recycle pool buffers — the
        refilled matrix must stay bit-exact."""
        ac = _ctx(engine, 2 * MAT)
        mats = _mats(5, rng)
        hs = [ac.send(m) for m in mats[:3]]
        ac.wait()
        victim = next(h for h in hs if ac.session.resolve(h).state == SPILLED)
        ac.session.resolve(victim).data()  # refill (possibly zero-copy)
        for m in mats[3:]:  # more pressure: spill gathers run
            ac.send(m)
        ac.wait()
        np.testing.assert_array_equal(
            np.asarray(ac.collect(victim)), mats[hs.index(victim)]
        )
        ac.stop()

    def test_governor_clear_shuts_the_ring_down(self, engine, rng):
        ac = _ctx(engine, MAT)
        for m in _mats(2, rng):
            ac.send(m)
        ac.wait()
        gov = ac.session.memgov
        gov.clear()
        assert gov._transfer is None
        assert gov.snapshot()["in_flight_spill_bytes"] == 0
        ac.stop()


# ---------------------------------------------------------------------------
# Fused pad/strip dispatch (ops.py)
# ---------------------------------------------------------------------------


class TestFusedDispatch:
    def test_interpret_path_matches_ref(self, monkeypatch):
        from repro.kernels import ops as kops

        x = np.arange(15, dtype=np.float32).reshape(3, 5)
        monkeypatch.setattr(kops, "_BACKEND", "pallas-interpret")
        fused, fpath = kops.pad_to(x, (4, 8))
        assert fpath == "pallas-interpret"
        back, spath = kops.strip_to(fused, (3, 5))
        assert spath == "pallas-interpret"
        monkeypatch.setattr(kops, "_BACKEND", "ref")
        ref, rpath = kops.pad_to(x, (4, 8))
        assert rpath == "ref"
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(back), x)

    def test_single_device_arrays_are_fusable(self):
        import jax

        from repro.kernels import ops as kops

        assert kops._fusable(np.ones((4, 4), np.float32))
        assert kops._fusable(jax.device_put(np.ones((4, 4), np.float32)))

    def test_impossible_direction_raises(self):
        from repro.kernels import ops as kops

        x = np.ones((4, 4), np.float32)
        with pytest.raises(ValueError):
            kops.pad_to(x, (2, 4))  # pad may never shrink
        with pytest.raises(ValueError):
            kops.strip_to(x, (8, 4))  # strip may never grow

    def test_spill_refill_replays_through_the_plan(self, engine, rng):
        """An explicit spill + data() replays the host payload through the
        session's cached relayout plan with the put donated."""
        ac = _ctx(engine, None)
        x = rng.standard_normal((6, 7)).astype(np.float32)
        h = ac.send(x)
        ac.wait()
        live = ac.session.resolve(h)
        gov = ac.session.memgov
        gov.spill(live)
        job = gov._in_flight.get(live.id)
        if job is not None:  # wait for the async copy-out to land
            assert job.event.wait(10)
        assert live.state == SPILLED
        np.testing.assert_array_equal(np.asarray(live.data())[:6, :7], x)
        assert live.state == MATERIALIZED
        assert gov._host_store.get(h.id) is None  # buffer donated back
        ac.stop()


# ---------------------------------------------------------------------------
# Stats plumbing
# ---------------------------------------------------------------------------


class TestStats:
    def test_summary_has_data_plane_keys(self, engine, rng):
        ac = _ctx(engine, None)
        ac.send(_mats(1, rng)[0])
        ac.wait()
        s = ac.stats.summary()
        for key in (
            "spill_copy_ns",
            "spill_overlap_ns",
            "transfer_queue_depth",
            "fused_relayouts",
        ):
            assert isinstance(s[key], int)
        ac.stop()

    def test_plan_cache_stats_report_fused_plans(self):
        from repro.core.relayout import RelayoutPlanCache

        stats = RelayoutPlanCache().stats()
        assert stats["fused_plans"] == 0
        assert set(stats) == {"hits", "misses", "plans", "fused_plans"}
