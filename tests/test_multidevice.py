"""Multi-device semantics, run in subprocesses so the forced host-device
count never leaks into this test process (smoke tests must see 1 device)."""

import os
import subprocess
import sys


HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def _run(script: str, marker: str, extra_env=None) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if extra_env:
        env.update(extra_env)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "multidevice", script)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    assert marker in proc.stdout, proc.stdout[-2000:]


def test_engine_worker_groups_and_distributed_linalg():
    _run("_engine_script.py", "MULTIDEVICE_ENGINE_OK")


def test_concurrent_sessions_overlap():
    _run("_concurrent_script.py", "MULTIDEVICE_CONCURRENT_OK")


def test_padded_sends_roundtrip_arbitrary_shapes():
    _run("_padding_script.py", "MULTIDEVICE_PADDING_OK")


def test_sharded_models_match_single_device():
    _run("_model_script.py", "MULTIDEVICE_MODEL_OK")


def test_queued_all_free_request_size_is_pinned():
    _run("_admission_script.py", "MULTIDEVICE_ADMISSION_OK")
