"""Public-API snapshot (DESIGN.md §9): ``repro.__all__`` is a contract.

An accidental addition, removal, or rename in the package's public surface
must fail here first — update the snapshot *deliberately*, in the same PR
that changes the surface, and record the change in DESIGN.md §9's migration
table if it affects callers.
"""

import repro

# The frozen v2 surface. Sorted; update deliberately (see module docstring).
PUBLIC_API = [
    "AlArray",
    "AlFuture",
    "AlMatrix",
    "AlchemistContext",
    "AlchemistEngine",
    "Eager",
    "ExecutionPolicy",
    "GRID",
    "LayoutSpec",
    "Pipelined",
    "PlacementRequest",
    "Planned",
    "REPLICATED",
    "ROW",
    "Session",
    "connect",
]


def test_public_api_snapshot():
    assert sorted(repro.__all__) == PUBLIC_API


def test_all_names_resolve():
    for name in repro.__all__:
        assert getattr(repro, name) is not None


def test_no_duplicate_exports():
    assert len(repro.__all__) == len(set(repro.__all__))


def test_version_is_v2():
    major = int(repro.__version__.split(".")[0])
    assert major >= 2
