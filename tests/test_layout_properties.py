"""Property-based tests for the cyclic-permutation machinery in
core/layouts.py (the Elemental block-cyclic emulation, DESIGN.md §2).

Invariants, across randomized shapes/shard counts/dtypes:

- ``cyclic_permutation(n, s)`` is a bijection on ``range(n)``;
- ``inverse_permutation`` really inverts it: permute ∘ unpermute = identity
  on arbitrary matrices (both orderings);
- shard assignment is genuinely cyclic: physical shard ``s`` holds logical
  rows ``s, s + n_shards, ...``.

Runs under hypothesis when installed (CI); the deterministic parametrized
cases below keep the invariants exercised everywhere else (the
tests/_hypothesis_compat.py shim skips only the property tests).
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.errors import LayoutError
from repro.core.layouts import cyclic_permutation, inverse_permutation

DTYPES = ["float32", "float64", "int32", "float16"]


def _assert_bijection(n: int, shards: int) -> None:
    perm = cyclic_permutation(n, shards)
    assert perm.shape == (n,)
    assert np.array_equal(np.sort(perm), np.arange(n))


def _assert_roundtrip(n: int, cols: int, shards: int, dtype: str, seed: int) -> None:
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((n, cols)) * 8).astype(dtype)
    perm = cyclic_permutation(n, shards)
    inv = inverse_permutation(perm)
    np.testing.assert_array_equal(x[perm][inv], x)  # permute ∘ unpermute
    np.testing.assert_array_equal(x[inv][perm], x)  # unpermute ∘ permute


# -- hypothesis properties --------------------------------------------------

@given(n=st.integers(min_value=1, max_value=512), shards=st.integers(min_value=1, max_value=16))
@settings(max_examples=200, deadline=None)
def test_cyclic_permutation_is_bijection(n, shards):
    _assert_bijection(n, shards)


@given(
    n=st.integers(min_value=1, max_value=256),
    cols=st.integers(min_value=1, max_value=8),
    shards=st.integers(min_value=1, max_value=16),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=100, deadline=None)
def test_permute_unpermute_identity(n, cols, shards, dtype, seed):
    _assert_roundtrip(n, cols, shards, dtype, seed)


@given(n=st.integers(min_value=1, max_value=256), shards=st.integers(min_value=1, max_value=16))
@settings(max_examples=100, deadline=None)
def test_assignment_is_cyclic(n, shards):
    """Physical position i holds logical row (i % block boundary walk):
    shard s gets rows s, s + shards, s + 2*shards, ... — Elemental's
    element-cyclic assignment, restricted to rows that exist."""
    perm = cyclic_permutation(n, shards)
    expected = [r for s in range(shards) for r in range(s, n, shards)]
    assert list(perm) == expected


@given(n=st.integers(min_value=1, max_value=64))
@settings(max_examples=50, deadline=None)
def test_single_shard_is_identity(n):
    assert np.array_equal(cyclic_permutation(n, 1), np.arange(n))


@given(n=st.integers(min_value=1, max_value=64), extra=st.integers(min_value=0, max_value=64))
@settings(max_examples=50, deadline=None)
def test_more_shards_than_rows_still_bijective(n, extra):
    _assert_bijection(n, n + extra if extra else n)


# -- deterministic fallbacks (run even without hypothesis) -------------------

@pytest.mark.parametrize(
    "n,shards", [(1, 1), (7, 3), (8, 4), (9, 4), (128, 16), (100, 7), (5, 11)]
)
def test_bijection_cases(n, shards):
    _assert_bijection(n, shards)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n,cols,shards", [(37, 5, 4), (64, 3, 8), (6, 2, 4)])
def test_roundtrip_cases(n, cols, shards, dtype):
    _assert_roundtrip(n, cols, shards, dtype, seed=0)


def test_nonpositive_shards_rejected():
    with pytest.raises(LayoutError):
        cyclic_permutation(8, 0)
    with pytest.raises(LayoutError):
        cyclic_permutation(8, -2)
