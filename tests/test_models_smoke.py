"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED variant of each family, run one forward/train step on CPU, assert
output shapes + no NaNs. Also decode steps and train/decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import InputShape, get_config, list_configs
from repro.core.sharding import single_device_mesh
from repro.models import build_model
from repro.models.registry import input_specs, make_batch
from repro.train import AdamW, constant, make_train_step

ARCHS = [a for a in list_configs() if a != "alchemist-svd"]
SMOKE_SHAPE = InputShape("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.fixture(scope="module")
def mesh():
    return single_device_mesh()


def _setup(arch, mesh):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
    return cfg, model, params, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch, mesh):
    cfg, model, params, batch = _setup(arch, mesh)
    with mesh:
        logits = jax.jit(model.forward)(params, batch)
    b = SMOKE_SHAPE.global_batch
    assert logits.shape[0] == b
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(logits).all()), f"{arch} produced non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, mesh):
    cfg, model, params, batch = _setup(arch, mesh)
    opt = AdamW(learning_rate=constant(1e-3), moment_dtype=cfg.optimizer_dtype)
    step = make_train_step(model, opt)
    with mesh:
        opt_state = opt.init(params)
        new_params, new_state, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # parameters actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, pq: acc or bool(jnp.any(pq[0] != pq[1])),
        jax.tree_util.tree_map(lambda a, b: (a, b), params, new_params),
        False,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    assert moved, f"{arch}: train step did not update parameters"
    assert int(new_state.step) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, mesh):
    cfg, model, params, _ = _setup(arch, mesh)
    with mesh:
        state = model.init_decode_state(2, 16)
        toks = jnp.array([[1], [2]], jnp.int32)
        step = jax.jit(model.decode_step)
        logits, state = step(params, state, toks)
        logits, state = step(params, state, toks)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(state.pos) == 2


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "olmoe-1b-7b", "mamba2-130m", "jamba-v0.1-52b"])
def test_train_decode_consistency_f32(arch, mesh):
    """Teacher-forced forward logits must equal step-by-step decode (f32).

    MoE configs get a drop-free capacity factor: with token dropping the
    two modes legitimately differ (different group sizes -> different drop
    patterns), so exact agreement is only contractual without drops.
    """
    cfg = dataclasses.replace(get_config(arch, smoke=True), compute_dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.num_experts))
        )
    model = build_model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab)
    with mesh:
        full = model.forward(params, {"tokens": toks})
        state = model.init_decode_state(2, 16)
        step = jax.jit(model.decode_step)
        outs = []
        for i in range(8):
            lg, state = step(params, state, toks[:, i : i + 1])
            outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full[:, :, : cfg.vocab]),
        np.asarray(dec[:, :, : cfg.vocab]),
        atol=2e-3,
    )


def test_sliding_window_restricts_context(mesh):
    """With window W, token t must be independent of tokens < t - W."""
    cfg = dataclasses.replace(
        get_config("qwen2-1.5b", smoke=True), compute_dtype="float32"
    )
    model = build_model(cfg, mesh, sliding_window=4)
    params = model.init(jax.random.PRNGKey(0))
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 7) % cfg.vocab)  # change a distant token
    with mesh:
        l1 = model.forward(params, {"tokens": t1})
        l2 = model.forward(params, {"tokens": t2})
    # last position attends to [8..11]; token 0 must not affect it
    np.testing.assert_allclose(
        np.asarray(l1[0, -1]), np.asarray(l2[0, -1]), atol=1e-5
    )
    # ...but an early position does differ (sanity that the edit mattered)
    assert not np.allclose(np.asarray(l1[0, 1]), np.asarray(l2[0, 1]), atol=1e-5)


def test_vlm_loss_masks_vision_positions(mesh):
    cfg = get_config("internvl2-26b", smoke=True)
    model = build_model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(2))
    with mesh:
        x, mask = model.embed_inputs(params, batch)
    tv = batch["vision_embeds"].shape[1]
    assert x.shape[1] == batch["tokens"].shape[1] + tv
    assert float(mask[:, :tv].sum()) == 0.0  # no loss on vision positions


def test_whisper_uses_frames_and_tokens(mesh):
    cfg = get_config("whisper-large-v3", smoke=True)
    specs = input_specs(cfg, SMOKE_SHAPE)
    assert set(specs) == {"frames", "tokens"}
    assert specs["frames"].shape == (2, cfg.encoder_seq, cfg.d_model)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_cover_params(arch, mesh):
    """Every param leaf must have a matching PartitionSpec leaf."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(0))
    specs = model.param_partition_specs()
    jax.tree_util.tree_map(lambda p, s: None, params, specs)  # structure match
    n_params = len(jax.tree_util.tree_leaves(params))
    assert n_params == len(jax.tree_util.tree_leaves(specs))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_config_formula(arch, mesh):
    """The analytic param_count used for MODEL_FLOPS must match the real
    parameter tree (within the pos-embed/adapters slack it ignores)."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(0))
    actual = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    predicted = cfg.param_count()
    assert abs(actual - predicted) / actual < 0.06, (
        f"{arch}: params {actual} vs formula {predicted}"
    )
