"""Memory governor tests (DESIGN.md §7): budget accounting, LRU + last-use
spill, transparent refill, pinning, reservations, the spilled handle state,
and the per-routine shape rules that price routine outputs.

Single-device here (divisibility pads are exercised on real worker groups in
tests/multidevice/); every matrix is 32x32 float32 = 4096 bytes, so budgets
read as whole matrix counts.
"""

import numpy as np
import pytest

import repro
from repro.core.errors import HandleError, ShapeError
from repro.core.expr import infer_run_shapes
from repro.core.handles import MATERIALIZED, SPILLED
from repro.core.memgov import MemoryGovernor

MAT = 32 * 32 * 4  # bytes of one 32x32 float32


@pytest.fixture()
def engine():
    return repro.AlchemistEngine()


def _ctx(engine, budget):
    ac = repro.AlchemistContext(engine, num_workers=1, name="gov", hbm_budget=budget)
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")
    return ac


def _mats(n, rng):
    return [rng.standard_normal((32, 32)).astype(np.float32) for _ in range(n)]


class TestAccounting:
    def test_unbudgeted_tracks_high_water_without_spilling(self, engine, rng):
        ac = _ctx(engine, None)
        hs = [ac.send(m) for m in _mats(3, rng)]
        ac.wait()
        s = ac.stats.summary()
        assert s["spills"] == 0 and s["refills"] == 0
        assert s["hbm_high_water"] == 3 * MAT
        assert all(h.state == MATERIALIZED for h in hs)
        ac.stop()

    def test_free_discharges_budget(self, engine, rng):
        ac = _ctx(engine, None)
        h = ac.send(_mats(1, rng)[0])
        ac.wait()
        assert ac.session.memgov.used == MAT
        ac.free(h)
        assert ac.session.memgov.used == 0
        ac.stop()

    def test_rejects_nonpositive_budget(self):
        with pytest.raises(ValueError):
            MemoryGovernor(budget=0)

    def test_physical_nbytes_equals_logical_when_unpadded(self, engine, rng):
        ac = _ctx(engine, None)
        h = ac.send(_mats(1, rng)[0])
        ac.wait()
        live = ac.session.resolve(h)
        assert live.pads == (0, 0)
        assert live.physical_nbytes() == live.nbytes() == MAT
        ac.stop()


class TestSpillRefill:
    def test_sends_beyond_budget_spill_lru(self, engine, rng):
        ac = _ctx(engine, 2 * MAT)
        mats = _mats(4, rng)
        hs = [ac.send(m) for m in mats]
        ac.wait()
        s = ac.stats.summary()
        assert s["spills"] == 2 and s["spilled_bytes"] == 2 * MAT
        assert s["hbm_high_water"] <= 2 * MAT
        # LRU: the two oldest sends were spilled, the two newest are resident
        states = [ac.session.resolve(h).state for h in hs]
        assert states == [SPILLED, SPILLED, MATERIALIZED, MATERIALIZED]
        ac.stop()

    def test_collect_of_spilled_serves_host_store_bit_exact(self, engine, rng):
        # Collect is client-bound: spilled bytes are served straight from the
        # host store (no refill, no eviction cascade) and stay spilled.
        ac = _ctx(engine, 2 * MAT)
        mats = _mats(4, rng)
        hs = [ac.send(m) for m in mats]
        for m, h in zip(mats, hs):
            np.testing.assert_array_equal(np.asarray(ac.collect(h)), m)
        s = ac.stats.summary()
        assert s["spills"] == 2 and s["refills"] == 0
        assert s["num_receives"] == 4  # every collect still recorded
        assert s["hbm_high_water"] <= 2 * MAT
        ac.stop()

    def test_compute_consumption_refills_bit_exact(self, engine, rng):
        # Engine-side consumption (a routine input) genuinely needs the bytes
        # on device: that is the refill path.
        ac = _ctx(engine, 2 * MAT)
        mats = _mats(4, rng)
        hs = [ac.send(m) for m in mats]
        for m, h in zip(mats, hs):
            norm = float(ac.run("elemental", "normest", h))
            assert abs(norm - np.linalg.norm(m)) < 1e-3
        s = ac.stats.summary()
        assert s["refills"] >= 2 and s["refilled_bytes"] >= 2 * MAT
        assert s["hbm_high_water"] <= 2 * MAT
        ac.stop()

    def test_spilled_handle_is_live_and_usable(self, engine, rng):
        ac = _ctx(engine, 2 * MAT)
        mats = _mats(3, rng)
        hs = [ac.send(m) for m in mats]
        ac.wait()
        first = ac.session.resolve(hs[0])
        assert first.state == SPILLED and first.is_live
        norm = float(ac.run("elemental", "normest", hs[0]))  # refill on use
        assert abs(norm - np.linalg.norm(mats[0])) < 1e-3
        assert ac.session.resolve(hs[0]).state == MATERIALIZED
        ac.stop()

    def test_free_spilled_handle_drops_host_store(self, engine, rng):
        ac = _ctx(engine, MAT)
        hs = [ac.send(m) for m in _mats(2, rng)]
        ac.wait()
        assert ac.session.memgov.snapshot()["spilled_handles"] == 1
        ac.free(hs[0])  # the spilled one
        assert ac.session.memgov.snapshot()["spilled_handles"] == 0
        with pytest.raises(HandleError):
            ac.collect(hs[0])
        ac.stop()

    def test_single_matrix_larger_than_budget_still_admitted(self, engine, rng):
        # Admission is best-effort: the governor bounds memory, it never
        # deadlocks the pipeline.
        ac = _ctx(engine, MAT // 2)
        m = _mats(1, rng)[0]
        np.testing.assert_array_equal(np.asarray(ac.collect(ac.send(m))), m)
        ac.stop()

    def test_run_inputs_pinned_not_spilled_by_outputs(self, engine, rng):
        ac = _ctx(engine, 3 * MAT)
        a, b = _mats(2, rng)
        ha, hb = ac.send(a), ac.send(b)
        hc = ac.run("elemental", "gemm", ha, hb)
        np.testing.assert_allclose(np.asarray(ac.collect(hc)), a @ b, atol=1e-4)
        s = ac.stats.summary()
        # a+b+output fit exactly: pinned inputs were never evicted mid-run
        assert s["spills"] == 0
        assert s["hbm_high_water"] <= 3 * MAT
        ac.stop()


class TestPlannerIntegration:
    def test_pipeline_2x_budget_identical_numerics(self, engine, rng):
        mats = _mats(6, rng)

        def run(budget):
            ac = _ctx(engine, budget)
            pl = ac.planner
            lazies = [pl.send(m, name=f"m{i}") for i, m in enumerate(mats)]
            outs = [np.asarray(pl.collect(la)) for la in lazies]
            # Second pass consumes each matrix engine-side (gemm against the
            # identity): under budget, the matrices spilled by the later
            # sends must refill here; collects alone would be served from
            # the host store.
            eye = np.eye(32, dtype=np.float32)
            outs2 = [np.asarray(pl.collect(la @ pl.send(eye))) for la in lazies]
            s = ac.stats.summary()
            ac.stop()
            return outs + outs2, s

        outs_free, s_free = run(None)
        outs_cap, s_cap = run(3 * MAT)
        for x, y in zip(outs_free, outs_cap):
            np.testing.assert_array_equal(x, y)
        # unbudgeted: everything stays resident (6 sends + eye + 6 products)
        assert s_free["spills"] == 0 and s_free["hbm_high_water"] >= 2 * (3 * MAT)
        assert s_cap["spills"] > 0 and s_cap["refills"] > 0
        assert s_cap["hbm_high_water"] <= 3 * MAT

    def test_last_use_hint_prefers_dead_intermediates(self, engine, rng):
        ac = _ctx(engine, None)
        pl = ac.planner
        a, b = _mats(2, rng)
        lc = pl.run("elemental", "gemm", pl.send(a), pl.send(b))
        ld = pl.run("elemental", "gemm", lc, pl.send(np.eye(32, dtype=np.float32)))
        pl.collect(ld)
        memgov = ac.session.memgov
        # lc was consumed by ld (its only consumer): hinted as idle. ld is the
        # root (still collectible): not hinted.
        h_lc = pl.materialize(lc)
        h_ld = pl.materialize(ld)
        assert h_lc.id in memgov._idle
        assert h_ld.id not in memgov._idle
        ac.stop()

    def test_spilled_resident_reuse_still_elides(self, engine, rng):
        ac = _ctx(engine, 2 * MAT)
        pl = ac.planner
        mats = _mats(3, rng)
        for m in mats:
            pl.collect(pl.send(m))  # fill + spill pressure
        # re-sending the first payload hits the resident cache even though
        # its matrix was spilled: no bridge bytes, refill on consumption
        sends_before = ac.stats.num_sends
        out = np.asarray(pl.collect(pl.send(mats[0])))
        np.testing.assert_array_equal(out, mats[0])
        assert ac.stats.num_sends == sends_before
        assert ac.stats.resident_reuses >= 1
        ac.stop()

    def test_offloaded_context_budget_override(self, engine, rng):
        from repro.sparklike import offload

        ac = _ctx(engine, None)
        with offload.offloaded(ac, hbm_budget=2 * MAT) as pl:
            assert ac.session.memgov.budget == 2 * MAT
            lazies = [pl.send(m) for m in _mats(4, rng)]
            for la in lazies:
                pl.collect(la)
        assert ac.session.memgov.budget is None  # restored
        assert ac.stats.summary()["spills"] > 0
        ac.stop()

    def test_lazy_row_matrix_state_surfaces_spill(self, engine, rng):
        from repro.sparklike import offload

        ac = _ctx(engine, MAT)
        pl = ac.planner
        m1, m2 = _mats(2, rng)
        lrm = offload.LazyRowMatrix(pl.send(m1), 32, 32)
        assert lrm.state == "deferred"
        np.testing.assert_array_equal(lrm.to_numpy(), m1)
        assert lrm.state == "materialized"
        pl.collect(pl.send(m2))  # evicts m1 under the 1-matrix budget
        assert lrm.state == "spilled"
        np.testing.assert_array_equal(lrm.to_numpy(), m1)  # refill
        ac.stop()


class TestReservations:
    def test_send_async_reserves_then_converts(self, engine, rng):
        ac = _ctx(engine, None)
        memgov = ac.session.memgov
        fut = ac.send_async(_mats(1, rng)[0])
        fut.result(30)
        ac.wait()
        assert memgov.reserved == 0  # converted to a charge
        assert memgov.used == MAT
        ac.stop()

    def test_failed_send_releases_reservation(self, engine, monkeypatch):
        import repro.core.client as client_mod

        ac = _ctx(engine, None)

        def boom(*a, **k):
            raise RuntimeError("transfer died")

        monkeypatch.setattr(client_mod, "timed_relayout", boom)
        f = ac.send_async(np.zeros((32, 32), dtype=np.float32))
        with pytest.raises(RuntimeError):
            f.result(30)
        assert ac.session.memgov.reserved == 0
        assert ac.session.memgov.used == 0
        ac.stop()

    def test_pressure_forecast(self, engine):
        gov = MemoryGovernor(budget=10 * MAT)
        n = gov.reserve(3 * MAT)
        assert gov.pressure() == 3 * MAT
        gov.unreserve(n)
        assert gov.pressure() == 0

    def test_planner_reservations_price_declared_dtype(self, engine, rng):
        # Output reservations must price the operands' declared itemsize even
        # when they reach the engine as unresolved futures (the planner path)
        # — handle charges are metadata-priced, so a mismatched default would
        # let admission drift from the ledger. (jax downcasts f64 host arrays
        # to f32 on device without x64 mode; the *accounting* contract — high
        # water bounded by the budget — must hold regardless.)
        mat64 = 32 * 32 * 8
        ac = _ctx(engine, 3 * mat64)
        pl = ac.planner
        a = rng.standard_normal((32, 32))  # float64 metadata
        b = rng.standard_normal((32, 32))
        c = pl.run("elemental", "gemm", pl.send(a), pl.send(b))
        d = pl.run("elemental", "gemm", c, pl.send(np.eye(32)))
        np.testing.assert_allclose(np.asarray(pl.collect(d)), a @ b, atol=1e-3)
        s = ac.stats.summary()
        assert s["hbm_high_water"] <= 3 * mat64, s
        ac.stop()


class TestShapeRules:
    def test_gemm_mismatch_raises_client_side(self, engine, rng):
        ac = _ctx(engine, None)
        ha = ac.send(rng.standard_normal((8, 4)).astype(np.float32))
        hb = ac.send(rng.standard_normal((8, 4)).astype(np.float32))
        with pytest.raises(ShapeError, match="inner dimensions"):
            ac.run("elemental", "gemm", ha, hb)
        ac.stop()

    def test_rules_cover_every_elemental_routine(self):
        from repro.core.expr import SHAPE_RULES
        from repro.linalg.library import ElementalLib

        lib = ElementalLib()
        missing = [r for r in lib.routine_names() if r not in SHAPE_RULES]
        assert not missing, f"routines without a shape rule: {missing}"

    @pytest.mark.parametrize(
        "routine,shapes,params,expected",
        [
            ("gemm", [(6, 4), (4, 3)], {}, ((6, 3),)),
            ("multiply", [(2, 5), (5, 2)], {}, ((2, 2),)),
            ("truncated_svd", [(16, 8)], {"k": 4}, ((16, 4), (4,), (8, 4))),
            ("randomized_svd", [(16, 8)], {"k": 8}, ((16, 8), (8,), (8, 8))),
            ("pca", [(32, 8)], {"k": 2}, ((8, 2), (32, 2), (2,))),
            ("tsqr", [(32, 8)], {}, ((32, 8), (8, 8))),
            ("ridge", [(16, 4), (16, 1)], {}, ((4, 1),)),
            ("normest", [(8, 8)], {}, ((),)),
            ("condest", [(8, 8)], {}, ((),)),
            ("sigma_max", [(8, 8)], {}, ((),)),
        ],
    )
    def test_rule_outputs(self, routine, shapes, params, expected):
        assert infer_run_shapes(routine, shapes, params) == expected

    @pytest.mark.parametrize(
        "routine,shapes,params",
        [
            ("gemm", [(6, 4), (3, 6)], {}),
            ("truncated_svd", [(16, 8)], {"k": 9}),
            ("truncated_svd", [(16, 8)], {"k": 0}),
            ("pca", [(4, 4)], {"k": 40}),
            ("tsqr", [(8, 32)], {}),  # wide, not tall-skinny
            ("ridge", [(16, 4), (15, 1)], {}),
        ],
    )
    def test_rule_rejections(self, routine, shapes, params):
        with pytest.raises(ShapeError):
            infer_run_shapes(routine, shapes, params)

    def test_unknown_shapes_stay_silent(self):
        assert infer_run_shapes("gemm", [None, (4, 3)], {}) == (None,)
        assert infer_run_shapes("not_a_routine", [(4, 3)], {}) is None

    def test_svd_without_keyword_k_stays_silent(self):
        # k not passed as a keyword (library default, or positional — which
        # the keyword-only adapters reject at execution): the rule must not
        # validate against an invented default.
        assert infer_run_shapes("truncated_svd", [(8, 8)], {}) == (None, None, None)
        assert infer_run_shapes("pca", [(4, 4)], {}) == (None, None, None)

    def test_arg_dtype_recurses_through_chained_runs(self, engine, rng):
        # Pricing must find the leaf dtype even when every direct operand of
        # a RunExpr is itself a deferred run/projection (f64 chains would
        # otherwise fall back to the f32 default and under-admit).
        from repro.core.planner import OffloadPlanner

        ac = _ctx(engine, None)
        pl = ac.planner
        a = pl.send(rng.standard_normal((8, 8)))  # float64 metadata
        c = a @ a
        d = c @ c  # args: RunExprs only
        assert OffloadPlanner._arg_dtype(d.expr) == "float64"
        q, r = pl.run("elemental", "tsqr", c, n_outputs=2)
        prod = pl.run("elemental", "gemm", q, r)  # args: ProjExprs only
        assert OffloadPlanner._arg_dtype(prod.expr) == "float64"
        ac.stop()

    def test_set_budget_serialized_and_validated(self, engine):
        gov = MemoryGovernor(budget=4 * MAT)
        with pytest.raises(ValueError):
            gov.set_budget(-1)
        gov.set_budget(None)  # admissions snapshot the budget: None = no-op
        assert gov.admit(10 * MAT) == 0

    def test_lazy_chain_shapes_propagate(self, engine, rng):
        ac = _ctx(engine, None)
        pl = ac.planner
        a = rng.standard_normal((32, 8)).astype(np.float32)
        u, s, v = pl.run("elemental", "truncated_svd", pl.send(a), n_outputs=3, k=4)
        assert u.shape == (32, 4) and v.shape == (8, 4)
        proj = pl.send(a) @ v  # (32, 8) @ (8, 4) validates at build time
        assert proj.shape == (32, 4)
        with pytest.raises(ShapeError):
            _ = u @ pl.send(a)  # (32, 4) @ (32, 8): inner mismatch
        ac.stop()
