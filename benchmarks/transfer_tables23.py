"""Paper Tables 2–3: 400 GB matrix transfer times, tall-skinny vs short-wide,
across Spark/Alchemist node splits.

Paper finding: the tall-skinny matrix (5.12e6 x 1e4) transfers *slower and
with more variance* than the short-wide one (4e4 x 1.28e6) at equal bytes,
because the wire format streams row-at-a-time — more rows = more messages.
Short-wide times improve steadily with more Alchemist nodes.

TPU adaptation (DESIGN.md §2): the relayout's analytic cost model exposes
the same mechanics fabric-natively — message counts and per-message sizes of
the ROW->GRID redistribution. We sweep worker-grid sizes at the paper's
exact matrix shapes (no allocation needed: the model is geometric) and
report bytes moved, messages, row-fragments (the per-row-send analogue),
and the ICI lower-bound seconds.

A small measured companion runs real relayouts at container scale to tie
the model to wall-clock.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

import repro
from benchmarks.common import MeshShim, csv_row
from repro.core.layouts import GRID, ROW
from repro.core.relayout import transfer_cost

TALL = (5_120_000, 10_000)   # paper Table 2
WIDE = (40_000, 1_280_000)   # paper Table 3
GRIDS = [(8, 8), (8, 16), (16, 16), (16, 32)]  # worker grids to sweep


def run(report: List[str], metrics: Optional[Dict] = None) -> None:
    # --- analytic sweep at the paper's exact 400 GB shapes -----------------
    for label, shape in (("tall", TALL), ("wide", WIDE)):
        for r, c in GRIDS:
            mesh = MeshShim(shape=(r, c), axis_names=("data", "model"))
            cost = transfer_cost(shape, "float64", ROW, GRID, mesh)
            name = f"transfer_t23_{label}_{r}x{c}"
            derived = (
                f"GB_moved={cost.bytes_moved/1e9:.1f};messages={cost.messages};"
                f"row_fragments={cost.row_fragments};"
                f"max_msg_MB={cost.max_message_bytes/1e6:.2f};"
                f"ici_lower_bound_s={cost.ici_seconds():.2f}"
            )
            report.append(csv_row(name, cost.ici_seconds() * 1e6, derived))

    # --- measured companion at container scale -----------------------------
    engine = repro.AlchemistEngine()
    rng = np.random.default_rng(2)
    for label, (m, n) in (("tall", (16_384, 64)), ("wide", (64, 16_384))):
        a = rng.standard_normal((m, n)).astype(np.float32)
        ac = repro.AlchemistContext(engine, name=f"transfer_{label}")
        t0 = time.perf_counter()
        h = ac.send(a)
        t_send = time.perf_counter() - t0
        rec = ac.stats.transfers[-1]
        name = f"transfer_measured_{label}_{m}x{n}"
        derived = (
            f"send_s={t_send:.4f};bytes={rec.cost.bytes_total};devices=1"
        )
        report.append(csv_row(name, t_send * 1e6, derived))
        ac.stop()
