"""Async task-queue engine: synchronous vs pipelined send→run→collect.

The paper's core overhead claim (§2, §3.3) is that Alchemist overlaps client
transfers with MPI compute. The task-queue engine (DESIGN.md §3) makes that
claim measurable in-process:

- ``sync``      — the paper-listing loop: send, run, collect, each blocking.
- ``pipelined`` — the same work through ``send_async``/``run_async``/
  ``collect_async``: every stage is queued at once, transfers stage while
  the previous round's routine still computes, and only the final collect
  waits.

Also reported: the relayout plan-cache hit rate (DESIGN.md §5) — repeated
same-shape transfers skip re-deriving shard geometry — and, when the host
exposes >= 2 devices (e.g. under ``--xla_force_host_platform_device_count``),
the two-session overlap of concurrent transfer streams on disjoint worker
groups.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import jax
import numpy as np

import repro
from benchmarks.common import csv_row

ROUNDS = 6
SHAPE = (1024, 1024)


def _pipeline_workload(ac, mats) -> None:
    last = None
    for m in mats:
        f = ac.send_async(m)
        g = ac.run_async("elemental", "gemm", f, f)
        last = ac.collect_async(g)
    last.result(600)


def _sync_workload(ac, mats) -> None:
    for m in mats:
        h = ac.send(m)
        g = ac.run("elemental", "gemm", h, h)
        ac.collect(g)


def _best_of(fn, repeats: int = 3) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(min(times))


def run(report: List[str], metrics: Optional[Dict] = None) -> None:
    rng = np.random.default_rng(7)
    engine = repro.AlchemistEngine()
    n = SHAPE[0]
    mats = [
        (rng.standard_normal(SHAPE) / np.sqrt(n)).astype(np.float32)
        for _ in range(ROUNDS)
    ]

    # --- single-session: sync vs pipelined ---------------------------------
    ac = repro.AlchemistContext(engine, num_workers=1, name="overlap_bench")
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")
    _sync_workload(ac, mats)  # warm jit + relayout plans (persistent server)

    t_sync = _best_of(lambda: _sync_workload(ac, mats))
    t_pipe = _best_of(lambda: _pipeline_workload(ac, mats))

    s = ac.stats.summary()
    hits, misses = s["relayout_cache_hits"], s["relayout_cache_misses"]
    hit_rate = hits / max(hits + misses, 1)
    ac.stop()

    derived = (
        f"sync_s={t_sync:.3f};pipelined_s={t_pipe:.3f};"
        f"speedup={t_sync / max(t_pipe, 1e-9):.2f}x;"
        f"rounds={ROUNDS};shape={SHAPE[0]}x{SHAPE[1]};"
        f"relayout_cache_hits={hits};relayout_cache_misses={misses};"
        f"relayout_cache_hit_rate={hit_rate:.3f}"
    )
    report.append(csv_row("overlap_async_pipeline", t_pipe * 1e6 / ROUNDS, derived))

    # --- two sessions on disjoint worker groups (needs >= 2 devices) -------
    if len(jax.devices()) < 2:
        report.append(
            csv_row("overlap_async_sessions", 0.0, "skipped=single_device_host")
        )
        return

    b1 = repro.AlchemistContext(engine, num_workers=1, name="overlap_s1")
    b2 = repro.AlchemistContext(engine, num_workers=1, name="overlap_s2")
    for b in (b1, b2):
        b.register_library("elemental", "repro.linalg.library:ElementalLib")

    # bigger operands: transfer streams need to dwarf per-call dispatch for
    # the cross-session overlap to be visible (16 MB each, as in
    # tests/multidevice/_concurrent_script.py)
    big = (rng.standard_normal((2048, 2048)) / 45.0).astype(np.float32)
    xfer_mats = [big] * ROUNDS

    def xfer(ac):
        last = None
        for m in xfer_mats:
            last = ac.collect_async(ac.send_async(m))
        last.result(600)

    xfer(b1)
    xfer(b2)  # warm
    t_serial = _best_of(lambda: xfer(b1)) + _best_of(lambda: xfer(b2))

    def concurrent():
        ts = [threading.Thread(target=xfer, args=(b,)) for b in (b1, b2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()

    t_conc = _best_of(concurrent)
    b1.stop()
    b2.stop()

    derived = (
        f"serial_s={t_serial:.3f};concurrent_s={t_conc:.3f};"
        f"overlap_ratio={t_conc / max(t_serial, 1e-9):.2f};"
        f"rounds={ROUNDS};shape=2048x2048"
    )
    report.append(csv_row("overlap_async_sessions", t_conc * 1e6 / ROUNDS, derived))
