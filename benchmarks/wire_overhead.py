"""Wire framing overhead vs loopback (DESIGN.md §11).

The TCP transport must not change *what* crosses the bridge — only wrap it
in frames. This suite runs one fixed workload (a handful of sends, a gemm,
a collect) under both transports and reports:

- ``framing_overhead`` — (framed bytes − payload bytes) / payload bytes for
  the loopback array framing: pure protocol tax (ALWF headers + chunk
  length prefixes) over the matrix bytes themselves. Analytic: derived from
  matrix shapes and CHUNK_BYTES, identical on every host — gated in CI
  (check_regression.py), where a jump means the framing genuinely got
  fatter, never a noisy runner.
- ``tcp_overhead`` — the same ratio for the full TCP exchange, control
  frames included (CONNECT/RUN/FETCH/... metadata on top of the arrays).
- ``bridge_parity_ok`` — 1 if the engine-side session byte counters
  (send/recv bytes and counts) are identical under both transports: the
  socket adds framing, never bridge traffic.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import csv_row, timeit

M, K, N = 256, 192, 128
PAYLOADS = 3  # two sends + one collected product


def _workload(transport):
    import repro

    rng = np.random.default_rng(17)
    a = rng.standard_normal((M, K)).astype(np.float32)
    b = rng.standard_normal((K, N)).astype(np.float32)
    engine = repro.AlchemistEngine()
    s = repro.connect(engine, transport=transport)
    s.register_library("elemental", "repro.linalg.library:ElementalLib")
    out = s.collect(s.run("elemental", "gemm", s.send(a), s.send(b)))
    np.asarray(out)
    bridge = {
        k: v
        for k, v in s.stats.summary().items()
        if k in ("send_bytes", "recv_bytes", "num_sends", "num_receives")
    }
    ws = s.transport.wire_stats()
    s.close()
    return bridge, ws


def run(report: List[str], metrics: Dict[str, Dict]) -> None:
    payload_bytes = (M * K + K * N + M * N) * 4  # the 3 f32 arrays that cross

    loop_bridge, loop_ws = _workload("loopback")
    framed = loop_ws["bytes_sent"]
    framing_overhead = (framed - payload_bytes) / payload_bytes

    tcp_bridge, tcp_ws = _workload("tcp")
    tcp_total = tcp_ws["bytes_sent"] + tcp_ws["bytes_received"]
    tcp_overhead = (tcp_total - payload_bytes) / payload_bytes

    parity_ok = int(loop_bridge == tcp_bridge)

    us = timeit(lambda: _workload("tcp"), repeats=3, warmup=1) * 1e6

    report.append(
        csv_row(
            "wire_tcp_workload",
            us,
            f"framing_overhead={framing_overhead:.4f} "
            f"tcp_overhead={tcp_overhead:.4f} parity={parity_ok}",
        )
    )
    metrics["wire"] = {
        "payload_bytes": payload_bytes,
        "loopback_framed_bytes": framed,
        "framing_overhead": round(framing_overhead, 6),
        "tcp_wire_bytes": tcp_total,
        "tcp_overhead": round(tcp_overhead, 6),
        "bridge_parity_ok": parity_ok,
    }
