"""Async data plane: how much spill copy-out hides behind compute (DESIGN.md §10).

The Alchemist papers price the bridge by what data movement adds to the
*critical path* (arXiv:1806.01270 Table 1; arXiv:1910.01354 throughout).
PR 6's transfer executor moves spill copy-outs off the session's queue
worker, so the next task's compute should hide the previous victim's D2H.
This benchmark measures exactly that:

- run the spill_pressure working set (2× overcommit) on an ``async_spill``
  engine and on a synchronous-baseline engine (``async_spill=False``);
- **overlap ratio** = ``spill_overlap_ns / spill_copy_ns`` — of the wall
  time the transfer ring spent streaming victims to host, the fraction
  during which the owning session's queue worker was simultaneously
  executing tasks. 0 = every copy ran on an idle engine (nothing hidden),
  1 = every copy was fully hidden behind queued work;
- the contract asserts: numerics bit-identical across the two engines,
  ``spill_copy_ns > 0`` on the async run (copies really rode the ring),
  structurally zero on the sync run, and ratio > 0.5 — the CI gate floors
  the ratio via BENCH_baseline.json.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro
from benchmarks.common import csv_row

M, N = 512, 256
N_MATS = 8
MAT_BYTES = M * N * 4
BUDGET = 4 * MAT_BYTES  # holds half the working set: every run spills


def _dataset() -> List[np.ndarray]:
    rng = np.random.default_rng(11)
    return [rng.standard_normal((M, N)).astype(np.float32) for _ in range(N_MATS)]


_DATA = _dataset()


def _pipeline(ac) -> Tuple[List[np.ndarray], List[float]]:
    """Send burst → normest pass → collect: the same shape as spill_pressure,
    chosen because the send burst spills early matrices *while the worker is
    still staging later ones* — the overlap the ring exists to create."""
    pl = ac.planner
    lazies = [pl.send(m, name=f"m{i}") for i, m in enumerate(_DATA)]
    for la in lazies:
        pl.lower(la)
    ac.wait()
    norms = [float(pl.collect(pl.run("elemental", "normest", la))) for la in lazies]
    outs = [np.asarray(pl.collect(la)) for la in lazies]
    return outs, norms


def _run_once(async_spill: bool, tag: str):
    engine = repro.AlchemistEngine(share_residents=False, async_spill=async_spill)
    ac = repro.AlchemistContext(engine, name=f"ov_{tag}", hbm_budget=BUDGET)
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")
    t0 = time.perf_counter()
    outs, norms = _pipeline(ac)
    dt = time.perf_counter() - t0
    stats = ac.stats.summary()
    snap = engine.memgov.snapshot()
    ac.stop()
    return outs, norms, stats, snap, dt


def run(report: List[str], metrics: Optional[Dict] = None) -> None:
    _run_once(True, "warm")  # warm jit/relayout caches off the record

    outs_a, norms_a, s_a, snap_a, t_a = _run_once(True, "async")
    outs_s, norms_s, s_s, _snap_s, t_s = _run_once(False, "sync")

    # Bit-identical numerics: the async plane moves bytes, never values.
    for a, b in zip(outs_a, outs_s):
        np.testing.assert_array_equal(a, b)
    assert norms_a == norms_s, (norms_a, norms_s)

    # The sync baseline must be structurally copy-silent (only ring copies
    # record), and the async run must have actually used the ring.
    assert s_s["spill_copy_ns"] == 0 and s_s["spill_overlap_ns"] == 0, s_s
    assert s_a["spills"] > 0 and s_a["spill_copy_ns"] > 0, s_a
    assert s_a["transfer_queue_depth"] >= 1, s_a

    ratio = s_a["spill_overlap_ns"] / s_a["spill_copy_ns"]
    assert 0.0 <= ratio <= 1.0, ratio
    assert ratio > 0.5, (
        f"spill copy-outs were not hidden behind compute: overlap ratio "
        f"{ratio:.3f} <= 0.5 (copy={s_a['spill_copy_ns']}ns, "
        f"overlap={s_a['spill_overlap_ns']}ns)"
    )

    derived = (
        f"overlap_ratio={ratio:.3f};"
        f"copy_ms={s_a['spill_copy_ns'] / 1e6:.2f};"
        f"overlap_ms={s_a['spill_overlap_ns'] / 1e6:.2f};"
        f"ring_depth={s_a['transfer_queue_depth']};"
        f"staging_reuses={snap_a['staging_reuses']};"
        f"spills={s_a['spills']};refills={s_a['refills']};"
        f"async_s={t_a:.3f};sync_s={t_s:.3f}"
    )
    report.append(csv_row("overlap_spill", t_a * 1e6, derived))
    if metrics is not None:
        metrics["overlap_spill"] = {
            "overlap_ratio": round(ratio, 4),
            "spill_copy_ns": s_a["spill_copy_ns"],
            "spill_overlap_ns": s_a["spill_overlap_ns"],
            "transfer_queue_depth": s_a["transfer_queue_depth"],
            "staging_reuses": snap_a["staging_reuses"],
            "spills": s_a["spills"],
            "refills": s_a["refills"],
            "async_seconds": t_a,
            "sync_seconds": t_s,
            "budget_bytes": BUDGET,
            "working_set_bytes": N_MATS * MAT_BYTES,
        }
