"""Shared benchmark utilities."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Tuple

import numpy as np


@dataclasses.dataclass
class MeshShim:
    """Stand-in with the (axis_names, devices.shape) interface that the
    analytic transfer-cost model needs — lets the Tables 2–3 benchmark sweep
    Cori-scale node counts on a 1-CPU container without building real
    device meshes."""

    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]

    class _Dev:
        def __init__(self, shape):
            self.shape = shape
            n = 1
            for s in shape:
                n *= s
            self.size = n

    @property
    def devices(self):
        return MeshShim._Dev(self.shape)


def timeit(fn: Callable[[], None], *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds over repeats."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
