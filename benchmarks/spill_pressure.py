"""Memory governor under pressure: working set ≥2× the HBM budget (DESIGN.md §7).

The deployment follow-up to Alchemist (arXiv:1910.01354) flags worker-side
memory as the limiting factor for long offload pipelines: every resident
matrix pins HBM until an explicit free. This benchmark drives a planned
pipeline whose resident working set is ~2× the configured budget and checks
the governor's contract:

- the pipeline **completes** with numerics bitwise-identical to the same
  pipeline on an unbudgeted session (spill/refill moves bytes, never values);
- ``spills > 0`` and ``refills > 0`` — pressure actually exercised the
  host store;
- ``hbm_high_water ≤ budget`` — admission kept the charged footprint bounded;
- a 6×6 send to a 4-worker session round-trips exactly (the padded-send path
  that used to fail outright), whenever the host exposes ≥4 devices.

Reported metrics feed the CI benchmark gate (BENCH_ci.json).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro
from benchmarks.common import csv_row

# 8 resident matrices of M×N f32; budget holds 4 of them — a 2× overcommit.
M, N = 512, 256
N_MATS = 8
MAT_BYTES = M * N * 4
BUDGET = 4 * MAT_BYTES


def _dataset() -> List[np.ndarray]:
    rng = np.random.default_rng(7)
    return [rng.standard_normal((M, N)).astype(np.float32) for _ in range(N_MATS)]


def _pipeline(ac, mats: List[np.ndarray]) -> Tuple[List[np.ndarray], List[float], Dict]:
    """Send the whole working set up front, then consume every matrix
    engine-side (Frobenius norm) and collect it. Under a budget, the send
    burst spills the early matrices, the norm pass refills them (compute
    needs the bytes on device), and the collects of whatever is spilled at
    that point are served from the host store."""
    pl = ac.planner
    lazies = [pl.send(m, name=f"m{i}") for i, m in enumerate(mats)]
    for la in lazies:
        pl.lower(la)  # dispatch all sends: the full working set hits residency
    ac.wait()
    norms = [
        float(pl.collect(pl.run("elemental", "normest", la))) for la in lazies
    ]
    outs = [np.asarray(pl.collect(la)) for la in lazies]
    return outs, norms, ac.stats.summary()


def _run_once(engine, budget: Optional[int], tag: str):
    ac = repro.AlchemistContext(engine, name=f"spill_{tag}", hbm_budget=budget)
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")
    t0 = time.perf_counter()
    outs, norms, stats = _pipeline(ac, _DATA)
    dt = time.perf_counter() - t0
    backlog = ac.session.tasks.stats()["max_backlog"]
    ac.stop()
    return outs, norms, stats, dt, backlog


_DATA = _dataset()


def _padded_roundtrip(engine) -> str:
    """The 6×6-to-4-workers acceptance case; needs a 4-device worker group."""
    if engine.available_workers < 4:
        return "skipped(<4 devices)"
    ac = repro.AlchemistContext(engine, num_workers=4, name="spill_pad")
    a = np.arange(36, dtype=np.float32).reshape(6, 6)
    got = np.asarray(ac.collect(ac.send(a)))
    ac.stop()
    assert np.array_equal(got, a), "6x6 padded send did not round-trip exactly"
    return "exact"


def run(report: List[str], metrics: Optional[Dict] = None) -> None:
    # Session-scoped residency on purpose: the warm/unbudgeted/budgeted runs
    # reuse one dataset, and engine-level content sharing (DESIGN.md §8)
    # would turn the later runs' sends into attaches — this suite must keep
    # measuring the governor under genuine send pressure. The shared-budget
    # multi-tenant case lives in benchmarks/cross_session.py.
    engine = repro.AlchemistEngine(share_residents=False)

    # Warm the jit/relayout caches so the timed passes compare fairly.
    _run_once(engine, None, "warm")

    outs_free, norms_free, s_free, t_free, _ = _run_once(engine, None, "unbudgeted")
    outs_cap, norms_cap, s_cap, t_cap, backlog = _run_once(engine, BUDGET, "budgeted")

    # The contract: identical numerics, actual spills, bounded high water.
    for a, b in zip(outs_free, outs_cap):
        np.testing.assert_array_equal(a, b)
    assert norms_free == norms_cap, (norms_free, norms_cap)
    assert s_cap["spills"] > 0 and s_cap["refills"] > 0, s_cap
    assert s_cap["hbm_high_water"] <= BUDGET, (s_cap["hbm_high_water"], BUDGET)
    # The unbudgeted session must have genuinely overcommitted the budget —
    # otherwise this benchmark is not testing pressure at all.
    assert s_free["hbm_high_water"] >= 2 * BUDGET, s_free["hbm_high_water"]
    assert s_free["spills"] == 0, s_free

    pad = _padded_roundtrip(engine)

    derived = (
        f"budget_MB={BUDGET / 1e6:.2f};working_set_MB={N_MATS * MAT_BYTES / 1e6:.2f};"
        f"unbudgeted_s={t_free:.3f};budgeted_s={t_cap:.3f};"
        f"spills={s_cap['spills']};refills={s_cap['refills']};"
        f"spilled_MB={s_cap['spilled_bytes'] / 1e6:.2f};"
        f"high_water_MB={s_cap['hbm_high_water'] / 1e6:.2f};"
        f"free_high_water_MB={s_free['hbm_high_water'] / 1e6:.2f};"
        f"queue_backlog={backlog};padded_6x6={pad}"
    )
    report.append(csv_row("spill_pressure", t_cap * 1e6, derived))
    if metrics is not None:
        metrics["spill"] = {
            "budget_bytes": BUDGET,
            "working_set_bytes": N_MATS * MAT_BYTES,
            "spills": s_cap["spills"],
            "refills": s_cap["refills"],
            "spilled_bytes": s_cap["spilled_bytes"],
            "hbm_high_water": s_cap["hbm_high_water"],
            "unbudgeted_high_water": s_free["hbm_high_water"],
            "budgeted_seconds": t_cap,
            "unbudgeted_seconds": t_free,
        }
