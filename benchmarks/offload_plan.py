"""Lazy offload planner: naive round-trip vs planned execution (DESIGN.md §6).

arXiv:1805.11800's cautionary measurement: Alchemist's speedup evaporates
when an application collects results back to Spark between every offloaded
call. This benchmark runs the same chained pipeline both ways and reports
bytes over the bridge plus wall clock:

- ``naive``   — every routine is a full send→run→collect round trip: each
  intermediate is collected client-side and re-sent to the next call, and
  the dataset is re-shipped whenever a step "loads" it again.
- ``planned`` — the identical DAG through ``ac.planner``: intermediates stay
  engine-resident (elided crossings), repeat sends of the same payload hit
  the content-keyed resident-matrix cache, and one collect materializes the
  final result.

The pipeline is the pca_offload example's shape, scaled so intermediates
dominate: PCA of A, projection of A onto the components, then a Gram matrix
of the projection — three chained routines, two large intermediates.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro
from benchmarks.common import csv_row

M, N, K = 2048, 512, 16


def _dataset() -> np.ndarray:
    rng = np.random.default_rng(11)
    lowrank = rng.standard_normal((M, K)) @ rng.standard_normal((K, N))
    return (lowrank + 0.1 * rng.standard_normal((M, N))).astype(np.float32)


def _naive(ac, a: np.ndarray) -> Tuple[np.ndarray, float, Dict]:
    """Round trip per routine — the 1805.11800 anti-pattern."""
    # step 1: PCA — send the dataset, collect the components
    h_a = ac.send(a, name="A")
    h_comps, _, _ = ac.run("elemental", "pca", h_a, k=K)
    comps = np.asarray(ac.collect(h_comps))            # bridge: recv
    # step 2: projection — the client re-loads the dataset and re-sends the
    # components it just collected
    h_a2 = ac.send(a, name="A_again")                  # bridge: send (dup)
    h_comps2 = ac.send(comps, name="comps")            # bridge: send (round trip)
    proj = np.asarray(ac.collect(ac.run("elemental", "gemm", h_a2, h_comps2)))
    # step 3: norm of the projection — re-send what was just collected
    h_proj = ac.send(proj, name="proj")                # bridge: send (round trip)
    norm = float(ac.run("elemental", "normest", h_proj))
    return proj, norm, ac.stats.summary()


def _planned(ac, a: np.ndarray) -> Tuple[np.ndarray, float, Dict]:
    """The same DAG through the lazy planner: collect once."""
    pl = ac.planner
    la = pl.send(a, name="A")
    comps, _, _ = pl.run("elemental", "pca", la, n_outputs=3, k=K)
    la2 = pl.send(a, name="A_again")                   # dedup: resident reuse
    proj = pl.run("elemental", "gemm", la2, comps)     # comps stays resident
    norm = float(pl.collect(pl.run("elemental", "normest", proj)))
    return np.asarray(pl.collect(proj)), norm, ac.stats.summary()


def _bridge_bytes(s: Dict) -> int:
    return int(s["send_bytes"]) + int(s["recv_bytes"])


def run(report: List[str], metrics: Optional[Dict] = None) -> None:
    a = _dataset()
    # Session-scoped residency on purpose: this suite measures the *planner's*
    # elision/dedup within one session, and the naive-vs-planned sessions
    # reuse the same dataset — the engine content store (DESIGN.md §8) would
    # turn the later sessions' sends into attaches and erase the baseline.
    # Cross-session sharing has its own suite (benchmarks/cross_session.py).
    engine = repro.AlchemistEngine(share_residents=False)

    results = {}
    for name, fn in (("naive", _naive), ("planned", _planned)):
        ac = repro.AlchemistContext(engine, num_workers=1, name=f"offload_{name}")
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        fn(ac, a)  # warm jit + relayout plans
        ac.stop()

        ac = repro.AlchemistContext(engine, num_workers=1, name=f"offload_{name}_t")
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        t0 = time.perf_counter()
        proj, norm, stats = fn(ac, a)
        dt = time.perf_counter() - t0
        ac.stop()
        results[name] = (dt, proj, norm, stats)

    t_naive, proj_naive, norm_naive, s_naive = results["naive"]
    t_planned, proj_planned, norm_planned, s_planned = results["planned"]
    b_naive, b_planned = _bridge_bytes(s_naive), _bridge_bytes(s_planned)

    # identical numerics down both paths
    np.testing.assert_allclose(proj_planned, proj_naive, atol=1e-2)
    assert abs(norm_planned - norm_naive) <= 1e-3 * max(abs(norm_naive), 1.0)

    # the acceptance property: the planned pipeline moves strictly fewer
    # bytes across the bridge, with crossings actually elided
    assert b_planned < b_naive, (b_planned, b_naive)
    assert s_planned["elided_crossings"] > 0, s_planned

    derived = (
        f"naive_s={t_naive:.3f};planned_s={t_planned:.3f};"
        f"speedup={t_naive / max(t_planned, 1e-9):.2f}x;"
        f"naive_bridge_MB={b_naive / 1e6:.2f};planned_bridge_MB={b_planned / 1e6:.2f};"
        f"bytes_elided_pct={100 * (1 - b_planned / b_naive):.1f};"
        f"elided_crossings={s_planned['elided_crossings']};"
        f"resident_reuses={s_planned['resident_reuses']};"
        f"planned_ops={s_planned['planned_ops']};"
        f"shape={M}x{N};k={K}"
    )
    report.append(csv_row("offload_plan", t_planned * 1e6, derived))
    if metrics is not None:
        # planned_bridge_bytes is the CI regression gate's headline number:
        # it is analytic (logical matrix bytes over the bridge), so it is
        # deterministic across hosts and device counts.
        metrics["offload"] = {
            "planned_bridge_bytes": b_planned,
            "naive_bridge_bytes": b_naive,
            "elided_crossings": s_planned["elided_crossings"],
            "resident_reuses": s_planned["resident_reuses"],
            "planned_ops": s_planned["planned_ops"],
            "planned_seconds": t_planned,
            "naive_seconds": t_naive,
        }
