"""Streaming wire data plane: shard-direct receive + overlapped I/O
(DESIGN.md §13).

PR 9's contract for the v2 wire: the socket is a *streaming* path, not a
stop-and-wait one. This suite drives large multi-shard arrays through the
real TCP transport and checks the three acceptance criteria:

- ``bit_identical`` — 1 if every TCP round trip (send → collect → fetch)
  returns exactly the bytes that went in. The streaming decode and the
  slab-streamed fetch must never change payload bytes.
- ``reassembly_receives`` — must stay 0 for shard-aligned sends: every
  SEND decodes chunk-by-chunk into per-shard staging slabs (the
  ``shard_direct_receives`` counter), never into a full-array reassembly
  buffer. Deterministic: the counter is a code-path count, not a clock.
- ``overlap_ratio`` — Σ(per-shard ``device_put`` time inside the socket
  receive window) / Σ(``device_put`` time) across shard-direct receives.
  With N shards, the first N−1 puts can run while later chunks are still
  arriving; the gate floor (BENCH_baseline − tolerance) is deliberately
  conservative, the one wall-clock-derived number here.

Plus the pipelining counters: ``max_inflight ≥ 2`` (two concurrent FETCHes
genuinely interleave on one socket — the multi-in-flight ticket protocol),
``vectored_writes > 0`` (replies coalesce header+length+payload into
``sendmsg`` batches), and ``streamed_fetches ≥ 1`` (collect results leave
the device slab-by-slab, the next ``device_get`` overlapping the current
socket write). Throughput is reported for the curious but never gated.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import csv_row

ROWS, COLS = 8192, 512  # 16 MiB f32: multiple chunks per shard on 8 devices
SENDS = 2


def run(report: List[str], metrics: Dict[str, Dict]) -> None:
    import repro
    from repro.serve.wire import ensure_server

    rng = np.random.default_rng(23)
    arrays = [
        rng.standard_normal((ROWS, COLS)).astype(np.float32) for _ in range(SENDS)
    ]

    engine = repro.AlchemistEngine()
    srv = ensure_server(engine)
    s = repro.connect(engine, transport="tcp")

    t0 = time.perf_counter()
    handles = [s.send(a).materialize() for a in arrays]

    # Concurrent collects: two FETCHes in flight on one socket, so the
    # server's per-connection depth counter must observe ≥ 2.
    outs: Dict[int, np.ndarray] = {}

    def fetch(i: int) -> None:
        outs[i] = np.asarray(s.collect(handles[i]))

    threads = [threading.Thread(target=fetch, args=(i,)) for i in range(SENDS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0

    bit_identical = int(
        all(np.array_equal(outs[i], arrays[i]) for i in range(SENDS))
    )

    st = dict(srv.stats)
    wire_snap = engine.stats()["wire"]
    ws = s.transport.wire_stats()
    s.close()

    put_ns = st["put_ns"]
    overlap_ratio = (st["overlap_ns"] / put_ns) if put_ns else 0.0
    payload = sum(a.nbytes for a in arrays) * 2  # each array crosses twice
    mb_s = payload / max(elapsed, 1e-9) / 2**20

    # Acceptance criteria asserted in-process too — a broken data plane
    # fails the benchmark run itself, not just the gate diff.
    assert bit_identical == 1, "TCP round trip changed payload bytes"
    assert st["shard_direct_receives"] >= SENDS, st
    assert st["reassembly_receives"] == 0, st
    assert st["streamed_fetches"] >= 1, st
    assert st["vectored_writes"] > 0, st
    assert st["max_inflight"] >= 2, st
    assert wire_snap["shard_direct_receives"] == st["shard_direct_receives"]

    report.append(
        csv_row(
            "wire_throughput_tcp",
            elapsed * 1e6,
            f"overlap={overlap_ratio:.3f} mb_s={mb_s:.1f} "
            f"shard_direct={st['shard_direct_receives']} "
            f"inflight_max={st['max_inflight']}",
        )
    )
    metrics["wire_throughput"] = {
        "payload_bytes": payload,
        "throughput_mb_s": round(mb_s, 1),
        "bit_identical": bit_identical,
        "overlap_ratio": round(overlap_ratio, 4),
        "shard_direct_receives": st["shard_direct_receives"],
        "reassembly_receives": st["reassembly_receives"],
        "streamed_fetches": st["streamed_fetches"],
        "gathered_fetches": st["gathered_fetches"],
        "vectored_writes": st["vectored_writes"],
        "max_inflight": st["max_inflight"],
        "client_vectored_writes": ws["vectored_writes"],
    }
