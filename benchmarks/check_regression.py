"""CI benchmark gate: fail on planned-bridge-bytes regressions.

Compares the metrics JSON a CI run just produced (``benchmarks.run --json
BENCH_ci.json``) against the checked-in baseline
(``benchmarks/BENCH_baseline.json``) and exits non-zero if a gated metric
regressed beyond tolerance.

Gated metrics are *analytic byte counts*, not wall clocks: planned bridge
bytes are derived from matrix shapes and the planner's elision decisions, so
they are deterministic across hosts and emulated-device counts — a >10%
increase means the planner genuinely started moving more data (e.g. a lost
elision or a broken resident-cache hit), never a noisy runner.

    python benchmarks/check_regression.py BENCH_ci.json benchmarks/BENCH_baseline.json
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict

# (suite, metric, direction): direction "lower" gates increases, "higher"
# gates decreases. Counters here must stay deterministic (see module doc) and
# must be *quality* metrics: spill/refill counts are deliberately NOT gated —
# they are policy artifacts (a better eviction policy legitimately lowers
# them), and the spill_pressure suite already asserts the actual contract
# internally (spills > 0, high_water <= budget, identical numerics).
GATES = [
    ("offload", "planned_bridge_bytes", "lower"),
    ("offload", "elided_crossings", "higher"),
    ("offload", "resident_reuses", "higher"),
    # Engine resident store (DESIGN.md §8): the attaching session's bridge
    # bytes must stay at zero (a baseline of 0 makes the limit 0 — any
    # re-shipped byte fails), and its attach count must not silently drop.
    ("cross_session", "second_session_bridge_bytes", "lower"),
    ("cross_session", "cross_session_reuses", "higher"),
    # Async data plane (DESIGN.md §10): the one wall-clock-derived gate. The
    # baseline is a deliberately conservative floor (measured ratios sit near
    # 1.0; 0.55 − 10% tolerance ≈ the 0.5 acceptance floor), so a pass means
    # "copy-outs still overlap compute", not "the runner was fast today".
    ("overlap_spill", "overlap_ratio", "higher"),
    # Wire transport (DESIGN.md §11): framing tax over raw matrix bytes is
    # analytic (shapes + CHUNK_BYTES), and the socket must never change the
    # engine-side bridge counters — parity is a 1-or-fail boolean.
    ("wire", "framing_overhead", "lower"),
    ("wire", "bridge_parity_ok", "higher"),
    # Streaming wire data plane (DESIGN.md §13): round trips must stay
    # bit-exact (1-or-fail), shard-aligned sends must never fall back to a
    # full-array reassembly buffer (baseline 0 makes the limit 0), and the
    # receive-side device_put/socket overlap must hold its floor — like
    # overlap_spill, the baseline is a conservative floor, not the measured
    # ratio, so a pass means "puts still overlap the socket reads".
    ("wire_throughput", "bit_identical", "higher"),
    ("wire_throughput", "reassembly_receives", "lower"),
    ("wire_throughput", "shard_direct_receives", "higher"),
    ("wire_throughput", "overlap_ratio", "higher"),
    ("wire_throughput", "max_inflight", "higher"),
    # Placement scheduler (DESIGN.md §12): the aging bound is an exact
    # invariant (fairness_ok is 1-or-fail; max_passed_by may only shrink),
    # and a shared-group reader must keep attaching with zero engine-side
    # bytes (baseline 0 makes the limit 0) across all of its declared views.
    ("admission", "fairness_ok", "higher"),
    ("admission", "max_passed_by", "lower"),
    ("admission", "shared_group_attach_bytes", "lower"),
    ("admission", "shared_views", "higher"),
    # Fleet chaos gate (DESIGN.md §14): recovery after an engine kill must
    # stay bit-identical (1-or-fail), refill residents by content key with
    # zero re-sent bytes (baseline 0 makes the limit 0), keep the replay
    # bounded by the analytically-priced lost DAG suffix (1-or-fail), and the
    # drain+re-admit step must finish under a generous wall-clock ceiling —
    # a boolean, so the gate catches hangs without being timing-sensitive.
    ("fleet", "bit_identical", "higher"),
    ("fleet", "refill_resend_bytes", "lower"),
    ("fleet", "refill_attaches", "higher"),
    ("fleet", "replayed_bytes_bounded", "higher"),
    ("fleet", "recovery_within_ceiling", "higher"),
    ("fleet", "recovered_sessions", "higher"),
]


def check(current: Dict, baseline: Dict, tolerance: float, suites=None) -> int:
    failures = 0
    gates = GATES if suites is None else [g for g in GATES if g[0] in suites]
    if not gates:
        print(f"[bench-gate] no gates match --suites {sorted(suites)}")
        return 1
    for suite, key, direction in gates:
        base = baseline.get(suite, {}).get(key)
        cur = current.get(suite, {}).get(key)
        if base is None:
            print(f"[bench-gate] {suite}.{key}: no baseline, skipping")
            continue
        if cur is None:
            print(f"[bench-gate] FAIL {suite}.{key}: missing from current run")
            failures += 1
            continue
        if direction == "lower":
            limit = base * (1 + tolerance)
            ok = cur <= limit
        else:
            limit = base * (1 - tolerance)
            ok = cur >= limit
        status = "ok" if ok else "FAIL"
        print(
            f"[bench-gate] {status} {suite}.{key}: current={cur} "
            f"baseline={base} limit={limit:g} ({direction} is better)"
        )
        failures += 0 if ok else 1
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="metrics JSON from this CI run")
    ap.add_argument("baseline", help="checked-in baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.10)
    ap.add_argument(
        "--suites",
        default=None,
        help="comma-separated subset of gated suites to check (a partial "
        "benchmark run — e.g. the tuned-bench CI step — must not fail gates "
        "for suites it never executed)",
    )
    args = ap.parse_args()

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    suites = None
    if args.suites:
        suites = {s.strip() for s in args.suites.split(",") if s.strip()}
    failures = check(current, baseline, args.tolerance, suites=suites)
    if failures:
        sys.exit(f"[bench-gate] {failures} gated metric(s) regressed")
    print("[bench-gate] all gates passed")


if __name__ == "__main__":
    main()
