"""Fleet chaos gate: kill an engine mid-pipeline, recover by lineage replay
(DESIGN.md §14).

The deployment study behind Alchemist (arXiv:1910.01354) runs long-lived
server processes under real operational churn; this benchmark is the
reproduction's chaos drill. A 2-engine :class:`repro.fleet.FleetSupervisor`
admits one client on engine 1, materializes the prefix of a gemm pipeline
there, then :meth:`kill`\\ s the engine under the client — the server is
stopped mid-session exactly like a crashed process. The supervisor drains the
dead engine and fails the client over to the survivor; finishing the pipeline
then asserts the three acceptance properties:

1. **Bit-identical.** The post-recovery result equals the result of the same
   pipeline on an unkilled fleet, bit for bit — replay is lazy re-lowering of
   the same expr DAG over the same content, not a numerical approximation.
2. **Zero re-sends.** Residents refill on the survivor by content key: the
   payloads the drain secured host-side are adopted into the survivor's
   store, so every replayed send attaches (``cross_session_reuses``) with
   ``send_bytes == 0`` on the recovered session.
3. **Bounded replay.** ``replayed_bytes`` (re-lowered nodes priced from
   static shapes) is bounded by the lost DAG suffix, computed analytically
   from the lineage — recovery never recomputes more than the kill destroyed.

A generous wall-clock ceiling on the drain+re-admit step rides along as a
boolean (``recovery_within_ceiling``) so a hung drain fails loudly without
making the gate timing-sensitive. All gated counters are analytic byte
counts, deterministic across hosts and emulated-device counts.

Both engines are given the *full* local device list (the supervisor
partitions a duplicated list), so the control engine and the survivor see
identical meshes — a requirement for the bit-identical comparison.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.fleet import FleetSupervisor, suffix_bytes

ELEMENTAL = "repro.linalg.library:ElementalLib"
M, K = 256, 128
A_BYTES = M * K * 4
B_BYTES = K * K * 4
#: generous drain+re-admit ceiling — catches hangs, not slow runners
RECOVERY_CEILING_S = 30.0


def _dataset():
    rng = np.random.default_rng(11)
    a = rng.standard_normal((M, K)).astype(np.float32)
    b = rng.standard_normal((K, K)).astype(np.float32)
    return a, b


def _pipeline(s, a: np.ndarray, b: np.ndarray):
    """send(a), send(b), then a 3-deep gemm chain. Every send feeds the
    first collect, so all content is resident (and therefore recoverable
    host-side) before the kill."""
    la, lb = s.send(a, name="A"), s.send(b, name="B")
    lc = s.run("elemental", "gemm", la, lb)
    ld = s.run("elemental", "gemm", lc, lb)
    le = s.run("elemental", "gemm", ld, lb)
    return [la, lb, lc, ld, le]


def _control(a: np.ndarray, b: np.ndarray) -> List[np.ndarray]:
    """The unkilled reference: same pipeline, one engine, same mesh."""
    with FleetSupervisor(devices=list(jax.devices()), engines=1) as sup:
        s = sup.connect(name="control")
        s.register_library("elemental", ELEMENTAL)
        roots = _pipeline(s, a, b)
        outs = [np.asarray(s.collect(roots[2])), np.asarray(s.collect(roots[4]))]
        s.close()
    return outs


def run(report: List[str], metrics: Optional[Dict] = None) -> None:
    a, b = _dataset()
    ref_prefix, ref_final = _control(a, b)  # also warms the gemm jit cache

    devices = list(jax.devices()) * 2  # each engine gets the full local mesh
    with FleetSupervisor(devices=devices, engines=2) as sup:
        victim = list(sup.engines)[0]
        s = sup.connect(name="app", engine=victim)
        s.register_library("elemental", ELEMENTAL)
        roots = _pipeline(s, a, b)
        prefix = np.asarray(s.collect(roots[2]))  # materialize A, B, A@B

        t0 = time.perf_counter()
        recs = sup.kill(victim)  # chaos: server stopped under the client
        t_recover = time.perf_counter() - t0
        assert len(recs) == 1, recs
        rec = recs[0]

        t1 = time.perf_counter()
        final = np.asarray(s.collect(roots[4]))  # forces the suffix replay
        t_replay = time.perf_counter() - t1

        sup.recovery.account_replay(rec, roots, s.planner)
        lost_bytes = suffix_bytes(roots, rec.lost_ids)
        post = s.stats.summary()
        fleet_stats = sup.stats()
        s.close()

    # 1. bit-identical vs the unkilled fleet
    np.testing.assert_array_equal(prefix, ref_prefix)
    np.testing.assert_array_equal(final, ref_final)
    # 2. refills attach by content key — zero bytes re-crossed the bridge
    assert post["send_bytes"] == 0, post
    assert post["cross_session_reuses"] == 2, post  # A and B re-attached
    assert rec.adopted_keys == 2 and rec.adopted_bytes == A_BYTES + B_BYTES, rec
    # 3. replay bounded by the lost suffix, both sides analytic
    assert 0 < rec.replayed_bytes <= lost_bytes, (rec.replayed_bytes, lost_bytes)
    within_ceiling = int(t_recover <= RECOVERY_CEILING_S)
    assert within_ceiling, f"drain+re-admit took {t_recover:.1f}s"

    derived = (
        f"recovered_sessions={len(recs)};"
        f"adopted_MB={rec.adopted_bytes / 1e6:.2f};"
        f"replayed_MB={rec.replayed_bytes / 1e6:.2f};"
        f"lost_suffix_MB={lost_bytes / 1e6:.2f};"
        f"refill_resend_bytes={post['send_bytes']};"
        f"recover_s={t_recover:.3f};replay_s={t_replay:.3f}"
    )
    report.append(csv_row("fleet_recovery", t_recover * 1e6, derived))
    if metrics is not None:
        metrics["fleet"] = {
            # gated: replay correctness and economy are 1-or-fail booleans;
            # the byte counters are analytic (shape-derived) so a baseline
            # of 0 resend bytes makes any re-shipped byte a failure
            "bit_identical": 1,
            "refill_resend_bytes": post["send_bytes"],
            "refill_attaches": post["cross_session_reuses"],
            "replayed_bytes_bounded": int(0 < rec.replayed_bytes <= lost_bytes),
            "recovery_within_ceiling": within_ceiling,
            "recovered_sessions": len(recs),
            "adopted_keys": rec.adopted_keys,
            "adopted_bytes": rec.adopted_bytes,
            "replayed_nodes": rec.replayed_nodes,
            "replayed_bytes": rec.replayed_bytes,
            "lost_suffix_bytes": lost_bytes,
            "recovery_seconds": t_recover,
            "replay_seconds": t_replay,
            # the fleet-level observability block (per-engine health, drains,
            # replays, autoscale actions) — DESIGN.md §14's sup.stats(),
            # surfaced in the CI artifact next to engine_stats
            "fleet_stats": fleet_stats,
        }
