"""Benchmark harness — one module per paper table/figure.

  gemm_table1        Table 1  (matrix multiply, Spark vs Spark+Alchemist)
  svd_fig34          Figs 3-4 (rank-20 truncated SVD + overhead split)
  transfer_tables23  Tables 2-3 (tall-skinny vs short-wide transfers)
  overlap_async      beyond-paper: sync vs pipelined task-queue engine,
                     relayout plan-cache hit rate (DESIGN.md §3/§5)
  offload_plan       beyond-paper: naive round-trip vs lazy-planned offload
                     (bytes over the bridge + elided crossings, DESIGN.md §6)
  spill_pressure     beyond-paper: memory governor with a working set ≥2× the
                     HBM budget — spill/refill counters, bounded high water,
                     padded uneven-shape sends (DESIGN.md §7)
  cross_session      beyond-paper: engine-level resident store + v2 admission
                     — a second session is *queued* for admission (DESIGN.md
                     §9), then its identical dataset attaches with zero
                     bridge bytes; two sessions 2× overcommitted against one
                     shared HBM budget stay bounded + bit-exact (DESIGN.md §8)
  overlap_spill      beyond-paper: asynchronous data plane — spill copy-outs
                     on the transfer ring overlapped with queue-worker
                     compute, measured as an overlap ratio and compared
                     bit-exactly against the synchronous baseline
                     (DESIGN.md §10)
  wire_overhead      beyond-paper: TCP transport vs loopback — framing
                     overhead over the raw matrix bytes and engine-side
                     bridge-counter parity (DESIGN.md §11)
  wire_throughput    beyond-paper: v2 streaming wire data plane — bit-exact
                     multi-shard TCP round trips with zero full-array
                     reassembly on receive, device_put/socket overlap ratio,
                     multi-in-flight depth, vectored-write counts
                     (DESIGN.md §13)
  admission_fairness beyond-paper: unified placement scheduler — a large
                     ticket under a small-connect storm is passed at most
                     ``aging_bound`` times (p50/p95 ticket waits reported),
                     and a content-affine reader joins the writer's shared
                     worker group with zero engine-side attach bytes
                     (DESIGN.md §12)
  fleet_recovery     beyond-paper: fleet chaos gate — kill one engine of a
                     2-engine supervised fleet mid-pipeline; the survivor
                     replays the lost DAG suffix bit-identically, refills
                     residents by content key with zero re-sent bytes, and
                     the replay is bounded by the analytically-priced lost
                     suffix (DESIGN.md §14)

Prints ``name,us_per_call,derived`` CSV rows. ``--only`` takes a
comma-separated subset; ``--json PATH`` additionally writes the structured
metrics each suite records — each suite block carries a ``runtime`` config
record (allocator, XLA flags, device count; repro.launch.runtime) so a
regression is attributable to environment drift, plus the merged
``engine.stats()`` snapshot that cross_session embeds — the file CI uploads
as ``BENCH_ci.json`` and gates against ``benchmarks/BENCH_baseline.json``
(see check_regression.py). ``--tuned`` re-execs the process under the tuned
runtime recipe (tcmalloc LD_PRELOAD when installed, emulated device count,
32-bit dtype defaults) before any jax import binds the environment.

    PYTHONPATH=src python -m benchmarks.run [--only offload,spill] \
        [--tuned] [--json out.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List

SUITE_NAMES = [
    "gemm", "svd", "transfer", "overlap", "offload", "spill", "cross",
    "overlap_spill", "wire", "wire_throughput", "admission", "fleet",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        default=None,
        help=f"comma-separated subset of: {','.join(SUITE_NAMES)}",
    )
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="also write structured per-suite metrics as JSON",
    )
    ap.add_argument(
        "--tuned",
        action="store_true",
        help="re-exec under the tuned runtime recipe (repro.launch.runtime)",
    )
    args = ap.parse_args()

    if args.tuned:
        # Before any benchmark import pulls in jax: LD_PRELOAD and XLA flags
        # bind at process start, so the only honest application is a re-exec
        # (a no-op if this process is already the tuned one).
        from repro.launch import runtime

        runtime.ensure_tuned()

    from benchmarks import (
        admission_fairness,
        cross_session,
        fleet_recovery,
        gemm_table1,
        offload_plan,
        overlap_async,
        overlap_spill,
        spill_pressure,
        svd_fig34,
        transfer_tables23,
        wire_overhead,
        wire_throughput,
    )
    from repro.launch import runtime

    suites = {
        "gemm": gemm_table1.run,
        "svd": svd_fig34.run,
        "transfer": transfer_tables23.run,
        "overlap": overlap_async.run,
        "offload": offload_plan.run,
        "spill": spill_pressure.run,
        "cross": cross_session.run,
        "overlap_spill": overlap_spill.run,
        "wire": wire_overhead.run,
        "wire_throughput": wire_throughput.run,
        "admission": admission_fairness.run,
        "fleet": fleet_recovery.run,
    }

    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in suites]
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; choose from {sorted(suites)}")
        suites = {n: suites[n] for n in names}

    report: List[str] = ["name,us_per_call,derived"]
    metrics: Dict[str, Dict] = {}
    t0 = time.perf_counter()
    for name, fn in suites.items():
        sys.stderr.write(f"[benchmarks] running {name} ...\n")
        fn(report, metrics)
    sys.stderr.write(f"[benchmarks] done in {time.perf_counter()-t0:.1f}s\n")
    print("\n".join(report))
    if args.json:
        # Every suite's block records the runtime it actually ran under —
        # regressions must be attributable to environment drift (allocator,
        # device count, flags), not guessed at.
        rt = runtime.snapshot()
        for block in metrics.values():
            block["runtime"] = rt
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        sys.stderr.write(f"[benchmarks] metrics written to {args.json}\n")


if __name__ == "__main__":
    main()
