"""Benchmark harness — one module per paper table/figure.

  gemm_table1        Table 1  (matrix multiply, Spark vs Spark+Alchemist)
  svd_fig34          Figs 3-4 (rank-20 truncated SVD + overhead split)
  transfer_tables23  Tables 2-3 (tall-skinny vs short-wide transfers)
  overlap_async      beyond-paper: sync vs pipelined task-queue engine,
                     relayout plan-cache hit rate (DESIGN.md §3/§5)
  offload_plan       beyond-paper: naive round-trip vs lazy-planned offload
                     (bytes over the bridge + elided crossings, DESIGN.md §6)
  spill_pressure     beyond-paper: memory governor with a working set ≥2× the
                     HBM budget — spill/refill counters, bounded high water,
                     padded uneven-shape sends (DESIGN.md §7)
  cross_session      beyond-paper: engine-level resident store + v2 admission
                     — a second session is *queued* for admission (DESIGN.md
                     §9), then its identical dataset attaches with zero
                     bridge bytes; two sessions 2× overcommitted against one
                     shared HBM budget stay bounded + bit-exact (DESIGN.md §8)

Prints ``name,us_per_call,derived`` CSV rows. ``--only`` takes a
comma-separated subset; ``--json PATH`` additionally writes the structured
metrics each suite records — including the merged ``engine.stats()``
snapshot (worker pool + admission queue, per-session stats, governor
pressure, resident store; DESIGN.md §9) that cross_session embeds — the
file CI uploads as ``BENCH_ci.json`` and gates against
``benchmarks/BENCH_baseline.json`` (see check_regression.py).

    PYTHONPATH=src python -m benchmarks.run [--only offload,spill] [--json out.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List


def main() -> None:
    from benchmarks import (
        cross_session,
        gemm_table1,
        offload_plan,
        overlap_async,
        spill_pressure,
        svd_fig34,
        transfer_tables23,
    )

    suites = {
        "gemm": gemm_table1.run,
        "svd": svd_fig34.run,
        "transfer": transfer_tables23.run,
        "overlap": overlap_async.run,
        "offload": offload_plan.run,
        "spill": spill_pressure.run,
        "cross": cross_session.run,
    }

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        default=None,
        help=f"comma-separated subset of: {','.join(suites)}",
    )
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="also write structured per-suite metrics as JSON",
    )
    args = ap.parse_args()

    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in suites]
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; choose from {sorted(suites)}")
        suites = {n: suites[n] for n in names}

    report: List[str] = ["name,us_per_call,derived"]
    metrics: Dict[str, Dict] = {}
    t0 = time.perf_counter()
    for name, fn in suites.items():
        sys.stderr.write(f"[benchmarks] running {name} ...\n")
        fn(report, metrics)
    sys.stderr.write(f"[benchmarks] done in {time.perf_counter()-t0:.1f}s\n")
    print("\n".join(report))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        sys.stderr.write(f"[benchmarks] metrics written to {args.json}\n")


if __name__ == "__main__":
    main()
