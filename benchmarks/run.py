"""Benchmark harness — one module per paper table/figure.

  gemm_table1        Table 1  (matrix multiply, Spark vs Spark+Alchemist)
  svd_fig34          Figs 3-4 (rank-20 truncated SVD + overhead split)
  transfer_tables23  Tables 2-3 (tall-skinny vs short-wide transfers)
  overlap_async      beyond-paper: sync vs pipelined task-queue engine,
                     relayout plan-cache hit rate (DESIGN.md §3/§5)
  offload_plan       beyond-paper: naive round-trip vs lazy-planned offload
                     (bytes over the bridge + elided crossings, DESIGN.md §6)

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only gemm|svd|transfer|overlap]
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import List


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=("gemm", "svd", "transfer", "overlap", "offload"))
    args = ap.parse_args()

    from benchmarks import gemm_table1, offload_plan, overlap_async, svd_fig34, transfer_tables23

    suites = {
        "gemm": gemm_table1.run,
        "svd": svd_fig34.run,
        "transfer": transfer_tables23.run,
        "overlap": overlap_async.run,
        "offload": offload_plan.run,
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    report: List[str] = ["name,us_per_call,derived"]
    t0 = time.perf_counter()
    for name, fn in suites.items():
        sys.stderr.write(f"[benchmarks] running {name} ...\n")
        fn(report)
    sys.stderr.write(f"[benchmarks] done in {time.perf_counter()-t0:.1f}s\n")
    print("\n".join(report))


if __name__ == "__main__":
    main()
