"""Admission fairness + shared worker groups (DESIGN.md §12).

The unified placement scheduler makes two quantitative promises beyond the
paper's first-free-block allocator:

1. **Bounded starvation.** Under a storm of small connects competing with one
   engine-sized request, the large ticket is passed by at most ``aging_bound``
   later-arriving smaller requests before the aging barrier holds the queue
   for it. ``max_passed_by`` is read off the resolved ticket, so the gate is
   exact: any scheduler change that lets smalls leapfrog past the bound flips
   ``fairness_ok`` to 0. Ticket waits (p50/p95) are reported for context but
   not gated — they are wall clocks.

2. **Zero-byte shared-group attach.** A session declaring affinity for
   content that is live on another session's worker group *joins* that group:
   no devices are consumed and every send resolves to a device-buffer view,
   so the reader's engine-side placement bytes are exactly zero. The byte
   counters are analytic (shapes + attach decisions), hence deterministic
   across hosts and emulated-device counts.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

import repro
from benchmarks.common import csv_row

AGING_BOUND = 4
N_SHARED_MATS = 3
M, N = 256, 128


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    ordered = sorted(xs)
    i = min(len(ordered) - 1, int(round(q * (len(ordered) - 1))))
    return ordered[i]


def _fairness_storm() -> Dict:
    """One large (whole-engine) ticket vs a storm of small connects."""
    engine = repro.AlchemistEngine(aging_bound=AGING_BOUND)
    total = engine.num_workers
    holders = [engine.connect(name=f"hold{i}", num_workers=1) for i in range(total)]

    tickets: Dict[str, object] = {}
    errors: Dict[str, BaseException] = {}

    def run_large() -> None:
        try:
            s = repro.connect(
                engine,
                name="large",
                placement=repro.PlacementRequest(workers=total, deadline=120),
            )
            tickets["large"] = s.placement
            s.close()
        except BaseException as e:
            errors["large"] = e

    def run_small(i: int) -> None:
        try:
            s = repro.connect(
                engine,
                name=f"small{i}",
                placement=repro.PlacementRequest(workers=1, deadline=120),
            )
            tickets[f"small{i}"] = s.placement
            time.sleep(0.02)  # trivial work, then leave
            s.close()
        except BaseException as e:
            errors[f"small{i}"] = e

    large = threading.Thread(target=run_large)
    large.start()
    time.sleep(0.05)  # large is queued first
    smalls = [
        threading.Thread(target=run_small, args=(i,)) for i in range(AGING_BOUND + 2)
    ]
    for t in smalls:
        t.start()
    time.sleep(0.05)
    # Drain the pool one device at a time: each release lets at most one
    # small leapfrog the blocked large ticket until the aging barrier trips.
    for h in holders:
        engine.release(h)
        time.sleep(0.03)
    large.join(timeout=120)
    for t in smalls:
        t.join(timeout=120)
    if errors:
        raise RuntimeError(f"admission storm failed: {errors}")

    big = tickets["large"]
    waits_ms = [t.wait_ns / 1e6 for t in tickets.values()]
    sched = engine.stats()["scheduler"]
    return {
        "aging_bound": AGING_BOUND,
        "max_passed_by": int(big.passed_by),
        "fairness_ok": int(big.state == "placed" and big.passed_by <= AGING_BOUND),
        "storm_tickets": len(tickets),
        "wait_ms_p50": _percentile(waits_ms, 0.50),
        "wait_ms_p95": _percentile(waits_ms, 0.95),
        "aged_tickets": sched["aged"],
        "placed": sched["placed"],
    }


def _shared_group() -> Dict:
    """A content-affine reader joins the writer's group with zero bytes."""
    engine = repro.AlchemistEngine()
    rng = np.random.default_rng(11)
    mats = [rng.standard_normal((M, N)).astype(np.float32) for _ in range(N_SHARED_MATS)]

    writer = repro.connect(engine, name="writer")
    refs = [np.asarray(writer.send(m, name=f"m{i}").data()) for i, m in enumerate(mats)]

    reader = repro.connect(
        engine,
        name="reader",
        placement=repro.PlacementRequest(affinity=tuple(mats), deadline=30),
    )
    assert reader.placement.shared, "reader must join the writer's worker group"
    outs = [np.asarray(reader.send(m, name=f"m{i}").data()) for i, m in enumerate(mats)]
    for ref, out in zip(refs, outs):
        np.testing.assert_array_equal(ref, out)

    stats = reader.session.stats.summary()
    sched = engine.stats()["scheduler"]
    attach_bytes = int(stats["placement_bytes"]) + int(stats["send_bytes"])
    reader.close()
    writer.close()
    return {
        "shared_group_attach_bytes": attach_bytes,
        "shared_views": int(stats["shared_views"]),
        "shared_joins": sched["shared_joins"],
        "payload_bytes": sum(m.nbytes for m in mats),
    }


def run(report: List[str], metrics: Dict[str, Dict]) -> None:
    t0 = time.perf_counter()
    storm = _fairness_storm()
    storm_us = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    shared = _shared_group()
    shared_us = (time.perf_counter() - t0) * 1e6

    report.append(
        csv_row(
            "admission_fairness_storm",
            storm_us,
            f"max_passed_by={storm['max_passed_by']} "
            f"bound={storm['aging_bound']} "
            f"wait_p50={storm['wait_ms_p50']:.1f}ms "
            f"wait_p95={storm['wait_ms_p95']:.1f}ms",
        )
    )
    report.append(
        csv_row(
            "admission_shared_group",
            shared_us,
            f"attach_bytes={shared['shared_group_attach_bytes']} "
            f"shared_views={shared['shared_views']} "
            f"payload_bytes={shared['payload_bytes']}",
        )
    )
    metrics["admission"] = {**storm, **shared}
