"""Paper Figures 3–4: rank-20 truncated SVD, Spark vs Spark+Alchemist.

Paper setup: m x 10,000 matrices (m up to 5e6; 25–400 GB), rank 20, on 22
Spark + 8 Alchemist Cori nodes; Spark fails the 30-minute limit for all but
the smallest matrix, Alchemist completes all with transfer overhead ≈ 20 %
of total runtime (Fig. 3).

Here: the same column count *aspect* scaled down; the reproduced claims —
  (a) engine completes with send+receive overhead a modest fraction of
      total (Fig. 3's decomposition, printed as a fraction),
  (b) the MLlib-style path's driver-synchronized matvec loop costs far more
      in modeled cluster time (Fig. 4's gap),
  (c) both agree with numpy sigmas (correctness).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

import repro
from benchmarks.common import csv_row
from repro.sparklike import IndexedRowMatrix, SparkLikeContext, mllib

ROWS = [8_000, 16_000]  # paper: 312k..5M rows x 10k cols, scaled /~300
COLS = 256              # keeps CPU runtime civil; aspect stays tall-skinny
RANK = 20


def _decaying(rng, m, n, decay=0.9):
    u, _ = np.linalg.qr(rng.standard_normal((m, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = decay ** np.arange(n) * 100
    return ((u * s) @ v.T).astype(np.float64)


def run(report: List[str], metrics: Optional[Dict] = None) -> None:
    rng = np.random.default_rng(1)
    engine = repro.AlchemistEngine()

    for m in ROWS:
        a = _decaying(rng, m, COLS)
        s_ref = np.linalg.svd(a, compute_uv=False)[:RANK]

        # --- Spark+Alchemist (steady state: the engine is a persistent
        # server; jit compile is one-time, like the paper's compiled MPI) ---
        ac = repro.AlchemistContext(engine, name="svd_bench")
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        warm = ac.send(a.astype(np.float32))
        ac.run("elemental", "truncated_svd", warm, k=RANK)
        ac.free(warm)
        t0 = time.perf_counter()
        ha = ac.send(a.astype(np.float32))
        hu, sig, hv = ac.run("elemental", "truncated_svd", ha, k=RANK)
        u_back = np.asarray(ac.collect(hu))
        t_alch = time.perf_counter() - t0
        stats = ac.stats.summary()
        overhead_frac = (
            (stats["send_seconds"] + stats["recv_seconds"]) / max(t_alch, 1e-9)
        )
        ac.stop()
        assert np.allclose(sig, s_ref, rtol=5e-2), "engine sigmas off"

        # --- Spark MLlib-style ---
        ctx = SparkLikeContext(num_partitions=4)
        ir = IndexedRowMatrix.from_numpy(ctx, a)
        ctx.reset_stats()
        t0 = time.perf_counter()
        _, sig_s, _ = mllib.compute_svd(ir, RANK)
        t_spark = time.perf_counter() - t0
        modeled_spark = ctx.modeled_seconds(mllib.svd_flops(m, COLS, RANK + 10))
        assert np.allclose(sig_s, s_ref, rtol=5e-2), "mllib sigmas off"

        # modeled at the paper's full scale (5e6 x 1e4, rank 20, Cori):
        # MPI side: flops at 8 nodes x 0.5 TF sustained + 400 GB transfer at
        # ~1.25 GB/s/node over 22 sender nodes; Spark side: same flops at 22
        # executor nodes plus per-iteration driver sync + stage overheads.
        full_flops = mllib.svd_flops(5_000_000, 10_000, RANK + 10)
        alch_modeled = full_flops / (8 * 5e11) + 400e9 / (1.25e9 * 22)
        spark_modeled_full = full_flops / (22 * 5e11) + (RANK + 10) * 2 * (
            0.1 + 22 * 0.005 + 0.02
        ) + (RANK + 10) * 400e9 / (1.25e9 * 22)  # re-reads A per matvec epoch

        name = f"svd_fig34_m{m}"
        derived = (
            f"alchemist_wall_s={t_alch:.3f};overhead_frac={overhead_frac:.2f};"
            f"spark_wall_s={t_spark:.3f};"
            f"spark_modeled_cori_s={modeled_spark:.1f};"
            f"alch_modeled_cori_full_s={alch_modeled:.0f};"
            f"spark_modeled_cori_full_s={spark_modeled_full:.0f};"
            f"driver_syncs={ctx.stats.driver_syncs};"
            f"send_s={stats['send_seconds']:.3f};compute_s={stats['compute_seconds']:.3f};"
            f"recv_s={stats['recv_seconds']:.3f}"
        )
        report.append(csv_row(name, t_alch * 1e6, derived))
