"""Cross-session placement: the engine-level resident store (DESIGN.md §8).

The Alchemist papers stress that the server amortizes data movement across
clients: several applications connect to one Alchemist instance and share its
worker-side matrices (arXiv:1805.11800, arXiv:1910.01354). This benchmark
asserts the two acceptance properties of the engine-level refactor:

1. **Zero-bridge second session, admitted via the queue.** Session 1 holds
   the whole worker pool, sends a dataset and computes on it; session 2's
   ``connect`` is *queued* (DESIGN.md §9) until session 1 stops, then sends
   the byte-identical dataset. With the engine's content-addressed store,
   session 2's sends become attach-only placements: ``send_bytes == 0`` and
   ``num_sends == 0`` while every result stays bit-identical, with
   ``cross_session_reuses`` counting the attaches. The session-scoped
   baseline (``share_residents=False``) re-ships everything (but still
   queues — admission and content dedup are independent layers).

2. **Shared HBM budget.** Two sessions with *distinct* working sets, each
   sized to the whole budget (2× overcommitted combined), run against one
   engine-wide governor: every result is bit-identical to an unbudgeted run
   and the engine-wide high water stays within the single shared budget —
   victims are picked across sessions, pinned operands of either session are
   never spilled.

Reported metrics feed the CI benchmark gate (BENCH_ci.json): the bridge-byte
counters are analytic (derived from matrix shapes and attach decisions), so
they are deterministic across hosts and emulated-device counts.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import repro
from benchmarks.common import csv_row

M, N = 512, 256
N_MATS = 6
MAT_BYTES = M * N * 4
# Part 2: each session's working set fills the whole shared budget, so the
# two sessions combined overcommit it 2x. The budget leaves headroom for the
# worst-case unspillable set (one pinned operand + one in-flight admission
# claim per session = 4 matrices): admission then never has to overshoot its
# best-effort contract, and the high-water assert is race-free.
CAP_MATS = 4
BUDGET = CAP_MATS * MAT_BYTES


def _dataset(seed: int) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((M, N)).astype(np.float32) for _ in range(N_MATS)]


_SHARED = _dataset(3)
_SET_A = _dataset(5)[:CAP_MATS]
_SET_B = _dataset(7)[:CAP_MATS]


def _workload(ac, mats: List[np.ndarray]) -> Tuple[List[np.ndarray], List[float], Dict]:
    """Send every matrix, consume each engine-side (Frobenius norm), then
    collect it back — sends, compute, and receives for one application."""
    pl = ac.planner
    lazies = [pl.send(m, name=f"m{i}") for i, m in enumerate(mats)]
    norms = [float(pl.collect(pl.run("elemental", "normest", la))) for la in lazies]
    outs = [np.asarray(pl.collect(la)) for la in lazies]
    return outs, norms, ac.stats.summary()


def _connect(engine, name: str, workers: Optional[int] = None, timeout: Optional[float] = None):
    ac = repro.connect(
        engine,
        name=name,
        placement=repro.PlacementRequest(workers=workers, deadline=timeout),
    )
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")
    return ac


def _two_sessions(engine, tag: str) -> Tuple[Dict, Dict, List[np.ndarray], List[np.ndarray]]:
    """The same dataset through two sessions of one engine, with the second
    session admitted via the **queued path** (DESIGN.md §9): session 1 holds
    the whole worker pool, so session 2's ``connect`` waits in the admission
    queue until session 1 stops — at which point session 1's
    uniquely-referenced residents have migrated host-side and session 2's
    sends attach to them with zero bridge bytes."""
    ac1 = _connect(engine, f"{tag}_s1")  # the whole pool
    outs1, norms1, s1 = _workload(ac1, _SHARED)

    queued_before = engine.admissions["queued"]

    def release_when_queued() -> None:
        deadline = time.time() + 60
        while engine.queued_connects == 0 and time.time() < deadline:
            time.sleep(0.01)
        ac1.close()

    t = threading.Thread(target=release_when_queued)
    t.start()
    ac2 = _connect(engine, f"{tag}_s2", timeout=120)  # queued, then placed
    t.join()
    assert engine.admissions["queued"] == queued_before + 1, engine.admissions
    outs2, norms2, s2 = _workload(ac2, _SHARED)
    ac2.close()
    assert norms1 == norms2, (norms1, norms2)
    for x, y in zip(outs1, outs2):
        np.testing.assert_array_equal(x, y)
    return s1, s2, outs1, outs2


def _shared_budget(budget: Optional[int]) -> Optional[Tuple]:
    """Two *concurrent* sessions with distinct working sets against one
    engine-wide budget. Both stay connected until both workloads finish, so
    their residency genuinely coexists under the shared ceiling. Needs two
    workers; returns None on a single-device host (CI runs with 8)."""
    engine = repro.AlchemistEngine(hbm_budget=budget)
    if engine.num_workers < 2:
        return None
    w = engine.num_workers // 2
    acs = {name: _connect(engine, name, w) for name in ("cap_a", "cap_b")}
    results: Dict[str, Tuple] = {}

    def drive(name: str, mats: List[np.ndarray]) -> None:
        results[name] = _workload(acs[name], mats)

    t0 = time.perf_counter()
    threads = [
        threading.Thread(target=drive, args=("cap_a", _SET_A)),
        threading.Thread(target=drive, args=("cap_b", _SET_B)),
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    for ac in acs.values():
        ac.stop()
    outs = results["cap_a"][0] + results["cap_b"][0]
    return outs, results["cap_a"][2], results["cap_b"][2], engine.memgov.high_water, dt


def run(report: List[str], metrics: Optional[Dict] = None) -> None:
    # --- part 1: second session attaches instead of re-shipping -------------
    # Warm jit on a throwaway engine: warming on the measured one would leave
    # migrated content behind and turn even session 1's sends into attaches.
    _two_sessions(repro.AlchemistEngine(), "warm")
    shared_engine = repro.AlchemistEngine()
    t0 = time.perf_counter()
    s1, s2, _, _ = _two_sessions(shared_engine, "shared")
    t_shared = time.perf_counter() - t0

    baseline_engine = repro.AlchemistEngine(share_residents=False)
    b1, b2, _, _ = _two_sessions(baseline_engine, "scoped")

    # The acceptance property: with the engine store the second session's
    # bridge bytes collapse to zero — attach-only placements — while the
    # session-scoped baseline re-ships the full dataset.
    assert s1["send_bytes"] == N_MATS * MAT_BYTES, s1
    assert s2["send_bytes"] == 0 and s2["num_sends"] == 0, s2
    assert s2["cross_session_reuses"] == N_MATS, s2
    assert b2["send_bytes"] == b1["send_bytes"] == N_MATS * MAT_BYTES, (b1, b2)
    assert b2["cross_session_reuses"] == 0, b2

    # --- part 2: one shared budget, two 1x-budget sessions (2x combined) ----
    free = _shared_budget(None)
    capped = _shared_budget(BUDGET)
    if free is not None and capped is not None:
        outs_free, _fa, _fb, hw_free, t_free = free
        outs_cap, ca, cb, hw_cap, t_cap = capped
        for x, y in zip(outs_free, outs_cap):
            np.testing.assert_array_equal(x, y)
        assert hw_free >= 2 * BUDGET, hw_free  # genuinely overcommitted
        assert hw_cap <= BUDGET, (hw_cap, BUDGET)  # one engine-wide ceiling
        assert ca["spills"] + cb["spills"] > 0, (ca, cb)
        part2 = (
            f"shared_budget_MB={BUDGET / 1e6:.2f};"
            f"free_high_water_MB={hw_free / 1e6:.2f};"
            f"capped_high_water_MB={hw_cap / 1e6:.2f};"
            f"spills={ca['spills'] + cb['spills']};"
            f"free_s={t_free:.3f};capped_s={t_cap:.3f}"
        )
    else:
        hw_cap = hw_free = None
        part2 = "shared_budget=skipped(<2 devices)"

    derived = (
        f"s1_bridge_MB={s1['send_bytes'] / 1e6:.2f};"
        f"s2_bridge_MB={s2['send_bytes'] / 1e6:.2f};"
        f"scoped_s2_bridge_MB={b2['send_bytes'] / 1e6:.2f};"
        f"cross_session_reuses={s2['cross_session_reuses']};"
        f"migrations={shared_engine.residents.stats()['migrations']};"
        + part2
    )
    report.append(csv_row("cross_session", t_shared * 1e6, derived))
    if metrics is not None:
        metrics["cross_session"] = {
            # gated: analytic bridge bytes of the attaching session (must
            # stay 0) and its attach count (must not silently drop)
            "second_session_bridge_bytes": s2["send_bytes"],
            "cross_session_reuses": s2["cross_session_reuses"],
            "first_session_bridge_bytes": s1["send_bytes"],
            "scoped_second_session_bridge_bytes": b2["send_bytes"],
            "queued_admissions": shared_engine.admissions["queued"],
            "shared_budget_bytes": BUDGET,
            "capped_high_water": hw_cap,
            "uncapped_high_water": hw_free,
            "shared_seconds": t_shared,
            # the merged observability snapshot (engine pool + admission
            # queue, per-session stats, governor pressure, resident store) —
            # DESIGN.md §9's engine.stats(), surfaced in the CI artifact
            "engine_stats": shared_engine.stats(),
        }
