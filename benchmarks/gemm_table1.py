"""Paper Table 1: matrix multiplication, Spark vs Spark+Alchemist.

The paper multiplies m x n by n x k dense matrices (dims in the thousands,
up to 144 GB results) on up to 4 Cori nodes; Spark's explode-and-shuffle
BlockMatrix path takes 160–809 s where it completes at all, and fails on
multi-node runs, while Alchemist's Send/Compute/Receive totals stay under
~310 s.

Here: the same operand *aspect ratios* scaled to container size, measured
three ways —
  (1) wall-clock on this container for both paths,
  (2) the Spark-side overhead model (stages, tasks, shuffle bytes) projected
      onto the paper's cluster constants,
  (3) the engine's Send/Compute/Receive split, the paper's own reporting
      format.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

import repro
from benchmarks.common import csv_row
from repro.sparklike import IndexedRowMatrix, SparkLikeContext, mllib


# paper dims scaled by /40 -> container-size operands with the same aspect
CASES = [
    (10_000, 10_000, 10_000),
    (50_000, 10_000, 30_000),
    (100_000, 10_000, 70_000),
]
SCALE = 40  # m up to 2500: numpy GEMM ~0.1-0.5 s, Spark-path overheads visible


def run(report: List[str], metrics: Optional[Dict] = None) -> None:
    rng = np.random.default_rng(0)
    engine = repro.AlchemistEngine()

    for m_k, n_k, k_k in CASES:
        m, n, k = (max(d // SCALE, 8) for d in (m_k, n_k, k_k))
        a = rng.standard_normal((m, n)).astype(np.float64)
        b = rng.standard_normal((n, k)).astype(np.float64)

        # --- Spark path (the paper's explode-shuffle-multiply recipe) ---
        ctx = SparkLikeContext(num_partitions=4)
        ir_a = IndexedRowMatrix.from_numpy(ctx, a)
        ir_b = IndexedRowMatrix.from_numpy(ctx, b)
        ctx.reset_stats()
        t0 = time.perf_counter()
        c_spark = mllib.multiply(ir_a, ir_b, block_size=max(m // 8, 16))
        t_spark = time.perf_counter() - t0
        spark_stats = ctx.stats
        modeled_spark = ctx.modeled_seconds(mllib.gemm_flops(m, n, k))

        # --- Alchemist path ---
        ac = repro.AlchemistContext(engine, name="gemm_bench")
        ac.register_library("elemental", "repro.linalg.library:ElementalLib")
        ha = ac.send(a.astype(np.float32), name="A")
        hb = ac.send(b.astype(np.float32), name="B")
        ac.run("elemental", "gemm", ha, hb)  # warm the jit cache: the paper's
        # MPI side is a persistent server; one-time compile is not per-call cost
        t0 = time.perf_counter()
        ha2 = ac.send(a.astype(np.float32), name="A2")
        hb2 = ac.send(b.astype(np.float32), name="B2")
        hc = ac.run("elemental", "gemm", ha2, hb2)
        c_alch = np.asarray(ac.collect(hc))
        t_alch = time.perf_counter() - t0
        s = ac.stats.summary()
        ac.stop()

        assert np.allclose(c_alch, c_spark.to_numpy(), atol=1e-2), "paths disagree"

        name = f"gemm_table1_m{m_k//1000}k_n{n_k//1000}k_k{k_k//1000}k"
        derived = (
            f"spark_wall_s={t_spark:.3f};alchemist_wall_s={t_alch:.3f};"
            f"speedup={t_spark/max(t_alch,1e-9):.1f}x;"
            f"spark_modeled_cori_s={modeled_spark:.1f};"
            f"send_s={s['send_seconds']:.3f};compute_s={s['compute_seconds']:.3f};"
            f"recv_s={s['recv_seconds']:.3f};"
            f"spark_shuffle_MB={spark_stats.shuffle_bytes/1e6:.1f};"
            f"spark_stages={spark_stats.stages}"
        )
        report.append(csv_row(name, t_alch * 1e6, derived))
