"""Batched serving demo: prefill + decode with the ServeEngine.

Run:  PYTHONPATH=src python examples/serve_batch.py --arch mamba2-130m
"""

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.sharding import single_device_mesh
from repro.models import build_model
from repro.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    mesh = single_device_mesh()
    model = build_model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(0))

    eng = ServeEngine(cfg, mesh, params, batch_size=4, context=128)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for n in (5, 9, 3, 7)
    ]
    outs = eng.serve(reqs)
    for i, o in enumerate(outs):
        print(f"req{i}: {o.tokens.tolist()}  "
              f"(prefill {o.prefill_seconds*1e3:.0f}ms, "
              f"{o.tokens_per_second:.1f} tok/s batch decode)")


if __name__ == "__main__":
    main()
