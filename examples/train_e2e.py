"""End-to-end training driver: train an assigned-architecture config on the
synthetic Markov stream and assert the loss genuinely falls.

Default is a CPU-sized run (reduced config, ~200 steps in a few minutes);
``--full`` selects the real config (for TPU deployments of this repo).

Run:  PYTHONPATH=src python examples/train_e2e.py --arch qwen2-1.5b --steps 200
"""

import argparse

from repro.configs import InputShape, get_config
from repro.core.sharding import single_device_mesh
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true", help="use the full (non-smoke) config")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    shape = InputShape("e2e", seq_len=args.seq, global_batch=args.batch, kind="train")
    mesh = single_device_mesh()

    print(f"training {cfg.arch_id}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps of {shape.tokens} tokens")
    hist = train(
        cfg, shape, mesh,
        steps=args.steps, peak_lr=args.lr, warmup=max(args.steps // 20, 5),
        log_every=max(args.steps // 20, 1),
        ckpt_dir=args.ckpt_dir, ckpt_every=0,
    )
    first, last = hist["loss"][0], hist["loss"][-1]
    drop = first - last
    print(f"loss: {first:.4f} -> {last:.4f} (drop {drop:.4f})")
    assert drop > 0.05, "training made no progress"
    print("OK: loss decreased.")


if __name__ == "__main__":
    main()
