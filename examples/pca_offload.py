"""PCA offload — the paper's headline workflow (§4.2), three ways, on v2.

A "Spark application" computes top-k PCA of a tall-skinny dataset and then
projects the dataset onto the principal components:
  1. MLlib-style (sparklike computeSVD: driver Lanczos, one cluster
     round-trip per matvec),
  2. naively offloaded through Alchemist — an **eager-policy** session where
     each call executes immediately and the PCA components are collected to
     the client and re-sent for the projection: the anti-pattern
     arXiv:1805.11800 warns about, now just a policy + two redundant
     crossings rather than a separate API,
  3. planned offload (DESIGN.md §6/§9) — the same code under the default
     **Planned** policy: the DAG keeps the components engine-resident, dedups
     the dataset send, and collects once.
It prints the paper's Send/Compute/Receive decomposition, the counted
Spark-side overheads (stages, driver syncs, shuffle bytes), and the planner's
elided-crossing / resident-reuse counters.

Run:  PYTHONPATH=src python examples/pca_offload.py
"""

import time

import numpy as np

import repro
from repro.sparklike import IndexedRowMatrix, SparkLikeContext, mllib
from repro.sparklike import offload


def make_dataset(m=6000, n=192, k_true=12, seed=0):
    """Low-rank + noise: the matrices PCA is for."""
    rng = np.random.default_rng(seed)
    factors = rng.standard_normal((m, k_true)) @ rng.standard_normal((k_true, n))
    return (factors + 0.1 * rng.standard_normal((m, n))).astype(np.float64)


def main() -> None:
    a = make_dataset()
    k = 8

    # ---------- path 1: Spark MLlib style -------------------------------
    ctx = SparkLikeContext(num_partitions=8)
    ir = IndexedRowMatrix.from_numpy(ctx, a - a.mean(0))
    t0 = time.perf_counter()
    _, sig_spark, v_spark = mllib.compute_svd(ir, k)
    t_spark = time.perf_counter() - t0
    print(f"[spark-like ] {t_spark*1e3:8.1f} ms | stages={ctx.stats.stages} "
          f"driver_syncs={ctx.stats.driver_syncs} "
          f"broadcast_MB={ctx.stats.broadcast_bytes/1e6:.1f}")

    # ---------- path 2: naive offload (eager policy, round trips) --------
    engine = repro.AlchemistEngine()
    a32 = a.astype(np.float32)
    t0 = time.perf_counter()
    with repro.connect(engine, name="pca_naive", policy="eager") as s:
        s.register_library("elemental", "repro.linalg.library:ElementalLib")
        al_a = s.send(a32, name="dataset")
        al_comps, al_scores, variance = s.run("elemental", "pca", al_a, n_outputs=3, k=k)
        comps = np.asarray(al_comps.data())          # bridge: engine → client
        al_comps_again = s.send(comps, name="comps")  # bridge: client → engine
        proj_naive = np.asarray((al_a @ al_comps_again).data())
        variance = np.asarray(variance.data())
        t_naive = time.perf_counter() - t0
        s_naive = s.stats.summary()
    naive_bytes = s_naive["send_bytes"] + s_naive["recv_bytes"]
    print(f"[naive/eager] {t_naive*1e3:8.1f} ms | send={s_naive['send_seconds']*1e3:.1f}ms "
          f"compute={s_naive['compute_seconds']*1e3:.1f}ms "
          f"recv={s_naive['recv_seconds']*1e3:.1f}ms "
          f"bridge_MB={naive_bytes/1e6:.2f}")

    # ---------- path 3: planned offload (default policy, crossings elided)
    t0 = time.perf_counter()
    with repro.connect(engine, name="pca_planned") as s2:
        s2.register_library("elemental", "repro.linalg.library:ElementalLib")
        la = s2.send(a32, name="dataset")
        comps_l, scores_l, var_l = s2.run("elemental", "pca", la, n_outputs=3, k=k)
        # projection consumes the engine-resident components: no collect, no
        # re-send — and the dataset node is reused, not re-shipped
        proj_l = la @ comps_l
        proj_planned = np.asarray(proj_l.data())
        variance2 = np.asarray(var_l.data())
        t_planned = time.perf_counter() - t0
        s_planned = s2.stats.summary()
        planned_bytes = s_planned["send_bytes"] + s_planned["recv_bytes"]
        print(f"[planned    ] {t_planned*1e3:8.1f} ms | "
              f"send={s_planned['send_seconds']*1e3:.1f}ms "
              f"compute={s_planned['compute_seconds']*1e3:.1f}ms "
              f"recv={s_planned['recv_seconds']*1e3:.1f}ms "
              f"bridge_MB={planned_bytes/1e6:.2f} "
              f"elided={s_planned['elided_crossings']} "
              f"reuses={s_planned['resident_reuses'] + s_planned['cross_session_reuses']}")

        # ---------- agreement ------------------------------------------------
        sig_alch = np.sqrt(np.asarray(variance) * (a.shape[0] - 1))
        rel = np.abs(sig_alch[:3] - sig_spark[:3]) / sig_spark[:3]
        print(f"top-3 sigma agreement: {np.round(rel, 4)} (relative)")
        # subspace agreement (principal angles ~ 0)
        overlap = np.linalg.svd(comps.T @ v_spark, compute_uv=False)
        print(f"subspace overlap (should be ~1): {np.round(overlap[:3], 4)}")
        assert (rel < 5e-2).all()

        # planned == naive numerics, strictly fewer bytes over the bridge
        np.testing.assert_allclose(proj_planned, proj_naive, atol=2e-2)
        np.testing.assert_allclose(variance2, variance, rtol=1e-5)
        assert s_planned["elided_crossings"] > 0, s_planned
        assert planned_bytes < naive_bytes, (planned_bytes, naive_bytes)
        print(f"bridge bytes: naive={naive_bytes/1e6:.2f} MB → "
              f"planned={planned_bytes/1e6:.2f} MB "
              f"({100 * (1 - planned_bytes / naive_bytes):.0f}% elided)")

        # ---------- drop-in: same MLlib call, engine-backed ------------------
        # arXiv:1805.11800's pitch verbatim: the path-1 code, unchanged,
        # inside an offloaded scope over the v2 session. U stays
        # engine-resident; sigmas match Spark's.
        with offload.offloaded(s2):
            u_lazy, sig_dropin, _ = mllib.compute_svd(ir, k)
        rel2 = np.abs(sig_dropin[:3] - sig_spark[:3]) / sig_spark[:3]
        print(f"[drop-in    ] mllib.compute_svd offloaded: U resident as "
              f"{type(u_lazy).__name__}, top-3 sigma agreement {np.round(rel2, 4)}")
        assert (rel2 < 5e-2).all()


if __name__ == "__main__":
    main()
