"""PCA offload — the paper's headline workflow (§4.2), both paths.

A "Spark application" computes top-k PCA of a tall-skinny dataset twice:
  1. MLlib-style (sparklike computeSVD: driver Lanczos, one cluster
     round-trip per matvec),
  2. offloaded through Alchemist (engine-resident matrix, Lanczos SVD on the
     worker grid).
It prints the paper's Send/Compute/Receive decomposition and the counted
Spark-side overheads (stages, driver syncs, shuffle bytes).

Run:  PYTHONPATH=src python examples/pca_offload.py
"""

import time

import numpy as np

from repro import AlchemistContext, AlchemistEngine
from repro.sparklike import IndexedRowMatrix, SparkLikeContext, mllib


def make_dataset(m=6000, n=192, k_true=12, seed=0):
    """Low-rank + noise: the matrices PCA is for."""
    rng = np.random.default_rng(seed)
    factors = rng.standard_normal((m, k_true)) @ rng.standard_normal((k_true, n))
    return (factors + 0.1 * rng.standard_normal((m, n))).astype(np.float64)


def main() -> None:
    a = make_dataset()
    k = 8

    # ---------- path 1: Spark MLlib style -------------------------------
    ctx = SparkLikeContext(num_partitions=8)
    ir = IndexedRowMatrix.from_numpy(ctx, a - a.mean(0))
    t0 = time.perf_counter()
    _, sig_spark, v_spark = mllib.compute_svd(ir, k)
    t_spark = time.perf_counter() - t0
    print(f"[spark-like ] {t_spark*1e3:8.1f} ms | stages={ctx.stats.stages} "
          f"driver_syncs={ctx.stats.driver_syncs} "
          f"broadcast_MB={ctx.stats.broadcast_bytes/1e6:.1f}")

    # ---------- path 2: offload via Alchemist ---------------------------
    engine = AlchemistEngine()
    ac = AlchemistContext(engine, name="pca_app")
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")

    al_a = ac.send(a.astype(np.float32), name="dataset")
    t0 = time.perf_counter()
    al_comps, al_scores, variance = ac.run("elemental", "pca", al_a, k=k)
    t_alch = time.perf_counter() - t0
    comps = np.asarray(ac.collect(al_comps))
    s = ac.stats.summary()
    print(f"[alchemist  ] {t_alch*1e3:8.1f} ms | send={s['send_seconds']*1e3:.1f}ms "
          f"compute={s['compute_seconds']*1e3:.1f}ms recv={s['recv_seconds']*1e3:.1f}ms")

    # ---------- agreement ------------------------------------------------
    sig_alch = np.sqrt(np.asarray(variance) * (a.shape[0] - 1))
    rel = np.abs(sig_alch[:3] - sig_spark[:3]) / sig_spark[:3]
    print(f"top-3 sigma agreement: {np.round(rel, 4)} (relative)")
    # subspace agreement (principal angles ~ 0)
    overlap = np.linalg.svd(comps.T @ v_spark, compute_uv=False)
    print(f"subspace overlap (should be ~1): {np.round(overlap[:3], 4)}")
    assert (rel < 5e-2).all()

    ac.stop()


if __name__ == "__main__":
    main()
