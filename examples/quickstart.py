"""Quickstart — the paper's §3.3 listing on the v2 surface (DESIGN.md §9).

Paper:                                    | Here (v2):
  val ac = new AlchemistContext(sc, n)    |   session = repro.connect(engine, workers=n)
  ac.registerLibrary("libA", loc)         |   session.register_library(...)
  val alA = AlMatrix(A)                   |   al_a = session.send(A)     # AlArray
  val out = ac.run("libA","condest",alA)  |   out = session.run("elemental","condest",al_a)
  ac.stop()                               |   session.close()

Everything is lazy by default (the Planned policy): operations build a DAG
and nothing crosses the client↔engine bridge until ``.data()`` demands a
result — intermediates stay engine-resident, exactly the AlMatrix contract.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import repro


def main() -> None:
    # start the Alchemist "server" (worker pool = this host's devices)
    engine = repro.AlchemistEngine()
    print(f"engine up: {engine.num_workers} worker(s)")

    # connect an application and load a library (the dlopen moment).
    # connect() is admission-aware: were the pool busy, this would queue
    # until a worker group frees up instead of failing.
    with repro.connect(engine, name="quickstart") as session:
        session.register_library("elemental", "repro.linalg.library:ElementalLib")

        # client-side data (the "RDD")
        rng = np.random.default_rng(0)
        a = rng.standard_normal((2000, 128)).astype(np.float32)

        # declare the transfer; the AlArray handle chains without executing
        al_a = session.send(a, name="A")
        print("declared:", al_a.state, al_a.shape)

        # the paper's running example: condition-number estimation
        cond = session.run("elemental", "condest", al_a)
        print(f"condest(A) = {float(cond.data()):.2f}  (numpy: "
              f"{np.linalg.cond(a):.2f})")

        # chained calls: TSQR's R factor squared — the intermediates never
        # leave the engine, and @ builds the same DAG session.run does
        al_q, al_r = session.run("elemental", "tsqr", al_a, n_outputs=2)
        al_r2 = al_r @ al_r
        print("chained result:", al_r2.state, "->", al_r2.shape)

        # rank-10 truncated SVD (the paper's flagship §4.2 routine)
        al_u, sigmas, al_v = session.run(
            "elemental", "truncated_svd", al_a, n_outputs=3, k=10
        )
        print("top-3 singular values:", np.round(np.asarray(sigmas.data())[:3], 3))

        # only now does bulk data cross back (the one explicit crossing);
        # under `with session.policy("eager")` every call would instead
        # execute immediately — same numbers, different schedule.
        u = np.asarray(al_u.data())
        print("U:", u.shape, "| transfer stats:", session.stats.summary())

    # the engine-wide picture: sessions, governor pressure, resident store
    print("engine snapshot:", {k: v for k, v in engine.stats()["engine"].items()})


if __name__ == "__main__":
    main()
