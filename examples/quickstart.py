"""Quickstart — the paper's §3.3 Scala listing, line-for-line in Python.

Paper:                                    | Here:
  val ac = new AlchemistContext(sc, n)    |   ac = AlchemistContext(engine, n)
  ac.registerLibrary("libA", loc)         |   ac.register_library(...)
  val alA = AlMatrix(A)                   |   al_a = ac.send(A)
  val out = ac.run("libA","condest",alA)  |   out = ac.run("elemental","condest",al_a)
  ac.stop()                               |   ac.stop()

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import AlchemistContext, AlchemistEngine


def main() -> None:
    # start the Alchemist "server" (worker pool = this host's devices)
    engine = AlchemistEngine()
    print(f"engine up: {engine.num_workers} worker(s)")

    # connect an application and load a library (the dlopen moment)
    ac = AlchemistContext(engine, name="quickstart")
    ac.register_library("elemental", "repro.linalg.library:ElementalLib")

    # client-side data (the "RDD")
    rng = np.random.default_rng(0)
    a = rng.standard_normal((2000, 128)).astype(np.float32)

    # ship it once; handles keep it engine-resident across calls
    al_a = ac.send(a, name="A")
    print("sent:", al_a)

    # the paper's running example: condition-number estimation
    cond = ac.run("elemental", "condest", al_a)
    print(f"condest(A) = {float(cond):.2f}  (numpy: "
          f"{np.linalg.cond(a):.2f})")

    # chained calls: TSQR's R factor squared, no client<->engine transfer —
    # the intermediate AlMatrix handles never leave the engine
    al_q, al_r = ac.run("elemental", "tsqr", al_a)
    al_r2 = ac.run("elemental", "gemm", al_r, al_r)
    print("chained result:", al_r2)

    # rank-10 truncated SVD (the paper's flagship §4.2 routine)
    al_u, sigmas, al_v = ac.run("elemental", "truncated_svd", al_a, k=10)
    print("top-3 singular values:", np.round(np.asarray(sigmas[:3]), 3))

    # only now does bulk data cross back (the AlMatrix contract)
    u = np.asarray(ac.collect(al_u))
    print("U:", u.shape, "| transfer stats:", ac.stats.summary())

    ac.stop()


if __name__ == "__main__":
    main()
