"""dasklike — a Dask-array-flavored frontend over the Alchemist session.

The Spark counterpart (``sparklike``) reproduces the paper's baseline
mechanics; this package demonstrates the other direction §6 gestures at: a
task-graph frontend whose lazy collections are *already* the v2 session
surface. ``from_array`` / ``compute`` / ``persist`` / ``svd`` are the
dask.array spellings; the DAG, the execution policy, and the bridge
accounting are the offload planner's. Works unchanged over any transport
(loopback or ``REPRO_TRANSPORT=tcp``).
"""

from repro.dasklike.array import (
    DaskLikeArray,
    compute,
    from_array,
    matmul,
    persist,
    svd,
)

__all__ = [
    "DaskLikeArray",
    "from_array",
    "compute",
    "persist",
    "matmul",
    "svd",
]
