"""Dask-flavored collections over an Alchemist session.

The paper frames Alchemist as an interface any task-graph frontend can sit
on (Spark is the worked example; §6 names Dask as the obvious sibling).
``sparklike`` plays the RDD story faithfully — this module is the Dask
counterpart, deliberately thin: a :class:`DaskLikeArray` is a Dask-style
lazy collection whose "graph" is the offload planner's expression DAG and
whose ``compute()`` is the one bridge crossing. Nothing here re-implements
scheduling; the point is that the v2 session surface already *is* the
delayed-collection contract (build lazily, ``compute``/``persist``
explicitly), so a Dask-shaped frontend is a naming layer.

The module is transport-agnostic by construction — it only speaks the
session API, so ``REPRO_TRANSPORT=tcp`` (or ``connect(transport=...)``)
puts every ``compute()`` on a real socket without touching this file.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple, Union

import numpy as np

from repro.core.client import AlArray, Session, connect as _connect

_ELEMENTAL = ("elemental", "repro.linalg.library:ElementalLib")


def _ensure_session(target: Any, **kwargs) -> Session:
    """A Session from a Session (as-is) or an engine (fresh connect)."""
    if isinstance(target, Session):
        sess = target
    else:
        sess = _connect(target, **kwargs)
    if _ELEMENTAL[0] not in sess.session.libraries:
        sess.register_library(*_ELEMENTAL)
    return sess


class DaskLikeArray:
    """A lazy 2D collection backed by an engine-resident :class:`AlArray`.

    Dask-array spellings (``compute``/``persist``/``@``/``.T``) over the
    planner's DAG. Chaining never executes; ``compute()`` forces the graph
    and returns a host ``np.ndarray``; ``persist()`` forces it but keeps the
    result engine-resident (Dask's distinction, mapped onto the bridge)."""

    __array_ufunc__ = None
    __array_priority__ = 1001  # above AlArray: ndarray @ us reaches __rmatmul__

    def __init__(self, al: AlArray, session: Session):
        self._al = al
        self._session = session

    # -- dask-style metadata -------------------------------------------------
    @property
    def shape(self) -> Optional[Tuple[int, int]]:
        return self._al.shape

    @property
    def dtype(self):
        return self._al.dtype

    @property
    def ndim(self) -> int:
        return 2

    # -- graph building ------------------------------------------------------
    def _wrap(self, al: AlArray) -> "DaskLikeArray":
        return DaskLikeArray(al, self._session)

    def _operand(self, other: Any) -> Any:
        return other._al if isinstance(other, DaskLikeArray) else other

    def __matmul__(self, other: Any) -> "DaskLikeArray":
        return self._wrap(self._al @ self._operand(other))

    def __rmatmul__(self, other: Any) -> "DaskLikeArray":
        return self._wrap(self._operand(other) @ self._al)

    def dot(self, other: Any) -> "DaskLikeArray":
        return self @ other

    @property
    def T(self) -> "DaskLikeArray":
        # No engine-side transpose routine: ship the flip through gemm with
        # an identity would be dishonest pricing, so transpose is a
        # client-side re-send of the (computed) value — explicit, like
        # dask's rechunk-to-transpose being a real data movement.
        host = np.asarray(self.compute()).T
        return from_array(self._session, np.ascontiguousarray(host))

    # -- execution -----------------------------------------------------------
    def compute(self) -> np.ndarray:
        """Force the DAG and pull the value client-side (the bridge
        crossing). Dask's ``.compute()`` contract: returns concrete data."""
        return np.asarray(self._al.data())

    def persist(self) -> "DaskLikeArray":
        """Force the DAG but keep the value engine-resident; returns self
        (now backed by materialized data), like ``dask.persist``."""
        self._al.materialize()
        return self

    def free(self) -> None:
        self._al.free()

    @property
    def state(self) -> str:
        return self._al.state

    def __repr__(self) -> str:
        return f"dasklike.Array(shape={self.shape}, dtype={self.dtype}, state={self.state!r})"


# -- module-level API (the dask.array spellings) ------------------------------
def from_array(target: Union[Session, Any], x: Any, name: str = "") -> DaskLikeArray:
    """Wrap a host array as a lazy engine-backed collection.

    ``target`` is a connected :class:`Session` or an engine (a session is
    opened over the default transport). The elemental library registers on
    first use. Equal payloads dedup through the session's content store."""
    sess = _ensure_session(target)
    return DaskLikeArray(sess.send(np.asarray(x), name=name), sess)


def compute(*collections: DaskLikeArray):
    """Force one or more collections; one argument returns its value,
    several return a tuple (the ``dask.compute`` shape)."""
    out = tuple(c.compute() for c in collections)
    return out[0] if len(out) == 1 else out


def persist(*collections: DaskLikeArray):
    out = tuple(c.persist() for c in collections)
    return out[0] if len(out) == 1 else out


def matmul(a: DaskLikeArray, b: Union[DaskLikeArray, Any]) -> DaskLikeArray:
    return a @ b


def svd(a: DaskLikeArray, k: int = 10, **params) -> Tuple[DaskLikeArray, ...]:
    """Truncated SVD on the engine (elemental ``truncated_svd``); returns
    lazy ``(u, s, v)`` — factors stay engine-resident until computed."""
    sess = a._session
    u, s, v = sess.run("elemental", "truncated_svd", a._al, n_outputs=3, k=k, **params)
    return a._wrap(u), a._wrap(s), a._wrap(v)
