"""Mixture-of-Experts with grouped, sort-based, expert-parallel dispatch.

Routing happens in *groups* — one group per data-parallel shard — so the
argsort/scatter bookkeeping never crosses devices; the only cross-device
movement is the dispatch of the packed expert buffers from batch sharding to
expert sharding. That boundary lowers to an **all-to-all**, which is exactly
the engine relayout primitive of the paper (DESIGN.md §4): the MoE layer is
the Alchemist bridge applied per-layer.

Dispatch is sort/scatter-based (not one-hot-einsum) so HLO FLOPs stay
honest: the one-hot formulation inflates compiled FLOPs by O(T²k/E·D) of
mask matmuls, which would poison the §Roofline compute term.

Capacity: per group, ``C = min(Tg, max(ceil(Tg·K·cf / E), min_capacity))``;
overflow tokens are dropped (GShard semantics) and the drop fraction is
reported as a metric.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Ax, ParamDef


def moe_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    assert cfg.moe is not None
    d, e, f = cfg.d_model, cfg.moe.num_experts, cfg.moe.d_expert
    if cfg.moe_shard_expert_ff:
        # Megatron-in-expert: shard the FF dim over the fsdp axis; the
        # contraction over F reduces activations (cheap at decode) instead
        # of gathering weights
        return {
            "router": ParamDef((d, e), (None, None), scale=0.02),
            "w_gate": ParamDef((e, d, f), ("expert", None, "fsdp")),
            "w_up": ParamDef((e, d, f), ("expert", None, "fsdp")),
            "w_down": ParamDef((e, f, d), ("expert", "fsdp", None)),
        }
    return {
        "router": ParamDef((d, e), (None, None), scale=0.02),
        "w_gate": ParamDef((e, d, f), ("expert", "fsdp", None)),
        "w_up": ParamDef((e, d, f), ("expert", "fsdp", None)),
        "w_down": ParamDef((e, f, d), ("expert", None, "fsdp")),
    }


def moe_block(
    cfg: ArchConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,            # [B, L, D]
    ax: Ax,
    *,
    num_groups: int,
    min_capacity: int = 8,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    moe = cfg.moe
    assert moe is not None
    b, seq, d = x.shape
    t_total = b * seq
    g = max(min(num_groups, t_total), 1)
    while t_total % g:
        g -= 1
    tg = t_total // g
    e, k = moe.num_experts, moe.top_k
    cap = min(tg, max(math.ceil(tg * k * moe.capacity_factor / e), min_capacity))

    xt = x.reshape(g, tg, d)
    xt = ax(xt, "batch", None, None)

    # ---- routing (f32) -----------------------------------------------------
    router_logits = jnp.einsum(
        "gtd,de->gte", xt.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)            # [G, Tg, E]
    gates, ids = jax.lax.top_k(probs, k)                      # [G, Tg, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    ids_f = ids.reshape(g, tg * k)
    gates_f = gates.reshape(g, tg * k)
    order = jnp.argsort(ids_f, axis=-1, stable=True)          # [G, TgK]
    sorted_ids = jnp.take_along_axis(ids_f, order, axis=-1)
    src_tok = order // k

    counts = jnp.sum(jax.nn.one_hot(ids_f, e, dtype=jnp.int32), axis=1)  # [G, E]
    offsets = jnp.cumsum(counts, axis=-1) - counts                       # [G, E]
    pos = jnp.arange(tg * k)[None, :] - jnp.take_along_axis(offsets, sorted_ids, axis=-1)
    keep = pos < cap
    dest = jnp.where(keep, sorted_ids * cap + pos, e * cap)   # overflow slot

    # ---- pack into expert buffers (local to each group) ----------------------
    x_sorted = jnp.take_along_axis(xt, src_tok[..., None], axis=1)       # [G, TgK, D]

    def pack(xs, ds):
        return jnp.zeros((e * cap + 1, d), xs.dtype).at[ds].set(xs)

    buf = jax.vmap(pack)(x_sorted, dest)[:, : e * cap].reshape(g, e, cap, d)
    # dispatch boundary: groups stay on the batch axes, experts move to the
    # tensor axis -> XLA emits the all-to-all here
    buf = ax(buf, "batch", "expert", None, None)

    # ---- expert FFN (SwiGLU) -------------------------------------------------
    w_gate = p["w_gate"].astype(buf.dtype)
    w_up = p["w_up"].astype(buf.dtype)
    w_down = p["w_down"].astype(buf.dtype)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, w_gate)) * jnp.einsum(
        "gecd,edf->gecf", buf, w_up
    )
    out = jnp.einsum("gecf,efd->gecd", h, w_down)
    out = ax(out, "batch", "expert", None, None)

    # ---- combine back (undispatch) -------------------------------------------
    out_flat = out.reshape(g, e * cap, d)
    out_flat = jnp.concatenate([out_flat, jnp.zeros((g, 1, d), out.dtype)], axis=1)
    y_sorted = jnp.take_along_axis(out_flat, dest[..., None], axis=1)    # [G, TgK, D]
    gates_sorted = jnp.take_along_axis(gates_f, order, axis=-1) * keep

    def combine(ys, ws, toks):
        return jnp.zeros((tg, d), ys.dtype).at[toks].add(ys * ws[:, None].astype(ys.dtype))

    y = jax.vmap(combine)(y_sorted, gates_sorted, src_tok).reshape(b, seq, d)

    # ---- aux: load-balance loss + drop fraction --------------------------------
    frac_tokens = counts.astype(jnp.float32) / (tg * k)                  # [G, E]
    mean_probs = probs.mean(axis=1)                                      # [G, E]
    aux = e * jnp.mean(jnp.sum(frac_tokens * mean_probs, axis=-1))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y, {"moe_aux": aux, "moe_dropped": dropped}
