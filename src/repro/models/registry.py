"""Model registry + input specs.

``build_model(cfg, mesh, ...)`` returns the right family class;
``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of an (arch x input-shape) combination — weak-type-correct,
shardable, no device allocation — which is exactly what the multi-pod
dry-run lowers against.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.core.sharding import ShardingRules


def build_model(
    cfg: ArchConfig,
    mesh: Mesh,
    rules: Optional[ShardingRules] = None,
    *,
    sliding_window: Optional[int] = None,
    remat: str = "none",
    scan_unroll: int = 1,
):
    if rules is None:
        rules = ShardingRules.default(mesh)
    if cfg.family in ("dense", "moe", "ssm", "vlm"):
        from repro.models.transformer import DecoderLM

        return DecoderLM(cfg, mesh, rules, sliding_window=sliding_window,
                         remat=remat, scan_unroll=scan_unroll)
    if cfg.family == "hybrid":
        from repro.models.hybrid import HybridLM

        return HybridLM(cfg, mesh, rules, remat=remat, scan_unroll=scan_unroll)
    if cfg.family == "audio":
        from repro.models.encdec import EncDecLM

        return EncDecLM(cfg, mesh, rules, remat=remat, scan_unroll=scan_unroll)
    raise KeyError(f"no model family {cfg.family!r}")


def effective_seq(cfg: ArchConfig, shape: InputShape) -> int:
    """Decoder sequence length actually used (whisper caps at 448)."""
    if cfg.is_enc_dec:
        return min(shape.seq_len, cfg.decoder_max_seq)
    return shape.seq_len


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the *batch* inputs of (cfg, shape).

    train/prefill: the full token batch (+ stub frontend embeddings).
    decode: a single-token batch; the KV/SSM cache specs come from
    :func:`decode_state_structs`.
    """
    b = shape.global_batch
    seq = effective_seq(cfg, shape)
    act_dtype = jnp.dtype(cfg.compute_dtype)

    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    if cfg.family == "audio":
        return {
            "frames": jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), act_dtype),
            "tokens": jax.ShapeDtypeStruct((b, seq), jnp.int32),
        }
    if cfg.family == "vlm":
        tv = min(cfg.vision_tokens, seq // 2)
        return {
            "tokens": jax.ShapeDtypeStruct((b, seq - tv), jnp.int32),
            "vision_embeds": jax.ShapeDtypeStruct((b, tv, cfg.d_model), act_dtype),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, seq), jnp.int32)}


def input_shardings(cfg: ArchConfig, shape: InputShape, rules: ShardingRules) -> Dict[str, P]:
    """PartitionSpecs matching :func:`input_specs` (batch over the data axes)."""
    batch = rules.batch if len(rules.batch) != 1 else rules.batch[0]
    specs = {}
    for name, s in input_specs(cfg, shape).items():
        specs[name] = P(*([batch] + [None] * (len(s.shape) - 1)))
    return specs


def decode_state_structs(model, cfg: ArchConfig, shape: InputShape):
    """ShapeDtypeStructs for the decode cache of (cfg, shape) — built via
    eval_shape so nothing is allocated."""
    b = shape.global_batch
    ctx = effective_seq(cfg, shape)
    return jax.eval_shape(lambda: model.init_decode_state(b, ctx))


def make_batch(cfg: ArchConfig, shape: InputShape, key: jax.Array) -> Dict[str, jax.Array]:
    """Materialize a random batch matching input_specs (smoke tests/examples)."""
    specs = input_specs(cfg, shape)
    out: Dict[str, jax.Array] = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, s.shape, 0, cfg.vocab, dtype=s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32).astype(s.dtype)
    return out
