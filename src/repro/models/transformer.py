"""DecoderLM — the uniform decoder-only language model.

Covers the dense (qwen2/qwen3/deepseek-7b/deepseek-coder-33b), MoE (olmoe,
arctic), SSM (mamba2) and VLM (internvl2) families through the ArchConfig:
the per-layer mixer is attention or SSD, the per-layer FFN is dense MLP or
MoE (optionally with arctic's dense residual), and VLM configs prepend
precomputed patch embeddings (stub frontend per the assignment carve-out).

Layers are homogeneous, so the whole stack is one ``lax.scan`` over stacked
parameters — compile time and HLO size stay flat in depth, which is what
makes the 62-layer dry-runs tractable.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.core.sharding import ShardingRules
from repro.models import attention as attn_mod
from repro.models import common, mlp as mlp_mod, moe as moe_mod, ssm as ssm_mod
from repro.models.common import Ax, ParamDef


def stack_defs(defs, n: int):
    """Prepend a layer dimension to every ParamDef in a tree."""
    return common.tree_map_defs(
        lambda d: ParamDef((n,) + d.shape, (None,) + d.logical, init=d.init, scale=d.scale),
        defs,
    )


class DecodeState(NamedTuple):
    """Per-layer caches, stacked on a leading layer axis, plus the position."""

    kv: Optional[attn_mod.KVCache]      # stacked [L, B, S, Hkv, hd] or None
    ssm: Optional[ssm_mod.SSMCache]     # stacked [L, B, ...] or None
    pos: jax.Array                      # [] int32: next absolute position


class DecoderLM:
    def __init__(
        self,
        cfg: ArchConfig,
        mesh: Mesh,
        rules: Optional[ShardingRules] = None,
        *,
        sliding_window: Optional[int] = None,
        remat: str = "none",            # none | full | dots
        scan_unroll: int = 1,           # dry-run uses full unroll so HLO
                                        # cost analysis sees every layer
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules or ShardingRules.default(mesh)
        self.ax = Ax(self.rules, mesh)
        self.sliding_window = sliding_window
        self.remat = remat
        self.scan_unroll = scan_unroll
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.num_groups = (
            int(np.prod([sizes[a] for a in self.rules.batch], dtype=np.int64))
            if self.rules.batch
            else 1
        )
        self.compute_dtype = jnp.dtype(cfg.compute_dtype)

    # ------------------------------------------------------------------ defs
    def layer_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        defs: Dict[str, Any] = {
            "norm1": common.norm_defs(cfg, cfg.d_model),
            "norm2": common.norm_defs(cfg, cfg.d_model),
        }
        if cfg.family == "ssm":
            defs["ssm"] = ssm_mod.ssm_defs(cfg)
            # pure-SSM blocks are mixer-only: norm2/ffn unused but kept for
            # layout uniformity? No — mamba2 has one block per layer.
            del defs["norm2"]
            return defs
        defs["attn"] = attn_mod.attn_defs(cfg)
        if cfg.moe is not None and cfg.moe.every_k_layers == 1:
            defs["moe"] = moe_mod.moe_defs(cfg)
            if cfg.moe.dense_residual:
                defs["mlp"] = mlp_mod.mlp_defs(cfg, cfg.d_ff)
        else:
            defs["mlp"] = mlp_mod.mlp_defs(cfg, cfg.d_ff)
        return defs

    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        defs: Dict[str, Any] = dict(common.embedding_defs(cfg))
        defs["layers"] = stack_defs(self.layer_defs(), cfg.n_layers)
        defs["final_norm"] = common.norm_defs(cfg, cfg.d_model)
        if cfg.family == "vlm":
            # projector bias only: patch embeddings arrive pre-projected from
            # the stub frontend, we keep a learned scale/shift adapter
            defs["vision_adapter"] = {
                "scale": ParamDef((cfg.d_model,), (None,), init="ones"),
                "bias": ParamDef((cfg.d_model,), (None,), init="zeros"),
            }
        if cfg.pos_emb == "learned":
            defs["pos_embed"] = ParamDef(
                (max(cfg.decoder_max_seq, 2048), cfg.d_model), (None, "fsdp"), scale=0.02
            )
        return defs

    def init(self, key: jax.Array):
        return common.init_params(self.param_defs(), key, jnp.dtype(self.cfg.param_dtype))

    def param_partition_specs(self):
        return common.partition_specs(self.param_defs(), self.rules, self.mesh)

    def param_shapes(self):
        return common.shape_structs(self.param_defs(), jnp.dtype(self.cfg.param_dtype))

    # ------------------------------------------------------------- layer fns
    def _layer_train(self, x: jax.Array, lp: Dict[str, Any], positions: jax.Array):
        cfg, ax = self.cfg, self.ax
        aux: Dict[str, jax.Array] = {}
        if cfg.family == "ssm":
            h = common.apply_norm(cfg, lp["norm1"], x)
            x = x + ssm_mod.ssm_block(cfg, lp["ssm"], h, ax)
            return x, aux
        h = common.apply_norm(cfg, lp["norm1"], x)
        x = x + attn_mod.attention_block(
            cfg, lp["attn"], h, ax,
            positions=positions, causal=True, window=self.sliding_window,
        )
        x = ax(x, "batch", "sequence", None)
        h = common.apply_norm(cfg, lp["norm2"], x)
        if "moe" in lp:
            y, aux = moe_mod.moe_block(cfg, lp["moe"], h, ax, num_groups=self.num_groups)
            if "mlp" in lp:  # arctic dense residual
                y = y + mlp_mod.mlp_block(cfg, lp["mlp"], h, ax)
        else:
            y = mlp_mod.mlp_block(cfg, lp["mlp"], h, ax)
        x = ax(x + y, "batch", "sequence", None)
        return x, aux

    def _scan(self, x, layers, fn):
        if self.remat == "full":
            fn = jax.checkpoint(fn)
        elif self.remat == "dots":
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            )

        def body(carry, lp):
            return fn(carry, lp)

        x, auxs = jax.lax.scan(body, x, layers, unroll=self.scan_unroll)
        return x, auxs

    # --------------------------------------------------------------- forward
    def embed_inputs(self, params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
        """Returns (x [B, L, D], loss_mask [B, L]) — handles the VLM prefix."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = common.embed_tokens(params, tokens, self.compute_dtype)
        mask = jnp.ones(tokens.shape, jnp.float32)
        if cfg.family == "vlm":
            vis = batch["vision_embeds"].astype(self.compute_dtype)
            va = params["vision_adapter"]
            vis = vis * va["scale"].astype(vis.dtype) + va["bias"].astype(vis.dtype)
            x = jnp.concatenate([vis, x], axis=1)
            mask = jnp.concatenate([jnp.zeros(vis.shape[:2], jnp.float32), mask], axis=1)
        if cfg.pos_emb == "learned":
            pe = params["pos_embed"][: x.shape[1]].astype(x.dtype)
            x = x + pe[None]
        return self.ax(x, "batch", "sequence", None), mask

    def forward(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        """Training forward: logits [B, L, Vpad]."""
        cfg = self.cfg
        x, _ = self.embed_inputs(params, batch)
        b, seq, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(seq)[None], (b, seq))
        fn = functools.partial(self._layer_train, positions=positions)
        x, _ = self._scan(x, params["layers"], lambda c, lp: fn(c, lp))
        x = common.apply_norm(cfg, params["final_norm"], x)
        return common.unembed(cfg, params, x)

    def loss(self, params, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x, mask = self.embed_inputs(params, batch)
        b, seq, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(seq)[None], (b, seq))
        x, auxs = self._scan(
            x, params["layers"],
            lambda c, lp: self._layer_train(c, lp, positions=positions),
        )
        x = common.apply_norm(cfg, params["final_norm"], x)
        logits = common.unembed(cfg, params, x)            # [B, L, Vpad]
        logits = self.ax(logits, "batch", None, "tensor")

        # next-token targets over the full (possibly vision-prefixed) sequence
        tokens = batch["tokens"]
        n_prefix = seq - tokens.shape[1]
        targets = tokens[:, 1:]                            # [B, Lt-1]
        pred_slice = jax.lax.dynamic_slice_in_dim(logits, n_prefix, tokens.shape[1] - 1, axis=1)
        xent, acc = _masked_xent(cfg, pred_slice, targets, batch.get("loss_mask"))

        metrics = {"xent": xent, "accuracy": acc}
        total = xent
        if auxs:
            aux_mean = {k: jnp.mean(v) for k, v in auxs.items()}
            metrics.update(aux_mean)
            if "moe_aux" in aux_mean and cfg.moe is not None:
                total = total + cfg.moe.router_aux_weight * aux_mean["moe_aux"]
        metrics["loss"] = total
        return total, metrics

    # --------------------------------------------------------------- prefill
    def prefill(
        self, params, batch: Dict[str, jax.Array], *, context: Optional[int] = None
    ) -> Tuple[jax.Array, DecodeState]:
        """Process a prompt, returning last-token logits + populated caches.

        ``context`` reserves cache capacity beyond the prompt (defaults to
        prompt length). With sliding window W (and W | prompt length), the
        cache is the last window, already ring-aligned.
        """
        cfg, ax = self.cfg, self.ax
        x, _ = self.embed_inputs(params, batch)
        b, seq, _ = x.shape
        ctx = context or seq
        positions = jnp.broadcast_to(jnp.arange(seq)[None], (b, seq))

        if cfg.family == "ssm":
            def body(carry, lp):
                h = common.apply_norm(cfg, lp["norm1"], carry)
                y, cache = ssm_mod.ssm_block(cfg, lp["ssm"], h, ax, return_cache=True)
                return carry + y, cache

            x, ssm_caches = jax.lax.scan(body, x, params["layers"], unroll=self.scan_unroll)
            state = DecodeState(kv=None, ssm=ssm_caches, pos=jnp.asarray(seq, jnp.int32))
        else:
            w = self.sliding_window

            def body(carry, lp):
                h = common.apply_norm(cfg, lp["norm1"], carry)
                y, (k, v) = attn_mod.attention_block(
                    cfg, lp["attn"], h, ax,
                    positions=positions, causal=True, window=w, return_kv=True,
                )
                xx = carry + y
                h2 = common.apply_norm(cfg, lp["norm2"], xx)
                if "moe" in lp:
                    f, _ = moe_mod.moe_block(cfg, lp["moe"], h2, ax, num_groups=self.num_groups)
                    if "mlp" in lp:
                        f = f + mlp_mod.mlp_block(cfg, lp["mlp"], h2, ax)
                else:
                    f = mlp_mod.mlp_block(cfg, lp["mlp"], h2, ax)
                return xx + f, (k, v)

            x, (ks, vs) = jax.lax.scan(body, x, params["layers"], unroll=self.scan_unroll)
            if w is not None:
                if seq % w == 0 and seq >= w:
                    ks, vs = ks[:, :, seq - w :], vs[:, :, seq - w :]  # ring-aligned
                elif seq > w:
                    raise ValueError(
                        f"sliding-window prefill needs window | prompt ({w} vs {seq})"
                    )
                cache_len = min(w, ctx)
            else:
                cache_len = ctx
            pad = cache_len - ks.shape[2]
            if pad > 0:
                zeros = jnp.zeros(ks.shape[:2] + (pad,) + ks.shape[3:], ks.dtype)
                ks = jnp.concatenate([ks, zeros], axis=2)
                vs = jnp.concatenate([vs, zeros], axis=2)
            state = DecodeState(
                kv=attn_mod.KVCache(
                    k=ks.astype(self.compute_dtype), v=vs.astype(self.compute_dtype)
                ),
                ssm=None,
                pos=jnp.asarray(seq, jnp.int32),
            )

        x = common.apply_norm(cfg, params["final_norm"], x)
        logits = common.unembed(cfg, params, x[:, -1])
        return _mask_pad_vocab(cfg, logits), state

    # ------------------------------------------------------ decode sharding
    def _kv_cache_logical(self) -> Tuple:
        """KV cache [L, B, S, Hkv, hd]: shard heads over the tensor axis when
        divisible, else shard the sequence dim (context-parallel decode)."""
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        tensor = 1
        for a in self.rules.tensor:
            tensor *= sizes.get(a, 1)
        if tensor > 1 and self.cfg.n_kv_heads and self.cfg.n_kv_heads % tensor == 0:
            return (None, "batch", None, "tensor", None)
        return (None, "batch", "tensor", None, None)

    def decode_state_logical(self) -> "DecodeState":
        cfg = self.cfg
        kv = ssm_spec = None
        if cfg.family != "ssm":
            spec = self._kv_cache_logical()
            kv = attn_mod.KVCache(k=spec, v=spec)
        else:
            ssm_spec = ssm_mod.SSMCache(
                conv=(None, "batch", None, "tensor"),
                state=(None, "batch", "tensor", None, None),
            )
        return DecodeState(kv=kv, ssm=ssm_spec, pos=())

    # ---------------------------------------------------------------- decode
    def init_decode_state(self, batch: int, context: int, dtype=None) -> DecodeState:
        cfg = self.cfg
        dtype = dtype or self.compute_dtype
        n = cfg.n_layers
        kv = None
        ssm_state = None
        if cfg.family != "ssm":
            one = attn_mod.init_cache(cfg, batch, context, dtype, window=self.sliding_window)
            kv = attn_mod.KVCache(
                k=jnp.zeros((n,) + one.k.shape, dtype), v=jnp.zeros((n,) + one.v.shape, dtype)
            )
        if cfg.family == "ssm":
            one = ssm_mod.init_ssm_cache(cfg, batch, dtype)
            ssm_state = ssm_mod.SSMCache(
                conv=jnp.zeros((n,) + one.conv.shape, dtype),
                state=jnp.zeros((n,) + one.state.shape, dtype),
            )
        return DecodeState(kv=kv, ssm=ssm_state, pos=jnp.zeros((), jnp.int32))

    def decode_step(
        self, params, state: DecodeState, tokens: jax.Array
    ) -> Tuple[jax.Array, DecodeState]:
        """One token for every sequence: tokens [B, 1] -> logits [B, Vpad]."""
        cfg, ax = self.cfg, self.ax
        x = common.embed_tokens(params, tokens, self.compute_dtype)
        if cfg.pos_emb == "learned":
            x = x + params["pos_embed"][state.pos][None, None].astype(x.dtype)
        x = ax(x, "batch", None, None)
        pos = state.pos

        if cfg.family == "ssm":
            def body(carry, lp_cache):
                lp, cache = lp_cache
                h = common.apply_norm(cfg, lp["norm1"], carry)
                y, new_cache = ssm_mod.ssm_decode_step(cfg, lp["ssm"], h, cache, ax)
                return carry + y, new_cache

            x, new_ssm = jax.lax.scan(
                body, x, (params["layers"], state.ssm), unroll=self.scan_unroll
            )
            new_state = DecodeState(kv=None, ssm=new_ssm, pos=pos + 1)
        else:
            def body(carry, lp_cache):
                lp, cache = lp_cache
                h = common.apply_norm(cfg, lp["norm1"], carry)
                y, new_kv = attn_mod.decode_attention(
                    cfg, lp["attn"], h, cache, pos, ax, window=self.sliding_window
                )
                xx = carry + y
                h2 = common.apply_norm(cfg, lp["norm2"], xx)
                if "moe" in lp:
                    f, _ = moe_mod.moe_block(cfg, lp["moe"], h2, ax, num_groups=self.num_groups)
                    if "mlp" in lp:
                        f = f + mlp_mod.mlp_block(cfg, lp["mlp"], h2, ax)
                else:
                    f = mlp_mod.mlp_block(cfg, lp["mlp"], h2, ax)
                return xx + f, new_kv

            x, new_kv = jax.lax.scan(
                body, x, (params["layers"], state.kv), unroll=self.scan_unroll
            )
            new_state = DecodeState(kv=new_kv, ssm=None, pos=pos + 1)

        x = common.apply_norm(cfg, params["final_norm"], x)
        logits = common.unembed(cfg, params, x)[:, 0]
        return _mask_pad_vocab(cfg, logits), new_state


def _mask_pad_vocab(cfg: ArchConfig, logits: jax.Array) -> jax.Array:
    if cfg.padded_vocab == cfg.vocab:
        return logits
    neg = jnp.full((cfg.padded_vocab - cfg.vocab,), -1e30, logits.dtype)
    return logits.at[..., cfg.vocab :].set(neg)


def _masked_xent(
    cfg: ArchConfig,
    logits: jax.Array,          # [B, T, Vpad]
    targets: jax.Array,         # [B, T]
    loss_mask: Optional[jax.Array],
) -> Tuple[jax.Array, jax.Array]:
    logits32 = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        # mask the padding ids out of the partition function
        pad = jnp.full((cfg.padded_vocab - cfg.vocab,), -1e30, jnp.float32)
        logits32 = logits32.at[..., cfg.vocab :].set(pad)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    picked = jnp.take_along_axis(logits32, targets[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if loss_mask is not None:
        m = loss_mask[:, 1 : 1 + targets.shape[1]]
        nll = nll * m
        denom = jnp.maximum(m.sum(), 1.0)
    else:
        denom = jnp.asarray(nll.size, jnp.float32)
    xent = nll.sum() / denom
    acc_hits = (jnp.argmax(logits32, axis=-1) == targets).astype(jnp.float32)
    if loss_mask is not None:
        acc = (acc_hits * m).sum() / denom
    else:
        acc = acc_hits.mean()
    return xent, acc
