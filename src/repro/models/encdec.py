"""EncDecLM — Whisper-style encoder-decoder transformer.

The mel-spectrogram + conv frontend is a STUB per the assignment carve-out:
the model consumes precomputed frame embeddings [B, encoder_seq, d_model]
(``input_specs`` provides them). Everything downstream — 32-layer encoder,
32-layer decoder with cross-attention, sinusoidal/learned positions, GELU
MLPs, LayerNorm — is implemented.

Shape policy (DESIGN.md §5): the whisper decoder context is architecturally
capped at ``decoder_max_seq`` (448); assigned shapes with longer seq_len run
at the cap with the assigned global batch. ``long_500k`` is skipped.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.core.sharding import ShardingRules
from repro.models import attention as attn_mod
from repro.models import common, mlp as mlp_mod
from repro.models.common import Ax, ParamDef
from repro.models.transformer import _mask_pad_vocab, _masked_xent, stack_defs


class EncDecDecodeState(NamedTuple):
    self_kv: attn_mod.KVCache          # [L, B, S_dec, H, hd]
    cross_kv: Tuple[jax.Array, jax.Array]  # precomputed: [L, B, S_enc, H, hd] x2
    pos: jax.Array


class EncDecLM:
    def __init__(self, cfg: ArchConfig, mesh: Mesh, rules: Optional[ShardingRules] = None,
                 *, remat: str = "none", scan_unroll: int = 1):
        assert cfg.encoder_layers > 0
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules or ShardingRules.default(mesh)
        self.ax = Ax(self.rules, mesh)
        self.remat = remat
        self.scan_unroll = scan_unroll
        self.compute_dtype = jnp.dtype(cfg.compute_dtype)

    # ------------------------------------------------------------------ defs
    def enc_layer_defs(self):
        cfg = self.cfg
        return {
            "norm1": common.norm_defs(cfg, cfg.d_model),
            "attn": attn_mod.attn_defs(cfg),
            "norm2": common.norm_defs(cfg, cfg.d_model),
            "mlp": mlp_mod.mlp_defs(cfg, cfg.d_ff),
        }

    def dec_layer_defs(self):
        cfg = self.cfg
        return {
            "norm1": common.norm_defs(cfg, cfg.d_model),
            "self_attn": attn_mod.attn_defs(cfg),
            "norm_x": common.norm_defs(cfg, cfg.d_model),
            "cross_attn": attn_mod.attn_defs(cfg, cross=True),
            "norm2": common.norm_defs(cfg, cfg.d_model),
            "mlp": mlp_mod.mlp_defs(cfg, cfg.d_ff),
        }

    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            **common.embedding_defs(cfg),
            "encoder": stack_defs(self.enc_layer_defs(), cfg.encoder_layers),
            "enc_final_norm": common.norm_defs(cfg, cfg.d_model),
            "decoder": stack_defs(self.dec_layer_defs(), cfg.n_layers),
            "final_norm": common.norm_defs(cfg, cfg.d_model),
            "pos_embed": ParamDef((cfg.decoder_max_seq, cfg.d_model), (None, "fsdp"), scale=0.02),
        }

    def init(self, key):
        return common.init_params(self.param_defs(), key, jnp.dtype(self.cfg.param_dtype))

    def param_partition_specs(self):
        return common.partition_specs(self.param_defs(), self.rules, self.mesh)

    def param_shapes(self):
        return common.shape_structs(self.param_defs(), jnp.dtype(self.cfg.param_dtype))

    # --------------------------------------------------------------- encoder
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: [B, S_enc, D] stub embeddings -> encoder output."""
        cfg, ax = self.cfg, self.ax
        x = frames.astype(self.compute_dtype)
        x = x + common.sinusoidal_embedding(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        x = ax(x, "batch", None, None)

        def layer(carry, lp):
            h = common.apply_norm(cfg, lp["norm1"], carry)
            carry = carry + attn_mod.attention_block(cfg, lp["attn"], h, ax, causal=False)
            carry = ax(carry, "batch", "sequence", None)
            h = common.apply_norm(cfg, lp["norm2"], carry)
            carry = carry + mlp_mod.mlp_block(cfg, lp["mlp"], h, ax)
            return ax(carry, "batch", "sequence", None), None

        fn = jax.checkpoint(layer) if self.remat != "none" else layer
        x, _ = jax.lax.scan(fn, x, params["encoder"], unroll=self.scan_unroll)
        return common.apply_norm(cfg, params["enc_final_norm"], x)

    def _cross_kv(self, lp, enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        b, s, _ = enc_out.shape
        k = (enc_out @ lp["cross_attn"]["wk"].astype(enc_out.dtype)).reshape(
            b, s, cfg.n_kv_heads, cfg.head_dim
        )
        v = (enc_out @ lp["cross_attn"]["wv"].astype(enc_out.dtype)).reshape(
            b, s, cfg.n_kv_heads, cfg.head_dim
        )
        return k, v

    # --------------------------------------------------------------- decoder
    def _decoder_layer(self, x, lp, enc_out, positions):
        cfg, ax = self.cfg, self.ax
        h = common.apply_norm(cfg, lp["norm1"], x)
        x = x + attn_mod.attention_block(
            cfg, lp["self_attn"], h, ax, positions=positions, causal=True
        )
        h = common.apply_norm(cfg, lp["norm_x"], x)
        x = x + attn_mod.attention_block(
            cfg, lp["cross_attn"], h, ax, cross_kv=self._cross_kv(lp, enc_out)
        )
        h = common.apply_norm(cfg, lp["norm2"], x)
        return ax(x + mlp_mod.mlp_block(cfg, lp["mlp"], h, ax), "batch", "sequence", None), None

    def forward(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        """batch: frames [B, S_enc, D] + tokens [B, L_dec] -> logits."""
        cfg, ax = self.cfg, self.ax
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        x = common.embed_tokens(params, tokens, self.compute_dtype)
        x = x + params["pos_embed"][: x.shape[1]].astype(x.dtype)[None]
        x = ax(x, "batch", None, None)
        b, seq, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(seq)[None], (b, seq))

        layer = functools.partial(self._decoder_layer, enc_out=enc_out, positions=positions)
        fn = jax.checkpoint(lambda c, lp: layer(c, lp)) if self.remat != "none" else (
            lambda c, lp: layer(c, lp)
        )
        x, _ = jax.lax.scan(fn, x, params["decoder"], unroll=self.scan_unroll)
        x = common.apply_norm(cfg, params["final_norm"], x)
        return common.unembed(cfg, params, x)

    def loss(self, params, batch):
        logits = self.forward(params, batch)
        tokens = batch["tokens"]
        xent, acc = _masked_xent(self.cfg, logits[:, :-1], tokens[:, 1:], batch.get("loss_mask"))
        return xent, {"loss": xent, "xent": xent, "accuracy": acc}

    # ------------------------------------------------------ decode sharding
    def decode_state_logical(self) -> "EncDecDecodeState":
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        tensor = 1
        for a in self.rules.tensor:
            tensor *= sizes.get(a, 1)
        if tensor > 1 and self.cfg.n_kv_heads % tensor == 0:
            spec = (None, "batch", None, "tensor", None)
        else:
            spec = (None, "batch", "tensor", None, None)
        return EncDecDecodeState(
            self_kv=attn_mod.KVCache(k=spec, v=spec),
            cross_kv=(spec, spec),
            pos=(),
        )

    # ---------------------------------------------------------------- decode
    def init_decode_state(self, batch: int, context: int, dtype=None) -> EncDecDecodeState:
        cfg = self.cfg
        dtype = dtype or self.compute_dtype
        n = cfg.n_layers
        ctx = min(context, cfg.decoder_max_seq)
        kv = attn_mod.KVCache(
            k=jnp.zeros((n, batch, ctx, cfg.n_kv_heads, cfg.head_dim), dtype),
            v=jnp.zeros((n, batch, ctx, cfg.n_kv_heads, cfg.head_dim), dtype),
        )
        cross = (
            jnp.zeros((n, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
            jnp.zeros((n, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        )
        return EncDecDecodeState(self_kv=kv, cross_kv=cross, pos=jnp.zeros((), jnp.int32))

    def precompute_cross_kv(self, params, enc_out: jax.Array):
        """Per-layer cross K/V from the encoder output (prefill-side)."""
        def per_layer(lp):
            return self._cross_kv(lp, enc_out)
        ks, vs = jax.lax.map(lambda lp: per_layer(lp), params["decoder"])
        return ks.astype(self.compute_dtype), vs.astype(self.compute_dtype)

    def decode_step(self, params, state: EncDecDecodeState, tokens: jax.Array):
        cfg, ax = self.cfg, self.ax
        x = common.embed_tokens(params, tokens, self.compute_dtype)
        x = x + params["pos_embed"][state.pos][None, None].astype(x.dtype)
        x = ax(x, "batch", None, None)
        pos = state.pos

        def body(carry, scanned):
            lp, cache, ck, cv = scanned
            h = common.apply_norm(cfg, lp["norm1"], carry)
            y, new_kv = attn_mod.decode_attention(cfg, lp["self_attn"], h, cache, pos, ax)
            x = carry + y
            h = common.apply_norm(cfg, lp["norm_x"], x)
            y, _ = attn_mod.decode_attention(
                cfg, lp["cross_attn"], h, cache, pos, ax, cross_kv=(ck, cv)
            )
            x = x + y
            h = common.apply_norm(cfg, lp["norm2"], x)
            x = x + mlp_mod.mlp_block(cfg, lp["mlp"], h, ax)
            return x, new_kv

        x, new_kv = jax.lax.scan(
            body, x, (params["decoder"], state.self_kv, state.cross_kv[0], state.cross_kv[1]),
            unroll=self.scan_unroll,
        )
        x = common.apply_norm(cfg, params["final_norm"], x)
        logits = common.unembed(cfg, params, x)[:, 0]
        return _mask_pad_vocab(cfg, logits), EncDecDecodeState(
            self_kv=new_kv, cross_kv=state.cross_kv, pos=pos + 1
        )
