"""Feed-forward blocks: SwiGLU (llama-family) and GELU (whisper)."""

from __future__ import annotations

from typing import Dict

import jax

from repro.configs.base import ArchConfig
from repro.models.common import Ax, ParamDef


def mlp_defs(cfg: ArchConfig, d_ff: int) -> Dict[str, ParamDef]:
    d = cfg.d_model
    if cfg.mlp_variant == "swiglu":
        return {
            "w_gate": ParamDef((d, d_ff), ("fsdp", "tensor")),
            "w_up": ParamDef((d, d_ff), ("fsdp", "tensor")),
            "w_down": ParamDef((d_ff, d), ("tensor", "fsdp")),
        }
    return {
        "w_in": ParamDef((d, d_ff), ("fsdp", "tensor")),
        "b_in": ParamDef((d_ff,), (None,), init="zeros"),
        "w_out": ParamDef((d_ff, d), ("tensor", "fsdp")),
        "b_out": ParamDef((cfg.d_model,), (None,), init="zeros"),
    }


def mlp_block(cfg: ArchConfig, p: Dict[str, jax.Array], x: jax.Array, ax: Ax) -> jax.Array:
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
        h = ax(h, "batch", None, "tensor")
        return h @ p["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w_in"].astype(x.dtype) + p["b_in"].astype(x.dtype))
    h = ax(h, "batch", None, "tensor")
    return h @ p["w_out"].astype(x.dtype) + p["b_out"].astype(x.dtype)
