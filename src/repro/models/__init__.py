"""models — the assigned architectures as composable JAX modules.

- ``common.py``      — ParamDef tree system (single source for init +
                       sharding specs + eval_shape), norms, rotary, embeddings
- ``attention.py``   — GQA attention (qk-norm, biases, sliding window,
                       KV caches incl. ring buffer), flash-kernel backed
- ``mlp.py``         — SwiGLU / GELU MLPs
- ``moe.py``         — grouped sort-based top-k routing, expert-parallel
                       dispatch (the all-to-all = the engine's relayout)
- ``ssm.py``         — Mamba2 SSD block (conv + gated SSD scan)
- ``transformer.py`` — uniform decoder LM (dense / MoE / SSM / VLM)
- ``hybrid.py``      — Jamba-style periodic mamba/attention interleave
- ``encdec.py``      — Whisper-style encoder-decoder (stub audio frontend)
- ``registry.py``    — ``build_model(cfg, mesh, rules)``
"""

from repro.models.registry import build_model

__all__ = ["build_model"]
