"""Shared model infrastructure: the ParamDef tree, norms, rotary, embeddings.

One definition tree per model is the single source of truth for
(a) initialization, (b) PartitionSpecs (via logical-axis names resolved
through :class:`repro.core.sharding.ShardingRules`), and (c)
ShapeDtypeStructs for the allocation-free dry-run.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sharding import ShardingRules, divisible_spec

# ---------------------------------------------------------------------------
# ParamDef trees
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declaration of one parameter tensor."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]  # logical axis per dim (None = replicated)
    init: str = "normal"                # normal | zeros | ones
    scale: Optional[float] = None       # stddev; default 1/sqrt(fan_in)

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(f"shape {self.shape} vs logical {self.logical} rank mismatch")

    def fan_in(self) -> int:
        # convention: last-but-one dim is fan-in for matrices; last for vectors
        if len(self.shape) >= 2:
            return self.shape[-2]
        return self.shape[-1]


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn, defs):
    return jax.tree_util.tree_map(fn, defs, is_leaf=is_def)


def init_params(defs, key: jax.Array, dtype=jnp.float32):
    """Materialize a ParamDef tree into arrays, one fold of the key per leaf."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))

    def make(d: ParamDef, k) -> jax.Array:
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        scale = d.scale if d.scale is not None else 1.0 / math.sqrt(max(d.fan_in(), 1))
        return (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [make(d, k) for d, k in zip(leaves, keys)]
    )


def partition_specs(defs, rules: ShardingRules, mesh: Mesh):
    """ParamDef tree -> PartitionSpec tree (divisibility-safe)."""

    def spec(d: ParamDef) -> P:
        raw = rules.resolve(d.logical)
        return divisible_spec(d.shape, raw, mesh)

    return tree_map_defs(spec, defs)


def shape_structs(defs, dtype=jnp.float32):
    """ParamDef tree -> ShapeDtypeStruct tree (for eval_shape / dry-run)."""
    return tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs)


def shard_params(params, defs, rules: ShardingRules, mesh: Mesh):
    specs = partition_specs(defs, rules, mesh)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs
    )


def param_bytes(defs, dtype=jnp.float32) -> int:
    itm = jnp.dtype(dtype).itemsize
    total = 0
    for d in jax.tree_util.tree_leaves(defs, is_leaf=is_def):
        total += int(np.prod(d.shape)) * itm
    return total


# ---------------------------------------------------------------------------
# Activation sharding helper
# ---------------------------------------------------------------------------

class Ax:
    """Activation-annotation helper bound to (rules, mesh)."""

    def __init__(self, rules: ShardingRules, mesh: Mesh):
        self.rules = rules
        self.mesh = mesh

    def __call__(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        raw = self.rules.resolve(tuple(logical))
        safe = divisible_spec(tuple(x.shape), raw, self.mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, safe))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_defs(cfg, d: int) -> Dict[str, ParamDef]:
    if cfg.norm_variant == "layernorm":
        return {
            "scale": ParamDef((d,), (None,), init="ones"),
            "bias": ParamDef((d,), (None,), init="zeros"),
        }
    return {"scale": ParamDef((d,), (None,), init="ones")}


def apply_norm(cfg, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    if cfg.norm_variant == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.rms_eps)
    return rms_norm(x, p["scale"], cfg.rms_eps)


# ---------------------------------------------------------------------------
# Rotary and positional embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, L, H, D]; positions: [B, L] absolute token positions."""
    freqs = rope_frequencies(x.shape[-1], theta)          # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, L, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(length: int, dim: int) -> jax.Array:
    """Whisper-style sinusoid table [length, dim]."""
    log_timescale = math.log(10_000.0) / (dim // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    scaled = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_defs(cfg) -> Dict[str, ParamDef]:
    v, d = cfg.padded_vocab, cfg.d_model
    # 0.02 stddev (GPT-2 convention); with tied embeddings this also keeps
    # the unembedding logits O(1) at init.
    out = {"embed": ParamDef((v, d), ("tensor", "fsdp"), scale=0.02)}
    if not cfg.tie_embeddings:
        out["unembed"] = ParamDef((d, v), ("fsdp", "tensor"))
    return out


def embed_tokens(p: Dict[str, jax.Array], tokens: jax.Array, compute_dtype) -> jax.Array:
    return p["embed"].astype(compute_dtype)[tokens]


def unembed(cfg, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """x: [..., D] -> logits [..., V] (padded vocab; slice at loss time)."""
    if cfg.tie_embeddings:
        w = p["embed"].astype(x.dtype).T
    else:
        w = p["unembed"].astype(x.dtype)
    return x @ w
