"""HybridLM — Jamba-style periodic Mamba/attention interleave with MoE.

Jamba (arXiv:2403.19887): blocks of ``attn_period`` layers with exactly one
attention layer per block (in-block index ``attn_offset``) and the rest
Mamba; the FFN alternates dense MLP / MoE every ``moe.every_k_layers``.

Layers inside one period are heterogeneous, so the scan unit is the
*period*: parameters are stacked per-role ([n_periods, ...] for the attn
layer, [n_periods, P-1, ...] for the mamba layers, etc.) and ``lax.scan``
runs over periods with a static Python loop over the 8 in-period layers.
HLO size grows with the period (8 layers), not the depth (32).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.core.sharding import ShardingRules
from repro.models import attention as attn_mod
from repro.models import common, mlp as mlp_mod, moe as moe_mod, ssm as ssm_mod
from repro.models.common import Ax
from repro.models.transformer import (
    _mask_pad_vocab,
    _masked_xent,
    stack_defs,
)


class HybridDecodeState(NamedTuple):
    kv: attn_mod.KVCache        # [n_periods, B, S, Hkv, hd]
    ssm: ssm_mod.SSMCache       # [n_periods, P-1, B, ...]
    pos: jax.Array


def _tree_index(tree, i: int):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


class HybridLM:
    def __init__(
        self,
        cfg: ArchConfig,
        mesh: Mesh,
        rules: Optional[ShardingRules] = None,
        *,
        remat: str = "none",
        scan_unroll: int = 1,
    ):
        assert cfg.attn_period > 0 and cfg.n_layers % cfg.attn_period == 0
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules or ShardingRules.default(mesh)
        self.ax = Ax(self.rules, mesh)
        self.remat = remat
        self.scan_unroll = scan_unroll
        self.period = cfg.attn_period
        self.n_periods = cfg.n_layers // cfg.attn_period
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.num_groups = (
            int(np.prod([sizes[a] for a in self.rules.batch], dtype=np.int64))
            if self.rules.batch
            else 1
        )
        self.compute_dtype = jnp.dtype(cfg.compute_dtype)

    # ------------------------------------------------------------------ defs
    def _ffn_is_moe(self, layer_in_period: int) -> bool:
        k = self.cfg.moe.every_k_layers if self.cfg.moe else 0
        return bool(k) and (layer_in_period % k == k - 1)

    def period_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        p = self.period
        n_mamba = p - 1
        n_moe = sum(1 for i in range(p) if self._ffn_is_moe(i))
        n_mlp = p - n_moe
        defs: Dict[str, Any] = {
            "mamba": stack_defs(
                {"norm": common.norm_defs(cfg, cfg.d_model), "ssm": ssm_mod.ssm_defs(cfg)},
                n_mamba,
            ),
            "attn": {"norm": common.norm_defs(cfg, cfg.d_model), "attn": attn_mod.attn_defs(cfg)},
            "ffn_norm": stack_defs(common.norm_defs(cfg, cfg.d_model), p),
        }
        if n_mlp:
            defs["mlp"] = stack_defs(mlp_mod.mlp_defs(cfg, cfg.d_ff), n_mlp)
        if n_moe:
            defs["moe"] = stack_defs(moe_mod.moe_defs(cfg), n_moe)
        return defs

    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            **common.embedding_defs(cfg),
            "periods": stack_defs(self.period_defs(), self.n_periods),
            "final_norm": common.norm_defs(cfg, cfg.d_model),
        }

    def init(self, key: jax.Array):
        return common.init_params(self.param_defs(), key, jnp.dtype(self.cfg.param_dtype))

    def param_partition_specs(self):
        return common.partition_specs(self.param_defs(), self.rules, self.mesh)

    def param_shapes(self):
        return common.shape_structs(self.param_defs(), jnp.dtype(self.cfg.param_dtype))

    # ---------------------------------------------------------------- period
    def _period_train(self, x: jax.Array, pp: Dict[str, Any], positions: jax.Array):
        cfg, ax = self.cfg, self.ax
        aux_sum = jnp.zeros((), jnp.float32)
        mamba_i = mlp_i = moe_i = 0
        for i in range(self.period):
            # mixer
            if i == cfg.attn_offset:
                lp = pp["attn"]
                h = common.apply_norm(cfg, lp["norm"], x)
                x = x + attn_mod.attention_block(
                    cfg, lp["attn"], h, ax, positions=positions, causal=True,
                )
            else:
                lp = _tree_index(pp["mamba"], mamba_i)
                mamba_i += 1
                h = common.apply_norm(cfg, lp["norm"], x)
                x = x + ssm_mod.ssm_block(cfg, lp["ssm"], h, ax)
            # ffn
            nrm = _tree_index(pp["ffn_norm"], i)
            h = common.apply_norm(cfg, nrm, x)
            if self._ffn_is_moe(i):
                mp = _tree_index(pp["moe"], moe_i)
                moe_i += 1
                y, aux = moe_mod.moe_block(cfg, mp, h, ax, num_groups=self.num_groups)
                aux_sum = aux_sum + aux["moe_aux"]
            else:
                wp = _tree_index(pp["mlp"], mlp_i)
                mlp_i += 1
                y = mlp_mod.mlp_block(cfg, wp, h, ax)
            x = x + y
        return x, aux_sum

    # --------------------------------------------------------------- forward
    def forward(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        logits, _ = self._forward_full(params, batch)
        return logits

    def _forward_full(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = common.embed_tokens(params, tokens, self.compute_dtype)
        x = self.ax(x, "batch", None, None)
        b, seq, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(seq)[None], (b, seq))

        fn = functools.partial(self._period_train, positions=positions)
        if self.remat in ("full", "dots"):
            fn = jax.checkpoint(fn)

        x, auxs = jax.lax.scan(
            lambda c, pp: fn(c, pp), x, params["periods"], unroll=self.scan_unroll
        )
        x = common.apply_norm(cfg, params["final_norm"], x)
        return common.unembed(cfg, params, x), auxs

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        logits, auxs = self._forward_full(params, batch)
        tokens = batch["tokens"]
        xent, acc = _masked_xent(cfg, logits[:, :-1], tokens[:, 1:], batch.get("loss_mask"))
        aux = jnp.mean(auxs) / max(sum(1 for i in range(self.period) if self._ffn_is_moe(i)), 1)
        total = xent + cfg.moe.router_aux_weight * aux
        return total, {"loss": total, "xent": xent, "accuracy": acc, "moe_aux": aux}

    # ------------------------------------------------------ decode sharding
    def decode_state_logical(self) -> "HybridDecodeState":
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        tensor = 1
        for a in self.rules.tensor:
            tensor *= sizes.get(a, 1)
        if tensor > 1 and self.cfg.n_kv_heads % tensor == 0:
            kv_spec = (None, "batch", None, "tensor", None)
        else:
            kv_spec = (None, "batch", "tensor", None, None)
        return HybridDecodeState(
            kv=attn_mod.KVCache(k=kv_spec, v=kv_spec),
            ssm=ssm_mod.SSMCache(
                conv=(None, None, "batch", None, "tensor"),
                state=(None, None, "batch", "tensor", None, None),
            ),
            pos=(),
        )

    # ---------------------------------------------------------------- decode
    def init_decode_state(self, batch: int, context: int, dtype=None) -> HybridDecodeState:
        cfg = self.cfg
        dtype = dtype or self.compute_dtype
        kv_one = attn_mod.init_cache(cfg, batch, context, dtype)
        ssm_one = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        np_, nm = self.n_periods, self.period - 1
        return HybridDecodeState(
            kv=attn_mod.KVCache(
                k=jnp.zeros((np_,) + kv_one.k.shape, dtype),
                v=jnp.zeros((np_,) + kv_one.v.shape, dtype),
            ),
            ssm=ssm_mod.SSMCache(
                conv=jnp.zeros((np_, nm) + ssm_one.conv.shape, dtype),
                state=jnp.zeros((np_, nm) + ssm_one.state.shape, dtype),
            ),
            pos=jnp.zeros((), jnp.int32),
        )

    def decode_step(self, params, state: HybridDecodeState, tokens: jax.Array):
        cfg, ax = self.cfg, self.ax
        x = common.embed_tokens(params, tokens, self.compute_dtype)
        x = ax(x, "batch", None, None)
        pos = state.pos

        def period_body(carry, scanned):
            pp, kv_cache, ssm_cache = scanned
            x = carry
            mamba_i = mlp_i = moe_i = 0
            new_kv = kv_cache
            new_conv, new_state = [], []
            for i in range(self.period):
                if i == cfg.attn_offset:
                    lp = pp["attn"]
                    h = common.apply_norm(cfg, lp["norm"], x)
                    y, new_kv = attn_mod.decode_attention(
                        cfg, lp["attn"], h, kv_cache, pos, ax
                    )
                    x = x + y
                else:
                    lp = _tree_index(pp["mamba"], mamba_i)
                    cache_i = ssm_mod.SSMCache(
                        conv=ssm_cache.conv[mamba_i], state=ssm_cache.state[mamba_i]
                    )
                    h = common.apply_norm(cfg, lp["norm"], x)
                    y, upd = ssm_mod.ssm_decode_step(cfg, lp["ssm"], h, cache_i, ax)
                    new_conv.append(upd.conv)
                    new_state.append(upd.state)
                    mamba_i += 1
                    x = x + y
                nrm = _tree_index(pp["ffn_norm"], i)
                h = common.apply_norm(cfg, nrm, x)
                if self._ffn_is_moe(i):
                    mp = _tree_index(pp["moe"], moe_i)
                    moe_i += 1
                    y, _ = moe_mod.moe_block(cfg, mp, h, ax, num_groups=self.num_groups)
                else:
                    wp = _tree_index(pp["mlp"], mlp_i)
                    mlp_i += 1
                    y = mlp_mod.mlp_block(cfg, wp, h, ax)
                x = x + y
            new_ssm = ssm_mod.SSMCache(
                conv=jnp.stack(new_conv), state=jnp.stack(new_state)
            )
            return x, (new_kv, new_ssm)

        x, (new_kv, new_ssm) = jax.lax.scan(
            period_body, x, (params["periods"], state.kv, state.ssm),
            unroll=self.scan_unroll,
        )
        x = common.apply_norm(cfg, params["final_norm"], x)
        logits = common.unembed(cfg, params, x)[:, 0]
        return _mask_pad_vocab(cfg, logits), HybridDecodeState(
            kv=new_kv, ssm=new_ssm, pos=pos + 1
        )
