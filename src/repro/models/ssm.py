"""Mamba2 block (SSD) — conv + gated selective-state-space mixer.

Follows arXiv:2405.21060: fused input projection producing
(z, x, B, C, dt), a causal depthwise conv over (x, B, C), softplus dt with a
learned bias, per-head scalar decay A, the SSD scan (Pallas kernel via
``ops.ssd_scan``), a D skip connection, gated RMSNorm, and output projection.

Decode carries two caches per layer: the conv tail [B, W-1, conv_channels]
and the SSD state [B, H, P, N].
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import common
from repro.models.common import Ax, ParamDef


class SSMCache(NamedTuple):
    conv: jax.Array   # [B, W-1, conv_channels]
    state: jax.Array  # [B, H, P, N]


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_ch = di + 2 * s.n_groups * s.d_state
    return s, d, di, nh, conv_ch


def ssm_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    s, d, di, nh, conv_ch = _dims(cfg)
    gs = s.n_groups * s.d_state
    return {
        # fused in_proj -> [z | x | B | C | dt]
        "w_in": ParamDef((d, 2 * di + 2 * gs + nh), ("fsdp", "tensor")),
        "conv_w": ParamDef((s.conv_width, conv_ch), (None, "tensor"), scale=0.5),
        "conv_b": ParamDef((conv_ch,), ("tensor",), init="zeros"),
        "dt_bias": ParamDef((nh,), (None,), init="zeros"),
        "a_log": ParamDef((nh,), (None,), init="zeros", scale=1.0),
        "d_skip": ParamDef((nh,), (None,), init="ones"),
        "norm_scale": ParamDef((di,), ("tensor",), init="ones"),
        "w_out": ParamDef((di, d), ("tensor", "fsdp")),
    }


def _split_proj(cfg: ArchConfig, proj: jax.Array):
    s, d, di, nh, _ = _dims(cfg)
    gs = s.n_groups * s.d_state
    z, xs, b_mat, c_mat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + gs, 2 * di + 2 * gs], axis=-1
    )
    return z, xs, b_mat, c_mat, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x [B, L, C], w [W, C]."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out + b[None, None, :])


def ssm_block(
    cfg: ArchConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,          # [B, L, D]
    ax: Ax,
    *,
    return_cache: bool = False,
):
    """Full-sequence SSD (training / prefill). With ``return_cache`` also
    returns the SSMCache (final state + conv tail) for decode handoff."""
    s, d, di, nh, conv_ch = _dims(cfg)
    bsz, seq, _ = x.shape
    proj = x @ p["w_in"].astype(x.dtype)
    z, xs, b_mat, c_mat, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xs, b_mat, c_mat], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    xs, b_mat, c_mat = jnp.split(conv_out, [di, di + s.n_groups * s.d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    xh = xs.reshape(bsz, seq, nh, s.head_dim)
    xh = ax(xh, "batch", None, "tensor", None)
    bh = b_mat.reshape(bsz, seq, s.n_groups, s.d_state)
    ch = c_mat.reshape(bsz, seq, s.n_groups, s.d_state)

    y, final_state = ops.ssd_scan(
        xh.astype(jnp.float32), dt, a,
        bh.astype(jnp.float32), ch.astype(jnp.float32),
        chunk=s.chunk,
    )
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, seq, di).astype(x.dtype)

    y = common.rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.rms_eps)
    out = y @ p["w_out"].astype(x.dtype)
    if return_cache:
        conv_tail = conv_in[:, -(s.conv_width - 1):, :]
        cache = SSMCache(conv=conv_tail.astype(x.dtype), state=final_state.astype(x.dtype))
        return out, cache
    return out


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype) -> SSMCache:
    s, d, di, nh, conv_ch = _dims(cfg)
    return SSMCache(
        conv=jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        state=jnp.zeros((batch, nh, s.head_dim, s.d_state), dtype),
    )


def ssm_decode_step(
    cfg: ArchConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,          # [B, 1, D]
    cache: SSMCache,
    ax: Ax,
) -> Tuple[jax.Array, SSMCache]:
    """Single-token SSD recurrence (O(1) in context length)."""
    s, d, di, nh, conv_ch = _dims(cfg)
    bsz = x.shape[0]
    proj = x @ p["w_in"].astype(x.dtype)
    z, xs, b_mat, c_mat, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xs, b_mat, c_mat], axis=-1)      # [B, 1, C]
    window = jnp.concatenate([cache.conv.astype(x.dtype), conv_in], axis=1)  # [B, W, C]
    w = p["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", window, w) + p["conv_b"].astype(x.dtype)
    conv_out = jax.nn.silu(conv_out)[:, None, :]
    new_conv = window[:, 1:, :]

    xs, b_mat, c_mat = jnp.split(conv_out, [di, di + s.n_groups * s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    xh = xs.reshape(bsz, 1, nh, s.head_dim)
    bh = b_mat.reshape(bsz, 1, s.n_groups, s.d_state)
    ch = c_mat.reshape(bsz, 1, s.n_groups, s.d_state)

    y, new_state = ops.ssd_step(
        xh.astype(jnp.float32), dt, a,
        bh.astype(jnp.float32), ch.astype(jnp.float32),
        cache.state.astype(jnp.float32),
    )
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = common.rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.rms_eps)
    return y @ p["w_out"].astype(x.dtype), SSMCache(
        conv=new_conv.astype(cache.conv.dtype), state=new_state.astype(cache.state.dtype)
    )
