"""GQA attention: projections, rope, qk-norm, KV caches (full + ring buffer).

Train/prefill attention goes through :func:`repro.kernels.ops.attention`
(flash kernel on TPU). Decode (one query against a long cache) is computed
directly — it is bandwidth-bound; a kernel buys nothing and the ring-buffer
position bookkeeping needs explicit key positions.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import common
from repro.models.common import Ax, ParamDef


class KVCache(NamedTuple):
    """Self-attention cache. ``k``/``v``: [B, S, Hkv, hd]; S = full context
    (dense) or the sliding window (ring buffer)."""

    k: jax.Array
    v: jax.Array

    @property
    def size(self) -> int:
        return self.k.shape[1]


def attn_defs(cfg: ArchConfig, *, cross: bool = False) -> Dict[str, ParamDef]:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    defs: Dict[str, ParamDef] = {
        "wq": ParamDef((d, hq * hd), ("fsdp", "tensor")),
        "wk": ParamDef((d, hkv * hd), ("fsdp", "tensor")),
        "wv": ParamDef((d, hkv * hd), ("fsdp", "tensor")),
        "wo": ParamDef((hq * hd, d), ("tensor", "fsdp")),
    }
    if cfg.qkv_bias and not cross:
        defs["bq"] = ParamDef((hq * hd,), (None,), init="zeros")
        defs["bk"] = ParamDef((hkv * hd,), (None,), init="zeros")
        defs["bv"] = ParamDef((hkv * hd,), (None,), init="zeros")
    if cfg.qk_norm and not cross:
        defs["q_norm"] = ParamDef((hd,), (None,), init="ones")
        defs["k_norm"] = ParamDef((hd,), (None,), init="ones")
    return defs


def _project_q(cfg, p, x, ax: Ax) -> jax.Array:
    b, seq, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(b, seq, cfg.n_heads, cfg.head_dim)
    if "q_norm" in p:
        q = common.rms_norm(q, p["q_norm"], cfg.rms_eps)
    return ax(q, "batch", None, "tensor", None)


def _project_kv(cfg, p, x, ax: Ax) -> Tuple[jax.Array, jax.Array]:
    b, seq, _ = x.shape
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bk" in p:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    k = k.reshape(b, seq, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, seq, cfg.n_kv_heads, cfg.head_dim)
    if "k_norm" in p:
        k = common.rms_norm(k, p["k_norm"], cfg.rms_eps)
    return ax(k, "batch", None, "tensor", None), ax(v, "batch", None, "tensor", None)


def attention_block(
    cfg: ArchConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,                 # [B, L, D]
    ax: Ax,
    *,
    positions: Optional[jax.Array] = None,   # [B, L]
    causal: bool = True,
    window: Optional[int] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill).

    With ``return_kv`` the post-rope K/V are also returned ([B, L, Hkv, hd])
    so prefill can populate decode caches.
    """
    b, seq, d = x.shape
    q = _project_q(cfg, p, x, ax)
    if cross_kv is not None:
        k, v = cross_kv
        causal = False
        window = None
    else:
        k, v = _project_kv(cfg, p, x, ax)
        if cfg.pos_emb == "rope":
            pos = positions if positions is not None else jnp.broadcast_to(
                jnp.arange(seq)[None, :], (b, seq)
            )
            q = common.apply_rope(q, pos, cfg.rope_theta)
            k = common.apply_rope(k, pos, cfg.rope_theta)

    # ops.attention wants [B, H, L, D]
    out = ops.attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
    ).transpose(0, 2, 1, 3)
    out = ax(out, "batch", None, "tensor", None)
    out = out.reshape(b, seq, cfg.n_heads * cfg.head_dim)
    y = out @ p["wo"].astype(x.dtype)
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# Decode path (single token, cached)
# ---------------------------------------------------------------------------

def init_cache(
    cfg: ArchConfig, batch: int, context: int, dtype, *, window: Optional[int] = None
) -> KVCache:
    s = min(window, context) if window else context
    shape = (batch, s, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def decode_attention(
    cfg: ArchConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,                  # [B, 1, D]
    cache: KVCache,
    pos: jax.Array,                # [] scalar: absolute position of this token
    ax: Ax,
    *,
    window: Optional[int] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, KVCache]:
    """One-token attention against the cache (ring buffer when windowed).

    Ring semantics: slot = pos % S. Key positions are reconstructed from the
    slot index so masking is exact both before the buffer wraps and after.
    """
    b, _, d = x.shape
    q = _project_q(cfg, p, x, ax)                      # [B, 1, Hq, hd]

    if cross_kv is not None:
        k_all, v_all = cross_kv                        # [B, S, Hkv, hd]
        mask = None
        new_cache = cache
    else:
        k_new, v_new = _project_kv(cfg, p, x, ax)      # [B, 1, Hkv, hd]
        if cfg.pos_emb == "rope":
            pos_b = jnp.broadcast_to(pos[None, None], (b, 1))
            q = common.apply_rope(q, pos_b, cfg.rope_theta)
            k_new = common.apply_rope(k_new, pos_b, cfg.rope_theta)
        s = cache.size
        slot = (pos % s).astype(jnp.int32)
        k_all = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), slot, axis=1
        )
        v_all = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), slot, axis=1
        )
        new_cache = KVCache(k=k_all, v=v_all)
        # absolute position held in each slot right now
        idx = jnp.arange(s)
        wrapped = pos - ((slot - idx) % s)             # [S]
        valid = (wrapped >= 0) & (wrapped <= pos)
        if window is not None:
            valid &= wrapped > pos - window
        mask = valid                                   # [S]

    # scores: [B, Hq, 1, S] with GQA grouping
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    group = hq // hkv
    qf = q[:, 0].astype(jnp.float32).reshape(b, hkv, group, cfg.head_dim)
    kf = k_all.astype(jnp.float32)                     # [B, S, Hkv, hd]
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf) / jnp.sqrt(float(cfg.head_dim))
    if mask is not None:
        scores = jnp.where(mask[None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v_all.astype(jnp.float32))
    out = out.reshape(b, 1, hq * cfg.head_dim).astype(x.dtype)
    return out @ p["wo"].astype(x.dtype), new_cache
