"""Engine health classification for the fleet supervisor (DESIGN.md §14).

The deployment study behind Alchemist (arXiv:1910.01354) is blunt about the
operational half of the system: server processes must be launched, watched,
and survive client churn. This module is the *watching* part — a small,
deterministic state machine per engine, fed exclusively by heartbeat scrapes
of ``engine.stats()``:

- **healthy** — scrapes arrive, the snapshot sequence advances, pressure is
  under the degraded watermark;
- **degraded** — alive, but the memory governor's pressure fraction sits at
  or above :attr:`HealthPolicy.degraded_pressure`. Degraded engines keep
  their sessions (nothing is broken) but stop receiving new fleet
  admissions and count toward the autoscaler's grow signal;
- **dead** — :attr:`HealthPolicy.miss_threshold` *consecutive* scrapes
  failed or came back stale/reordered. Dead is terminal for the slot's
  sessions: the supervisor drains and recovers them (recovery.py); an
  engine that later answers again re-enters only through an explicit
  :meth:`EngineHealth.revive` (flapping engines must not silently re-adopt
  sessions that were already replayed elsewhere).

Staleness is decided from the two fields PR 10 added to
``engine.stats()["engine"]``: ``snapshot_seq`` must strictly advance and
``uptime_s`` must not run backwards (a restarted process answering with a
fresh counter would otherwise masquerade as the engine we were monitoring).
A stale scrape is *counted as a miss* — a monitoring channel replaying old
snapshots is indistinguishable from a wedged engine.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

HEALTHY = "healthy"
DEGRADED = "degraded"
DEAD = "dead"

#: transition log depth kept per engine (oldest dropped first)
_MAX_TRANSITIONS = 16


@dataclass(frozen=True)
class HealthPolicy:
    """Liveness + pressure thresholds for :class:`EngineHealth`.

    ``miss_threshold`` consecutive failed/stale scrapes classify an engine
    dead; a memory-governor pressure fraction at or above
    ``degraded_pressure`` (used+reserved over budget) classifies it
    degraded. Budgetless engines (``budget=None``) never degrade on
    pressure — there is no ceiling to press against.
    """

    miss_threshold: int = 3
    degraded_pressure: float = 0.85

    def __post_init__(self) -> None:
        if self.miss_threshold < 1:
            raise ValueError("miss_threshold must be >= 1")
        if not (0.0 < self.degraded_pressure <= 1.0):
            raise ValueError("degraded_pressure must be in (0, 1]")


class EngineHealth:
    """One engine's health record, driven by heartbeat observations."""

    def __init__(self, policy: Optional[HealthPolicy] = None):
        self.policy = policy or HealthPolicy()
        self.state = HEALTHY
        self.consecutive_misses = 0
        self.last_seq = 0
        self.last_uptime = -1.0
        self.heartbeats = 0  # accepted (fresh) scrapes
        self.misses = 0  # failed scrapes, cumulative
        self.stale = 0  # scrapes rejected as stale/reordered
        self.pressure = 0.0  # last observed pressure fraction
        self.last_snapshot: Optional[Dict[str, Any]] = None
        self.transitions: List[Tuple[str, str, str]] = []

    # -- observations --------------------------------------------------------
    def observe(self, snapshot: Dict[str, Any]) -> str:
        """Fold one scraped ``engine.stats()`` snapshot into the state.

        Returns the (possibly updated) state. Snapshots whose sequence
        number does not advance, or whose uptime runs backwards, are
        rejected as stale and counted as a miss — they are not evidence of
        life *now*.
        """
        eng = snapshot.get("engine", {})
        seq = int(eng.get("snapshot_seq", 0))
        uptime = float(eng.get("uptime_s", 0.0))
        if seq <= self.last_seq or uptime < self.last_uptime:
            self.stale += 1
            return self.miss(f"stale scrape (seq {seq} <= {self.last_seq})")
        self.last_seq = seq
        self.last_uptime = uptime
        self.last_snapshot = snapshot
        self.heartbeats += 1
        self.consecutive_misses = 0
        mg = snapshot.get("memgov", {})
        budget = mg.get("budget")
        self.pressure = (
            float(mg.get("pressure", 0)) / float(budget) if budget else 0.0
        )
        if self.state != DEAD:
            if self.pressure >= self.policy.degraded_pressure:
                self._move(DEGRADED, f"pressure {self.pressure:.2f}")
            else:
                self._move(HEALTHY, "scrape ok")
        return self.state

    def miss(self, why: str = "scrape failed") -> str:
        """One failed (or stale) scrape; crosses into DEAD at the policy's
        consecutive-miss threshold."""
        self.misses += 1
        self.consecutive_misses += 1
        if self.consecutive_misses >= self.policy.miss_threshold:
            self._move(DEAD, why)
        return self.state

    def force_dead(self, why: str = "killed") -> str:
        """Administrative death (chaos kill, operator action): skip the miss
        accounting and go straight to DEAD."""
        self._move(DEAD, why)
        return self.state

    def revive(self, why: str = "revived") -> str:
        """Explicit re-admission of a previously dead engine as *fresh*
        capacity. Counters reset: its old sessions were recovered elsewhere
        and must not be re-adopted."""
        self.consecutive_misses = 0
        self.last_seq = 0
        self.last_uptime = -1.0
        self._move(HEALTHY, why)
        return self.state

    # -- internals -----------------------------------------------------------
    def _move(self, new: str, why: str) -> None:
        if new == self.state:
            return
        self.transitions.append((self.state, new, why))
        del self.transitions[:-_MAX_TRANSITIONS]
        self.state = new

    def summary(self) -> Dict[str, Any]:
        """JSON-serializable per-engine health block for fleet stats."""
        return {
            "state": self.state,
            "heartbeats": self.heartbeats,
            "misses": self.misses,
            "stale": self.stale,
            "consecutive_misses": self.consecutive_misses,
            "pressure": self.pressure,
            "last_seq": self.last_seq,
            "uptime_s": self.last_uptime if self.last_uptime >= 0 else None,
            "transitions": [list(t) for t in self.transitions],
        }

    def __repr__(self) -> str:
        return (
            f"EngineHealth(state={self.state}, beats={self.heartbeats}, "
            f"misses={self.misses}, pressure={self.pressure:.2f})"
        )
