"""repro.fleet — supervised engine fleet (DESIGN.md §14).

A :class:`FleetSupervisor` owns N engines behind wire servers, heartbeats
them over the control-plane HEALTH verb, classifies health, drains and
recovers dead engines by lineage replay, and autoscales from a spare device
pool. See supervisor.py / health.py / recovery.py.
"""

from repro.fleet.health import (
    DEAD,
    DEGRADED,
    HEALTHY,
    EngineHealth,
    HealthPolicy,
)
from repro.fleet.recovery import RecoveryPlanner, SessionRecovery, suffix_bytes
from repro.fleet.supervisor import AutoscalePolicy, EngineSlot, FleetSupervisor

__all__ = [
    "AutoscalePolicy",
    "DEAD",
    "DEGRADED",
    "EngineHealth",
    "EngineSlot",
    "FleetSupervisor",
    "HEALTHY",
    "HealthPolicy",
    "RecoveryPlanner",
    "SessionRecovery",
    "suffix_bytes",
]
