"""FleetSupervisor — a heartbeat-monitored pool of AlchemistEngines
(DESIGN.md §14).

Alchemist's deployment story (arXiv:1910.01354) runs long-lived server
processes that many drivers share; this module is the first multi-engine
layer of the reproduction: one supervisor owning N engines (each behind its
own :class:`~repro.serve.wire.EngineServer`), a heartbeat loop scraping each
engine's merged ``engine.stats()`` snapshot over the wire's control-plane
HEALTH verb, health classification via :mod:`repro.fleet.health`, drain +
lineage-replay recovery via :mod:`repro.fleet.recovery`, and an autoscaling
hook driven by admission-queue depth and governor pressure.

Layout: the supervisor partitions its device pool into fixed-size engine
slots; devices left over (or freed by a scale-down) form the **spare pool**
the autoscaler grows new engines from. A dead engine's devices are treated
as lost with it — in a real deployment they died with the host — so only
clean scale-downs return capacity.

Clients enter through :meth:`FleetSupervisor.connect`, which places them on
the least-loaded live engine and registers the binding; on an engine death
the supervisor drains it and fails every bound client over to a survivor
(transplant + re-admit + lazy replay — see recovery.py). The chaos hook
:meth:`kill` is the test/benchmark entry: it stops the engine's server
under its clients exactly like a crashed process would.
"""

from __future__ import annotations

import itertools
import json
import socket
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core import client as client_mod
from repro.core import transport as wire
from repro.core.engine import AlchemistEngine
from repro.fleet.health import DEAD, DEGRADED, HEALTHY, EngineHealth, HealthPolicy
from repro.fleet.recovery import RecoveryPlanner, SessionRecovery
from repro.serve.wire import EngineServer, TcpTransport, ensure_server


@dataclass(frozen=True)
class AutoscalePolicy:
    """Grow/shrink thresholds for the fleet (DESIGN.md §14).

    Grow when fleet-wide queued connects reach ``queue_high`` or the mean
    pressure fraction of live engines reaches ``pressure_high`` (and spare
    devices allow). Shrink an engine that sat completely idle — no
    sessions, no queued admissions — for ``idle_beats`` consecutive
    heartbeats, never below ``min_engines``.
    """

    min_engines: int = 1
    max_engines: int = 8
    queue_high: int = 1
    pressure_high: float = 0.85
    idle_beats: int = 3


class EngineSlot:
    """One supervised engine: the engine, its wire server, its health."""

    def __init__(self, name: str, engine: AlchemistEngine, server: EngineServer,
                 health: EngineHealth):
        self.name = name
        self.engine = engine
        self.server = server
        self.health = health
        self.idle_beats = 0
        self.draining = False

    @property
    def state(self) -> str:
        return self.health.state

    def __repr__(self) -> str:
        return f"EngineSlot({self.name}, state={self.state}, workers={self.engine.num_workers})"


class FleetSupervisor:
    """Own N engines; watch, drain, recover, autoscale."""

    def __init__(
        self,
        devices: Optional[List[Any]] = None,
        *,
        engines: int = 2,
        devices_per_engine: Optional[int] = None,
        name: str = "fleet",
        health_policy: Optional[HealthPolicy] = None,
        autoscale: Optional[AutoscalePolicy] = None,
        heartbeat_interval: float = 0.25,
        scrape_timeout: float = 2.0,
        scrape_over_wire: bool = True,
        engine_kwargs: Optional[Dict[str, Any]] = None,
    ):
        if engines < 1:
            raise ValueError("a fleet needs at least one engine")
        devices = list(devices if devices is not None else jax.devices())
        per = devices_per_engine or max(1, len(devices) // engines)
        if per * engines > len(devices):
            raise ValueError(
                f"cannot cut {engines} engines of {per} devices from "
                f"{len(devices)} devices"
            )
        self.name = name
        self.health_policy = health_policy or HealthPolicy()
        self.autoscale = autoscale or AutoscalePolicy()
        self.heartbeat_interval = float(heartbeat_interval)
        self.scrape_timeout = float(scrape_timeout)
        self.scrape_over_wire = scrape_over_wire
        self._engine_kwargs = dict(engine_kwargs or {})
        self._devices_per_engine = per
        self._ids = itertools.count(1)
        self._lock = threading.RLock()
        self._slots: Dict[str, EngineSlot] = {}
        #: devices not currently assigned to a live engine (autoscale pool)
        self._spare: List[Any] = devices[per * engines:]
        #: (client core, slot name) for every fleet-admitted session
        self._clients: List[Tuple[Any, str]] = []
        self._probes: Dict[str, socket.socket] = {}
        self.recovery = RecoveryPlanner()
        self.recoveries: List[SessionRecovery] = []
        self.heartbeats = 0
        self.scrapes = 0
        self.scrape_failures = 0
        self.kills = 0
        self.scale_ups = 0
        self.scale_downs = 0
        self.retired: List[str] = []
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None
        for i in range(engines):
            self._add_slot(devices[i * per:(i + 1) * per])

    # -- slot lifecycle ------------------------------------------------------
    def _add_slot(self, devs: List[Any]) -> EngineSlot:
        ename = f"{self.name}-e{next(self._ids)}"
        engine = AlchemistEngine(devs, name=ename, **self._engine_kwargs)
        server = ensure_server(engine)
        slot = EngineSlot(ename, engine, server, EngineHealth(self.health_policy))
        with self._lock:
            self._slots[ename] = slot
        return slot

    @property
    def engines(self) -> Dict[str, EngineSlot]:
        with self._lock:
            return dict(self._slots)

    def slot(self, name: str) -> EngineSlot:
        with self._lock:
            return self._slots[name]

    def _live_slots(self) -> List[EngineSlot]:
        with self._lock:
            return [s for s in self._slots.values() if s.state != DEAD]

    # -- client admission ----------------------------------------------------
    def connect(self, *, engine: Optional[str] = None, **kwargs) -> "client_mod.Session":
        """Admit a client session on the fleet.

        Picks the least-loaded live engine (most free workers; degraded
        engines only when no healthy one exists) unless ``engine=`` names a
        slot, builds a v2 :class:`repro.core.client.Session` on it, and
        registers the binding so an engine death fails this client over
        automatically. All other kwargs pass through to ``Session``
        (placement, hbm_budget, policy, transport, ...).
        """
        with self._lock:
            if engine is not None:
                slot = self._slots[engine]
                if slot.state == DEAD:
                    raise RuntimeError(f"engine {engine} is dead")
            else:
                slot = self._pick_slot()
        sess = client_mod.Session(slot.engine, **kwargs)
        with self._lock:
            self._clients.append((sess, slot.name))
        return sess

    def _pick_slot(self) -> EngineSlot:
        # caller holds self._lock
        live = [s for s in self._slots.values() if s.state == HEALTHY and not s.draining]
        if not live:
            live = [s for s in self._slots.values() if s.state == DEGRADED and not s.draining]
        if not live:
            raise RuntimeError(f"fleet {self.name!r} has no live engine to admit on")
        return max(
            live,
            key=lambda s: (s.engine.available_workers, -s.engine.queued_connects),
        )

    def clients_of(self, slot_name: str) -> List[Any]:
        with self._lock:
            return [c for c, n in self._clients if n == slot_name]

    def _prune_clients(self) -> None:
        with self._lock:
            self._clients = [(c, n) for c, n in self._clients if not c._stopped]

    # -- heartbeat loop ------------------------------------------------------
    def start(self) -> None:
        """Run the heartbeat loop on a daemon thread until :meth:`stop`."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop_evt.clear()

        def loop() -> None:
            while not self._stop_evt.wait(self.heartbeat_interval):
                try:
                    self.heartbeat_once()
                except Exception:  # noqa: BLE001 — the watcher must not die
                    pass

        self._thread = threading.Thread(
            target=loop, name=f"{self.name}-heartbeat", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        """Stop the heartbeat loop (engines keep running)."""
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        for sock in self._probes.values():
            try:
                sock.close()
            except OSError:
                pass
        self._probes.clear()

    def shutdown(self) -> None:
        """Stop monitoring and tear the whole fleet down."""
        self.stop()
        with self._lock:
            slots = list(self._slots.values())
        for slot in slots:
            slot.server.stop()
            slot.engine.shutdown()

    def heartbeat_once(self) -> Dict[str, str]:
        """One supervision beat: scrape every non-dead engine, classify,
        recover the newly dead, run the autoscaler. Returns the post-beat
        state per engine (dead slots included, for observability)."""
        self._prune_clients()
        for slot in self._live_slots():
            snap = self._scrape(slot)
            if snap is None:
                state = slot.health.miss()
            else:
                state = slot.health.observe(snap)
            if state == DEAD and not slot.draining:
                self._recover_slot(slot)
        self._autoscale_once()
        self.heartbeats += 1
        with self._lock:
            return {name: s.state for name, s in self._slots.items()}

    # -- scraping ------------------------------------------------------------
    def _scrape(self, slot: EngineSlot) -> Optional[Dict[str, Any]]:
        """One stats scrape: the wire HEALTH verb over a cached per-slot
        monitoring socket (the control-plane path — answered inline by the
        server's connection loop, never queued behind data-plane workers),
        or a direct in-process call when ``scrape_over_wire=False``."""
        self.scrapes += 1
        if not self.scrape_over_wire:
            try:
                return slot.engine.stats()
            except Exception:  # noqa: BLE001 — a failing engine is a miss
                self.scrape_failures += 1
                return None
        sock = self._probes.get(slot.name)
        try:
            if sock is None:
                sock = socket.create_connection(
                    slot.server.address, timeout=self.scrape_timeout
                )
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                self._probes[slot.name] = sock
            wire.send_frame(sock, wire.T_HEALTH, {})
            ftype, reply, _ = wire.recv_frame(sock)
            if ftype != wire.T_OK:
                raise ConnectionError(f"HEALTH scrape got frame 0x{ftype:02x}")
            return json.loads(str(reply["__stats_json"]))
        except (ConnectionError, OSError, TimeoutError, KeyError, ValueError):
            self.scrape_failures += 1
            if sock is not None:
                try:
                    sock.close()
                except OSError:
                    pass
            self._probes.pop(slot.name, None)
            return None

    # -- chaos + recovery ----------------------------------------------------
    def kill(self, name: str) -> List[SessionRecovery]:
        """Chaos hook: abruptly kill engine ``name`` — stop its server under
        its clients (mid-flight RPCs fail, exactly like a crashed process),
        mark it dead, and run recovery synchronously. Returns the recovery
        records. The heartbeat path reaches the same `_recover_slot` after
        ``miss_threshold`` failed scrapes."""
        slot = self.slot(name)
        self.kills += 1
        slot.health.force_dead("killed")
        return self._recover_slot(slot)

    def _recover_slot(self, slot: EngineSlot) -> List[SessionRecovery]:
        """Drain a dead engine and fail its clients over to survivors."""
        with self._lock:
            if slot.draining:
                return []
            slot.draining = True
            affected = [c for c, n in self._clients if n == slot.name]
        probe = self._probes.pop(slot.name, None)
        if probe is not None:
            try:
                probe.close()
            except OSError:
                pass
        self.recovery.drain(slot.engine, server=slot.server)
        recs: List[SessionRecovery] = []
        for core in affected:
            if core._stopped:
                continue
            target = self._recovery_target()
            rec = self.recovery.recover_client(
                core,
                slot.engine,
                target.engine,
                transport=self._transport_like(core, target),
            )
            recs.append(rec)
            with self._lock:
                self._clients = [
                    (c, target.name if c is core else n) for c, n in self._clients
                ]
        with self._lock:
            self.recoveries.extend(recs)
            slot.draining = False
        return recs

    def _recovery_target(self) -> EngineSlot:
        with self._lock:
            try:
                return self._pick_slot()
            except RuntimeError:
                pass
        # No survivor: try growing one from the spare pool.
        grown = self.scale_up()
        if grown is None:
            raise RuntimeError(
                f"fleet {self.name!r}: no surviving engine and no spare "
                "devices to grow one — sessions cannot be recovered"
            )
        return grown

    @staticmethod
    def _transport_like(core, target: EngineSlot):
        """A fresh transport of the client's current flavor, aimed at the
        target slot (a TCP client reconnects to the survivor's server; a
        loopback client stays in-process)."""
        if isinstance(core.transport, TcpTransport):
            return TcpTransport(ensure_server(target.engine))
        if core.transport is not None:
            return type(core.transport)()
        return None

    # -- autoscaling ---------------------------------------------------------
    def _autoscale_once(self) -> None:
        pol = self.autoscale
        live = self._live_slots()
        if not live:
            return
        queued = sum(s.engine.queued_connects for s in live)
        pressures = [s.health.pressure for s in live]
        mean_pressure = sum(pressures) / len(pressures)
        if (
            (queued >= pol.queue_high or mean_pressure >= pol.pressure_high)
            and len(live) < pol.max_engines
            and len(self._spare) >= self._devices_per_engine
        ):
            self.scale_up()
            return
        # Shrink: an engine idle for idle_beats consecutive beats goes back
        # to the spare pool (never below min_engines, never a draining one).
        for slot in live:
            idle = (
                len(slot.engine.sessions) == 0
                and slot.engine.queued_connects == 0
                and not slot.draining
            )
            slot.idle_beats = slot.idle_beats + 1 if idle else 0
        candidates = [s for s in live if s.idle_beats >= pol.idle_beats]
        if candidates and len(live) > pol.min_engines:
            self.scale_down(candidates[0].name)

    def scale_up(self, workers: Optional[int] = None) -> Optional[EngineSlot]:
        """Grow one engine from the spare pool; None when it can't."""
        n = workers or self._devices_per_engine
        with self._lock:
            if len(self._spare) < n:
                return None
            devs = self._spare[:n]
            del self._spare[:n]
        slot = self._add_slot(devs)
        self.scale_ups += 1
        return slot

    def scale_down(self, name: str) -> bool:
        """Retire an *idle* engine cleanly, returning its devices to the
        spare pool. Refuses engines with live sessions or queued connects."""
        with self._lock:
            slot = self._slots.get(name)
            if slot is None or slot.draining:
                return False
            if len(slot.engine.sessions) or slot.engine.queued_connects:
                return False
            del self._slots[name]
        probe = self._probes.pop(name, None)
        if probe is not None:
            try:
                probe.close()
            except OSError:
                pass
        slot.server.stop()
        slot.engine.shutdown()
        with self._lock:
            self._spare.extend(slot.engine.devices)
            self.retired.append(name)
        self.scale_downs += 1
        return True

    # -- observability -------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """The fleet-level stats block (embedded by ``benchmarks/run.py
        --json`` the same way ``engine.stats()`` is): per-engine health,
        drains, replays, autoscale actions, spare capacity."""
        with self._lock:
            slots = dict(self._slots)
            spare = len(self._spare)
            clients = len(self._clients)
        per_engine = {}
        for name, slot in slots.items():
            per_engine[name] = {
                **slot.health.summary(),
                "workers": slot.engine.num_workers,
                "available_workers": slot.engine.available_workers,
                "sessions": len(slot.engine.sessions),
                "queued_connects": slot.engine.queued_connects,
                "idle_beats": slot.idle_beats,
            }
        return {
            "engines": per_engine,
            "spare_devices": spare,
            "clients": clients,
            "heartbeats": self.heartbeats,
            "scrapes": self.scrapes,
            "scrape_failures": self.scrape_failures,
            "kills": self.kills,
            "scale_ups": self.scale_ups,
            "scale_downs": self.scale_downs,
            "retired": list(self.retired),
            **self.recovery.stats(),
        }

    def __enter__(self) -> "FleetSupervisor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def __repr__(self) -> str:
        with self._lock:
            states = {n: s.state for n, s in self._slots.items()}
        return f"FleetSupervisor({self.name!r}, engines={states})"
