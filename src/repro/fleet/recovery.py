"""Session draining and lineage-replay recovery (DESIGN.md §14).

The recovery contract the ROADMAP promised: everything a recovery needs is
already persisted by the layers below —

- the **expr DAG is the lineage**: every ``AlArray`` roots a deferred graph
  whose nodes name exactly how each engine-side value was produced;
- the **resident store holds content-keyed host payloads**: publishes
  snapshot the bytes at send time, and ``Session.close`` migration secures
  uniquely-held content host-side during the drain;
- the **planner's lowering memo is the loss ledger**: the node ids lowered
  at failure time name the DAG prefix whose engine-side outputs died with
  the engine.

Recovery is therefore three mechanical steps per affected client:

1. **transplant** — enumerate the dead engine's recoverable content for the
   session (:meth:`ResidentStore.recoverable_for`) and adopt the payloads
   into the surviving engine's store (:meth:`ResidentStore.adopt`). The
   re-admitted session's re-lowered sends then take the *attach* path:
   residents refill by content key with zero bytes re-crossing the
   client↔engine bridge;
2. **re-admit** — :meth:`ClientCore.rebind`: a queued
   ``connect(placement=...)`` on the survivor using the session's original
   admission kwargs, libraries re-registered from the descriptor, planner
   memos dropped;
3. **replay** — nothing eager. The next materialization re-lowers only the
   DAG suffix its value actually needs; the planner's memo discipline makes
   ``replayed ⊆ lost`` by construction, and :func:`suffix_bytes` prices
   both sides analytically so the chaos gate can assert the bound.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, Optional, Set

import numpy as np

from repro.core.expr import Expr, ProjExpr, RunExpr, SendExpr, iter_nodes
from repro.core.planner import OffloadPlanner


@dataclass
class SessionRecovery:
    """The per-session recovery record: what was drained, transplanted, and
    (after the replayed pipeline materializes) actually re-run."""

    session_id: int
    name: str
    target_engine: str
    descriptor: Dict[str, Any]
    adopted_keys: int = 0
    adopted_bytes: int = 0
    #: planner memo snapshot at failure: node ids whose outputs were lost
    lost_ids: Set[int] = field(default_factory=set)
    replayed_nodes: int = 0
    replayed_bytes: int = 0

    def summary(self) -> Dict[str, Any]:
        return {
            "session_id": self.session_id,
            "name": self.name,
            "target_engine": self.target_engine,
            "adopted_keys": self.adopted_keys,
            "adopted_bytes": self.adopted_bytes,
            "lost_nodes": len(self.lost_ids),
            "replayed_nodes": self.replayed_nodes,
            "replayed_bytes": self.replayed_bytes,
        }


def _node_bytes(node: Expr) -> int:
    """Analytic output price of replaying one expr node.

    Runs are priced from their :data:`~repro.core.expr.SHAPE_RULES`-inferred
    output shapes at the best-known operand dtype (the same pricing the
    governor admits outputs with); sends at their payload size (a re-send
    only happens when the content was unrecoverable); projections are views
    of their parent's outputs and price zero.
    """
    if isinstance(node, SendExpr):
        n = 1
        for d in node.shape:
            n *= int(d)
        return n * np.dtype(node.dtype).itemsize
    if isinstance(node, RunExpr):
        try:
            shapes = node.output_shapes()
        except Exception:  # noqa: BLE001 — unpriceable stays unpriced
            return 0
        if not shapes:
            return 0
        dtype = OffloadPlanner._arg_dtype(node) or "float32"
        itemsize = np.dtype(dtype).itemsize
        total = 0
        for shp in shapes:
            if shp is None:
                continue
            n = 1
            for d in shp:
                n *= int(d)
            total += n * itemsize
        return total
    if isinstance(node, ProjExpr):
        return 0
    return 0


def suffix_bytes(roots: Iterable[Any], ids: Set[int]) -> int:
    """Σ analytic output bytes of the DAG nodes in ``ids``, walking the
    graphs under ``roots`` (AlArrays/LazyMatrix or bare Expr). Each node is
    priced once even when several roots share it."""
    seen: Set[int] = set()
    total = 0
    for root in roots:
        expr = getattr(root, "expr", root)
        if not isinstance(expr, Expr):
            continue
        for node in iter_nodes(expr):
            if node.id in ids and node.id not in seen:
                seen.add(node.id)
                total += _node_bytes(node)
    return total


class RecoveryPlanner:
    """Drain + transplant + re-admit, with fleet-level accounting."""

    def __init__(self):
        self.drains = 0
        self.drained_sessions = 0
        self.recovered_sessions = 0
        self.adopted_keys = 0
        self.adopted_bytes = 0
        self.replayed_nodes = 0
        self.replayed_bytes = 0

    # -- drain ---------------------------------------------------------------
    def drain(self, engine, server=None) -> int:
        """Drain a dead engine: stop its wire server (releases wire-bound
        sessions, unblocks mid-FETCH workers), then release every remaining
        session. ``Session.close`` migration secures each session's
        uniquely-held resident payloads host-side — the store survives the
        engine because it is host-metadata by design (DESIGN.md §8).
        Returns the number of sessions drained."""
        drained = 0
        if server is not None:
            server.stop()  # idempotent; releases its bound sessions
        for sess in list(engine.sessions.values()):
            engine.release(sess)
            drained += 1
        self.drains += 1
        self.drained_sessions += drained
        return drained

    # -- recover -------------------------------------------------------------
    def recover_client(
        self,
        core,
        dead_engine,
        target_engine,
        *,
        transport=None,
        placement=None,
    ) -> SessionRecovery:
        """Fail one client core over from ``dead_engine`` to
        ``target_engine``: transplant its recoverable content, snapshot the
        loss ledger, re-admit via :meth:`ClientCore.rebind`."""
        sess = core.session
        rec = SessionRecovery(
            session_id=int(sess.id),
            name=sess.name,
            target_engine=target_engine.name,
            descriptor=sess.descriptor(),
        )
        for entry in dead_engine.residents.recoverable_for(sess.id).values():
            if target_engine.residents.adopt(entry):
                rec.adopted_keys += 1
                rec.adopted_bytes += entry.nbytes()
        if core._planner is not None:
            rec.lost_ids = core._planner.lowered_ids()
        core.rebind(target_engine, transport=transport, placement=placement)
        self.recovered_sessions += 1
        self.adopted_keys += rec.adopted_keys
        self.adopted_bytes += rec.adopted_bytes
        return rec

    def account_replay(self, rec: SessionRecovery, roots: Iterable[Any], planner) -> int:
        """After the replayed pipeline materialized: intersect the planner's
        re-lowered node ids with the loss ledger and price the replayed
        suffix. Returns the replayed bytes (also folded into ``rec`` and the
        fleet counters)."""
        replayed = planner.lowered_ids() & rec.lost_ids
        rec.replayed_nodes = len(replayed)
        rec.replayed_bytes = suffix_bytes(roots, replayed)
        self.replayed_nodes += rec.replayed_nodes
        self.replayed_bytes += rec.replayed_bytes
        return rec.replayed_bytes

    def stats(self) -> Dict[str, int]:
        return {
            "drains": self.drains,
            "drained_sessions": self.drained_sessions,
            "recovered_sessions": self.recovered_sessions,
            "adopted_keys": self.adopted_keys,
            "adopted_bytes": self.adopted_bytes,
            "replayed_nodes": self.replayed_nodes,
            "replayed_bytes": self.replayed_bytes,
        }
