"""Architecture configs — one module per assigned architecture, plus the
paper's own workload configs.

Use :func:`repro.configs.base.get_config` / :func:`list_configs` to resolve
by ``--arch <id>``.
"""

from repro.configs.base import (
    ArchConfig,
    InputShape,
    INPUT_SHAPES,
    get_config,
    get_input_shape,
    list_configs,
)

__all__ = [
    "ArchConfig",
    "InputShape",
    "INPUT_SHAPES",
    "get_config",
    "get_input_shape",
    "list_configs",
]
