"""arctic-480b [moe] — 128 experts top-2 with a dense residual MLP.

Assigned: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual [hf:Snowflake/snowflake-arctic-base].
Optimizer moments run bf16: f32 moments for 468B params exceed a single
v5e pod's HBM (EXPERIMENTS.md §Dry-run memory notes).
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base (Arctic model card)",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    moe=MoEConfig(num_experts=128, top_k=2, d_expert=4864, dense_residual=True),
    optimizer_dtype="bfloat16",
    sliding_window=4096,
)

SMOKE = ArchConfig(
    arch_id="arctic-480b-smoke",
    family="moe",
    source=CONFIG.source,
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    d_ff=192,
    vocab=512,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=192, dense_residual=True),
    sliding_window=32,
)
