"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

Assigned: 24L d_model=768 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060].
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060 (Mamba-2/SSD); hf:state-spaces/mamba2-130m",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1, conv_width=4),
)

SMOKE = ArchConfig(
    arch_id="mamba2-130m-smoke",
    family="ssm",
    source=CONFIG.source,
    n_layers=2,
    d_model=256,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=512,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=32, expand=2, head_dim=32, n_groups=1, conv_width=4),
)
