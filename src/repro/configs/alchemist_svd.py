"""The paper's own workload: truncated SVD / GEMM / transfer matrices (§4).

Not a language model — this config drives the engine benchmarks at the
paper's matrix shapes (scaled variants selectable for the CPU container).
"""

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class PaperWorkload:
    # §4.2: rank-20 SVD of m x 10_000 matrices, m up to 5.12e6 (400 GB f64)
    svd_rows: Tuple[int, ...] = (312_500, 625_000, 1_250_000, 2_500_000, 5_000_000)
    svd_cols: int = 10_000
    svd_rank: int = 20
    # §4.1 Table 1 (dims in units of 1000)
    gemm_cases: Tuple[Tuple[int, int, int], ...] = (
        (10_000, 10_000, 10_000),
        (50_000, 10_000, 30_000),
        (100_000, 10_000, 70_000),
        (300_000, 10_000, 60_000),
    )
    # §4.3 Tables 2-3: 400 GB transfer matrices
    transfer_tall: Tuple[int, int] = (5_120_000, 10_000)
    transfer_wide: Tuple[int, int] = (40_000, 1_280_000)

    # CPU-container scale factor for wall-clock benchmarks
    bench_scale: int = 1000  # divide rows by this in local runs


CONFIG = PaperWorkload()
SMOKE = PaperWorkload(
    svd_rows=(2_000,), svd_cols=64, svd_rank=8,
    gemm_cases=((256, 128, 192),),
    transfer_tall=(4_096, 64), transfer_wide=(64, 4_096),
)
