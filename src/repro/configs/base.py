"""Config schema + registry for the assigned architectures and input shapes.

Every architecture from the assignment pool is a module in this package
defining ``CONFIG`` (exact dims, source cited) and ``SMOKE`` (a reduced
variant of the same family: <=2 layers, d_model<=512, <=4 experts, used by
CPU smoke tests). ``get_config(arch_id)`` resolves them.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_input_shape(name: str) -> InputShape:
    try:
        return INPUT_SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown input shape {name!r}; known: {sorted(INPUT_SHAPES)}") from None


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                # expert FFN hidden dim
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: dense MLP in parallel with experts
    router_aux_weight: float = 0.01
    every_k_layers: int = 1       # jamba: MoE every 2nd layer


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 64

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture. Dims follow the assignment block verbatim."""

    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    source: str                      # citation from the assignment
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None   # defaults to d_model // n_heads
    qkv_bias: bool = False           # qwen2
    qk_norm: bool = False            # qwen3
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    mlp_variant: str = "swiglu"      # "swiglu" (3 mats) | "gelu" (2 mats, whisper)
    norm_variant: str = "rmsnorm"    # "rmsnorm" | "layernorm" (whisper)
    pos_emb: str = "rope"            # "rope" | "learned" (whisper decoder)

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # hybrid (jamba): one attention layer per `attn_period` layers, rest SSM
    attn_period: int = 0             # 0 = not hybrid
    attn_offset: int = 0             # index of the attention layer in a period

    # enc-dec (whisper): encoder depth; n_layers is the decoder depth
    encoder_layers: int = 0
    encoder_seq: int = 0             # frames the encoder consumes (stub frontend)
    decoder_max_seq: int = 0         # whisper decoder context (448)

    # vlm (internvl): patch embeddings prepended to the token sequence
    vision_tokens: int = 0

    # sliding-window attention (enables long_500k for dense archs)
    sliding_window: Optional[int] = None

    # dtype policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"  # moment dtype (arctic uses bf16)

    # vocab padding for even sharding (beyond-paper optimization; None = faithful)
    pad_vocab_to_multiple: Optional[int] = None

    # MoE expert-weight sharding: False = shard D (ZeRO-style; decode must
    # all-gather weights), True = shard the expert FF dim (Megatron-in-expert;
    # decode reduces activations instead — the arctic hillclimb variant)
    moe_shard_expert_ff: bool = False

    def __post_init__(self):
        if self.n_heads and self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def padded_vocab(self) -> int:
        if not self.pad_vocab_to_multiple:
            return self.vocab
        m = self.pad_vocab_to_multiple
        return ((self.vocab + m - 1) // m) * m

    # ---- parameter counting (for roofline MODEL_FLOPS = 6·N·D) -------------
    def param_count(self, active_only: bool = False) -> int:
        """Total (or active-per-token) parameter count, embeddings included."""
        d = self.d_model
        n = 0
        # embeddings (+ untied head)
        n += self.vocab * d
        if not self.tie_embeddings:
            n += self.vocab * d

        def attn_params() -> int:
            hd = self.head_dim or 0
            p = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            if self.qkv_bias:
                p += (self.n_heads + 2 * self.n_kv_heads) * hd
            if self.qk_norm:
                p += 2 * hd
            return p

        def mlp_params(ff: int) -> int:
            return (3 if self.mlp_variant == "swiglu" else 2) * d * ff

        def moe_params(active: bool) -> int:
            assert self.moe is not None
            e = self.moe.top_k if active else self.moe.num_experts
            p = e * 3 * d * self.moe.d_expert + d * self.moe.num_experts  # + router
            if self.moe.dense_residual:
                p += mlp_params(self.d_ff)
            return p

        def ssm_params() -> int:
            assert self.ssm is not None
            di = self.ssm.d_inner(d)
            g, s = self.ssm.n_groups, self.ssm.d_state
            nh = self.ssm.n_heads(d)
            p = d * (2 * di + 2 * g * s + nh)       # in_proj (x, z, B, C, dt)
            p += self.ssm.conv_width * (di + 2 * g * s)  # conv over x,B,C
            p += nh * 2                              # A_log, D skip
            p += di * d                              # out_proj
            p += di                                  # gated norm scale
            return p

        per_layer_norms = 2 * d
        for layer in range(self.n_layers):
            n += per_layer_norms
            if self.family == "ssm":
                n += ssm_params()
                continue
            is_attn_layer = (
                self.attn_period == 0 or layer % self.attn_period == self.attn_offset
            )
            n += attn_params() if is_attn_layer else ssm_params()
            if self.is_enc_dec:
                n += attn_params() + d  # cross-attention + its norm
            is_moe_layer = self.moe is not None and (
                layer % max(self.moe.every_k_layers, 1) == (self.moe.every_k_layers - 1)
                if self.moe.every_k_layers > 1
                else True
            )
            if is_moe_layer:
                n += moe_params(active_only)
            else:
                n += mlp_params(self.d_ff)
        # encoder stack (attention + MLP per layer, fully dense)
        for _ in range(self.encoder_layers):
            n += per_layer_norms + attn_params() + mlp_params(self.d_ff)
        n += d  # final norm
        return n

    def supports_shape(self, shape: InputShape) -> Tuple[bool, str]:
        """(supported, reason-if-not) — encodes the assignment's skip rules."""
        if shape.name == "long_500k":
            sub_quadratic = (
                self.family in ("ssm", "hybrid") or self.sliding_window is not None
            )
            if self.is_enc_dec:
                return False, (
                    "enc-dec audio model: decoder context is hard-capped at "
                    f"{self.decoder_max_seq} tokens (30s audio window); a 524k-token "
                    "decode is undefined for this architecture (DESIGN.md §5)"
                )
            if not sub_quadratic:
                return False, "full-attention arch without sliding-window variant"
        return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "whisper-large-v3",
    "qwen2-1.5b",
    "deepseek-coder-33b",
    "qwen3-14b",
    "internvl2-26b",
    "olmoe-1b-7b",
    "mamba2-130m",
    "jamba-v0.1-52b",
    "arctic-480b",
    "deepseek-7b",
    # paper's own workload (not an LM): engine linear-algebra config
    "alchemist-svd",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str, *, smoke: bool = False) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return getattr(mod, "SMOKE" if smoke else "CONFIG")


def list_configs() -> Tuple[str, ...]:
    return ARCH_IDS
