"""qwen2-1.5b [dense] — GQA with QKV bias.

Assigned: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
[arXiv:2407.10671].
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2-1.5b",
    family="dense",
    source="arXiv:2407.10671 (Qwen2); hf:Qwen/Qwen2-1.5B",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    sliding_window=4096,    # enables long_500k (variant flag; off for train)
)

SMOKE = ArchConfig(
    arch_id="qwen2-1.5b-smoke",
    family="dense",
    source=CONFIG.source,
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab=512,
    qkv_bias=True,
    tie_embeddings=True,
    sliding_window=32,
)
