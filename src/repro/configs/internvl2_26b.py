"""internvl2-26b [vlm] — InternViT (stub) + InternLM2 language backbone.

Assigned: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821]. The ViT + MLP projector is a stub per the assignment
carve-out: ``input_specs()`` provides 1024 precomputed patch embeddings
prepended to the token sequence.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="internvl2-26b",
    family="vlm",
    source="arXiv:2404.16821 (InternVL2); hf:OpenGVLab/InternVL2-26B",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    rope_theta=1_000_000.0,
    vision_tokens=1024,
    sliding_window=4096,
)

SMOKE = ArchConfig(
    arch_id="internvl2-26b-smoke",
    family="vlm",
    source=CONFIG.source,
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab=512,
    vision_tokens=16,
    sliding_window=32,
)
