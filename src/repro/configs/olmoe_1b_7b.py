"""olmoe-1b-7b [moe] — 64 experts, top-8.

Assigned: 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304,
MoE 64e top-8 [arXiv:2409.02060]. d_ff is the per-expert FFN dim.
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060 (OLMoE); hf:allenai/OLMoE-1B-7B-0924",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    qk_norm=True,
    moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024),
    sliding_window=4096,
)

SMOKE = ArchConfig(
    arch_id="olmoe-1b-7b-smoke",
    family="moe",
    source=CONFIG.source,
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    qk_norm=True,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=128),
    sliding_window=32,
)
