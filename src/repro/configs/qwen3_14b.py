"""qwen3-14b [dense] — GQA with per-head qk-norm.

Assigned: 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936
[hf:Qwen/Qwen3-8B family].
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3-14b",
    family="dense",
    source="hf:Qwen/Qwen3-14B (Qwen3 family card)",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    head_dim=128,
    rope_theta=1_000_000.0,
    sliding_window=4096,
)

SMOKE = ArchConfig(
    arch_id="qwen3-14b-smoke",
    family="dense",
    source=CONFIG.source,
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab=512,
    qk_norm=True,
    head_dim=64,
    sliding_window=32,
)
