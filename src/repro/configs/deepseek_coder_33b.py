"""deepseek-coder-33b [dense] — llama-arch GQA.

Assigned: 62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256
[arXiv:2401.14196].
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek-coder-33b",
    family="dense",
    source="arXiv:2401.14196 (DeepSeek-Coder); hf:deepseek-ai/deepseek-coder-33b-base",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
    rope_theta=100_000.0,
    sliding_window=4096,
)

SMOKE = ArchConfig(
    arch_id="deepseek-coder-33b-smoke",
    family="dense",
    source=CONFIG.source,
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    d_ff=704,
    vocab=512,
    sliding_window=32,
)
