"""deepseek-7b [dense] — llama-arch, full MHA (kv = heads).

Assigned: 30L d_model=4096 32H (GQA kv=32) d_ff=11008 vocab=102400
[arXiv:2401.02954].
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek-7b",
    family="dense",
    source="arXiv:2401.02954 (DeepSeek LLM); hf:deepseek-ai/deepseek-llm-7b-base",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    rope_theta=10_000.0,
    sliding_window=4096,
)

SMOKE = ArchConfig(
    arch_id="deepseek-7b-smoke",
    family="dense",
    source=CONFIG.source,
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab=512,
    sliding_window=32,
)
