"""whisper-large-v3 [audio] — enc-dec transformer, conv/mel frontend stubbed.

Assigned: 32L d_model=1280 20H (GQA kv=20) d_ff=5120 vocab=51866
[arXiv:2212.04356]. 32 encoder + 32 decoder layers; the mel-spectrogram +
conv feature extractor is a stub per the assignment carve-out —
``input_specs()`` provides the 1500 precomputed frame embeddings.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356 (Whisper); hf:openai/whisper-large-v3",
    n_layers=32,            # decoder depth
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,          # full MHA
    d_ff=5120,
    vocab=51866,
    mlp_variant="gelu",
    norm_variant="layernorm",
    pos_emb="learned",
    rope_theta=0.0,
    encoder_layers=32,
    encoder_seq=1500,       # 30 s of audio at 50 frames/s after conv stub
    decoder_max_seq=448,
)

SMOKE = ArchConfig(
    arch_id="whisper-large-v3-smoke",
    family="audio",
    source=CONFIG.source,
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab=512,
    mlp_variant="gelu",
    norm_variant="layernorm",
    pos_emb="learned",
    encoder_layers=2,
    encoder_seq=64,
    decoder_max_seq=64,
)
