"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, 16e top-2 MoE.

Assigned: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2 [arXiv:2403.19887]. Period-8 blocks with the attention layer
at in-block index 4; MoE replaces the MLP on every second layer.
"""

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887 (Jamba); hf:ai21labs/Jamba-v0.1",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336, every_k_layers=2),
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1, conv_width=4),
    attn_period=8,
    attn_offset=4,
)

SMOKE = ArchConfig(
    arch_id="jamba-v0.1-52b-smoke",
    family="hybrid",
    source=CONFIG.source,
    n_layers=4,            # one period of 4 with attn at index 2
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab=512,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=512, every_k_layers=2),
    ssm=SSMConfig(d_state=32, expand=2, head_dim=32, n_groups=1, conv_width=4),
    attn_period=4,
    attn_offset=2,
)
