"""Tiled matmul Pallas kernel — the engine's local GEMM.

The paper's compute hot spot is dense GEMM (Elemental's ``Gemm`` wrapped via
the ALI, §4.1). On TPU the distributed layer (SUMMA, :mod:`repro.linalg.gemm`)
reduces to *local* GEMMs per device; this kernel is that local GEMM, tiled
for VMEM with an f32 accumulator held in scratch across the K-loop.

Tiling notes (v5e): MXU is a 128x128 systolic array — block dims are
multiples of 128 in production (defaults below); the K grid dimension is
innermost so the accumulator tile stays resident in VMEM while A/B tiles
stream HBM→VMEM. VMEM working set = bm*bk + bk*bn + bm*bn(f32)
≈ (512·512·2)·2 + 512·512·4 ≈ 2.1 MiB at defaults — comfortably inside the
~16 MiB/core budget, leaving room for double-buffering.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Production block sizes (MXU-aligned). Tests sweep smaller ones.
DEFAULT_BM = 512
DEFAULT_BN = 512
DEFAULT_BK = 512


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref):
    """One (i, j, k) grid step: acc += A[i,k] @ B[k,j]; flush at last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x: jax.Array, mult: Tuple[int, int]) -> jax.Array:
    m, n = x.shape
    pm, pn = (-m) % mult[0], (-n) % mult[1]
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"),
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """C[m,n] = A[m,k] @ B[k,n] with f32 accumulation.

    Inputs are zero-padded up to block multiples (zero padding is exact for
    matmul); the result is sliced back.
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul expects 2D operands, got {a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    out_dtype = out_dtype or a.dtype
    m, kdim = a.shape
    _, n = b.shape

    bm_, bn_, bk_ = min(bm, max(m, 1)), min(bn, max(n, 1)), min(bk, max(kdim, 1))
    ap = _pad_to(a, (bm_, bk_))
    bp = _pad_to(b, (bk_, bn_))
    mp, kp = ap.shape
    _, np_ = bp.shape

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm_, np_ // bn_, kp // bk_),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
        name="repro_tiled_matmul",
    )(ap, bp)
    return out[:m, :n]


def vmem_bytes(bm: int, bn: int, bk: int, dtype=jnp.bfloat16) -> int:
    """Working-set estimate used by block-size selection and DESIGN notes."""
    itm = jnp.dtype(dtype).itemsize
    return bm * bk * itm + bk * bn * itm + bm * bn * 4
