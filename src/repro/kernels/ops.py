"""Dispatching wrappers — the public kernel API the rest of the framework uses.

On TPU, calls lower to the Pallas kernels; elsewhere (this CPU container,
unit tests) they run the pure-jnp oracles in :mod:`repro.kernels.ref`. Set
``REPRO_FORCE_PALLAS=interpret`` to exercise the kernel bodies on CPU via
interpret mode (used by the kernel test suite).

The dispatch is deliberately *per-call-site static* (a module-level backend
probe), so jitted programs never trace both paths.
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax

from repro.kernels import flash_attention as _flash
from repro.kernels import matmul as _matmul
from repro.kernels import ref as _ref
from repro.kernels import relayout_pad as _relayout_pad
from repro.kernels import ssd_scan as _ssd

_FORCE = os.environ.get("REPRO_FORCE_PALLAS", "").lower()


def backend() -> str:
    if _FORCE == "interpret":
        return "pallas-interpret"
    if _FORCE in ("1", "true", "tpu"):
        return "pallas"
    try:
        plat = jax.default_backend()
    except Exception:  # pragma: no cover - no devices at all
        plat = "cpu"
    return "pallas" if plat == "tpu" else "ref"


_BACKEND = backend()

# Dry-run cost-variant mode: "real" (default), "stub" (O(L·D) stand-in so the
# cost fit isolates non-attention work; see repro.roofline.attention_model).
ATTENTION_MODE = "real"


def use_pallas() -> bool:
    return _BACKEND.startswith("pallas")


def _interp() -> bool:
    return _BACKEND == "pallas-interpret"


def matmul(
    a: jax.Array, b: jax.Array, *, out_dtype=None, block: Optional[Tuple[int, int, int]] = None
) -> jax.Array:
    """Local (per-device) GEMM with f32 accumulation."""
    if use_pallas():
        bm, bn, bk = block or (_matmul.DEFAULT_BM, _matmul.DEFAULT_BN, _matmul.DEFAULT_BK)
        return _matmul.matmul(
            a, b, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, interpret=_interp()
        )
    return _ref.matmul(a, b, out_dtype=out_dtype)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    block: Optional[Tuple[int, int]] = None,
) -> jax.Array:
    """GQA scaled-dot-product attention [B, Hq, Lq, D] x [B, Hkv, Lk, D]."""
    if ATTENTION_MODE == "stub":
        return _ref.attention_stub(q, k, v)
    if use_pallas():
        lq, lk = q.shape[2], k.shape[2]
        bq, bk = block or (_flash.DEFAULT_BQ, _flash.DEFAULT_BK)
        # shrink blocks to legal divisors for small/ragged shapes
        while lq % min(bq, lq):
            bq //= 2
        while lk % min(bk, lk):
            bk //= 2
        return _flash.flash_attention(
            q, k, v,
            causal=causal, window=window, scale=scale, q_offset=q_offset,
            bq=bq, bk=bk, interpret=_interp(),
        )
    lq, lk = q.shape[2], k.shape[2]
    if lq >= 2048 and lq * lk >= (1 << 22):
        # flash-structured streaming program: bounded memory, kernel-like
        # HBM traffic in the dry-run's memory analysis
        return _ref.attention_chunked(
            q, k, v, causal=causal, window=window, scale=scale, q_offset=q_offset
        )
    return _ref.attention(
        q, k, v, causal=causal, window=window, scale=scale, q_offset=q_offset
    )


def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b_mat: jax.Array,
    c_mat: jax.Array,
    *,
    init_state: Optional[jax.Array] = None,
    chunk: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """Mamba2 SSD over a sequence; returns (y, final_state)."""
    if use_pallas():
        return _ssd.ssd_scan(
            x, dt, a, b_mat, c_mat, init_state=init_state, chunk=chunk,
            interpret=_interp(),
        )
    if x.shape[1] % max(min(chunk, x.shape[1]), 1) == 0 and x.shape[1] >= chunk:
        # chunked oracle: same math as the kernel, parallel-friendly HLO
        return _ref.ssd_chunked(x, dt, a, b_mat, c_mat, chunk=chunk, init_state=init_state)
    return _ref.ssd_scan(x, dt, a, b_mat, c_mat, init_state=init_state)


def _fusable(x) -> bool:
    """Pallas pad/strip take one device's buffer: numpy hosts and
    single-device jax arrays qualify; sharded arrays fall back to ref."""
    if isinstance(x, jax.Array):
        try:
            return len(x.sharding.device_set) == 1
        except Exception:  # pragma: no cover - exotic array types
            return False
    return True  # numpy / python buffers: pallas_call will device_put them


def pad_to(x, physical_shape: Tuple[int, int]):
    """Pad ``x`` up to the layout's physical shape.

    Returns ``(padded, path)`` where ``path`` is the backend that actually
    ran: "pallas" / "pallas-interpret" (fused kernel) or "ref" (jnp.pad).
    The plan cache records the path so benchmarks can attribute fusion.
    """
    if use_pallas() and _fusable(x):
        try:
            return _relayout_pad.pad_to(x, tuple(physical_shape), interpret=_interp()), _BACKEND
        except ValueError:
            raise
        except Exception:  # lowering/compile failure: fall back to the oracle
            pass
    return _ref.pad_to(x, tuple(physical_shape)), "ref"


def strip_to(x, logical_shape: Tuple[int, int]):
    """Strip divisibility padding down to the logical shape.

    Returns ``(stripped, path)`` — same contract as :func:`pad_to`.
    """
    if use_pallas() and _fusable(x):
        try:
            return _relayout_pad.strip_to(x, tuple(logical_shape), interpret=_interp()), _BACKEND
        except ValueError:
            raise
        except Exception:
            pass
    return _ref.strip_to(x, tuple(logical_shape)), "ref"


def ssd_step(
    x: jax.Array,      # [B, 1, H, P] single token
    dt: jax.Array,     # [B, 1, H]
    a: jax.Array,      # [H]
    b_mat: jax.Array,  # [B, 1, G, N]
    c_mat: jax.Array,  # [B, 1, G, N]
    state: jax.Array,  # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD recurrence for decode (no kernel needed: O(1) work)."""
    return _ref.ssd_scan(x, dt, a, b_mat, c_mat, init_state=state)
