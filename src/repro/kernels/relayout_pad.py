"""Fused pad/strip relayout kernels — the bridge's divisibility padding on device.

The bridge pads uneven matrices up to the destination layout's shard-count
multiples before ``device_put`` and slices the padding off on collect/refill
(DESIGN.md §7). As host-side ``jnp.pad`` + slice passes those cost an extra
materialization each way; these kernels fuse the mask/copy into a single
tiled device pass (DESIGN.md §10), following the grid idiom of
:mod:`repro.kernels.matmul`.

- :func:`pad_to` grids over the *physical* (padded) output. Each input block
  shares the output block's index map, so edge tiles read out of bounds; a
  ``broadcasted_iota`` mask against the logical extent selects real values
  and writes zeros elsewhere — OOB reads never reach the output.
- :func:`strip_to` grids over the *logical* output with a block that divides
  the physical input dims, so every input read is in bounds; partial edge
  output tiles are write-masked by Pallas automatically and the body is a
  straight block copy.

Bit-exactness against :mod:`repro.kernels.ref` is property-tested in
tests/test_padded_roundtrip.py; ``ops.py`` dispatches here on TPU (or under
``REPRO_FORCE_PALLAS=interpret``) and to the jnp references on CPU.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _pick_block(dim: int, cap: int = DEFAULT_BLOCK) -> int:
    """Largest divisor of ``dim`` not exceeding ``cap`` — blocks that divide
    the physical extent keep strip_to's reads in bounds and pad_to's grid
    exact."""
    dim = max(int(dim), 1)
    for cand in range(min(cap, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return 1  # pragma: no cover - range always yields 1


def _pad_kernel(x_ref, o_ref, *, m: int, n: int, bm: int, bn: int):
    i, j = pl.program_id(0), pl.program_id(1)
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    mask = (rows < m) & (cols < n)
    o_ref[...] = jnp.where(mask, x_ref[...], jnp.zeros((), o_ref.dtype))


def _strip_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("physical_shape", "block", "interpret"))
def pad_to(
    x: jax.Array,
    physical_shape: Tuple[int, int],
    *,
    block: Optional[Tuple[int, int]] = None,
    interpret: bool = False,
) -> jax.Array:
    """Zero-pad ``x`` [m, n] up to ``physical_shape`` [mp, np] in one pass."""
    m, n = x.shape
    mp, np_ = int(physical_shape[0]), int(physical_shape[1])
    if (mp, np_) == (m, n):
        return x
    if mp < m or np_ < n:
        raise ValueError(f"cannot pad {x.shape} down to {physical_shape}")
    bm, bn = block or (_pick_block(mp), _pick_block(np_))
    kern = functools.partial(_pad_kernel, m=m, n=n, bm=bm, bn=bn)
    return pl.pallas_call(
        kern,
        grid=(mp // bm, np_ // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
        name="repro_relayout_pad",
    )(x)


@functools.partial(jax.jit, static_argnames=("logical_shape", "block", "interpret"))
def strip_to(
    x: jax.Array,
    logical_shape: Tuple[int, int],
    *,
    block: Optional[Tuple[int, int]] = None,
    interpret: bool = False,
) -> jax.Array:
    """Slice the divisibility padding off ``x`` [mp, np] down to [m, n]."""
    mp, np_ = x.shape
    m, n = int(logical_shape[0]), int(logical_shape[1])
    if (m, n) == (mp, np_):
        return x
    if m > mp or n > np_:
        raise ValueError(f"cannot strip {x.shape} up to {logical_shape}")
    bm, bn = block or (_pick_block(mp), _pick_block(np_))
    return pl.pallas_call(
        _strip_kernel,
        grid=(pl.cdiv(m, bm), pl.cdiv(n, bn)),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
        name="repro_relayout_strip",
    )(x)
