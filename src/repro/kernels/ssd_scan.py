"""Mamba2 SSD chunked-scan Pallas kernel.

The state-space-duality form (Dao & Gu, arXiv:2405.21060): within a chunk
the recurrence is a masked, decay-weighted "attention" (MXU-friendly
matmuls); across chunks only the [P, N] state is carried. The chunk axis is
the innermost grid dimension, so the state lives in VMEM scratch across grid
steps — the TPU version of the paper's kernel, rethought from the CUDA warp
formulation into a grid-carried-scratch pipeline.

Grid: (batch, heads, n_chunks). Per-step VMEM: x (c·P), dt (c), B/C (c·N),
state (P·N f32), chunk-local (c·c) attention tile. With c=64, P=64, N=128:
≈ 100 KiB — small; multiple heads pipeline concurrently.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _ssd_kernel(
    a_ref,      # [1] decay rate for this head
    x_ref,      # [1, c, 1, P]
    dt_ref,     # [1, c, 1]
    b_ref,      # [1, c, 1, N]
    c_ref,      # [1, c, 1, N]
    h0_ref,     # [1, 1, P, N] initial state
    y_ref,      # [1, c, 1, P] out
    hout_ref,   # [1, 1, P, N] final state out
    state_ref,  # VMEM [P, N] f32 carried across chunks
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    a = a_ref[0]
    x = x_ref[0, :, 0, :].astype(jnp.float32)      # [c, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # [c]
    bmat = b_ref[0, :, 0, :].astype(jnp.float32)   # [c, N]
    cmat = c_ref[0, :, 0, :].astype(jnp.float32)   # [c, N]

    la = dt * a                                    # log decay per step [c]
    seg = jnp.cumsum(la)                           # [c]

    # Intra-chunk masked attention term.
    att = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)  # [c, c]
    dseg = seg[:, None] - seg[None, :]             # [t, s]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(s_idx <= t_idx, jnp.exp(dseg), 0.0)
    att = att * w
    dx = dt[:, None] * x                           # [c, P]
    y_intra = jnp.dot(att, dx, preferred_element_type=jnp.float32)   # [c, P]

    # Inter-chunk term from the carried state.
    h_in = state_ref[...]                          # [P, N]
    c_dec = cmat * jnp.exp(seg)[:, None]           # [c, N]
    y_inter = jnp.dot(c_dec, h_in.T, preferred_element_type=jnp.float32)  # [c, P]

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # State update: h = exp(sum la) * h_in + sum_s exp(seg_last - seg_s) dx_s ⊗ b_s
    wst = jnp.exp(seg[-1] - seg)                   # [c]
    contrib = jnp.dot((wst[:, None] * dx).T, bmat, preferred_element_type=jnp.float32)  # [P, N]
    state_ref[...] = jnp.exp(seg[-1]) * h_in + contrib

    @pl.when(ci == pl.num_programs(2) - 1)
    def _final():
        hout_ref[0, 0] = state_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,      # [B, L, H, P]
    dt: jax.Array,     # [B, L, H]
    a: jax.Array,      # [H]
    b_mat: jax.Array,  # [B, L, G, N]
    c_mat: jax.Array,  # [B, L, G, N]
    *,
    init_state: Optional[jax.Array] = None,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Kernel-backed SSD. Same contract as :func:`repro.kernels.ref.ssd_scan`."""
    B, L, H, P = x.shape
    G, N = b_mat.shape[2], b_mat.shape[3]
    if H % G:
        raise ValueError(f"H={H} must be divisible by G={G}")
    group = H // G
    c = min(chunk, L)
    if L % c:
        raise ValueError(f"L={L} must be divisible by chunk={c}")
    h0 = (
        init_state
        if init_state is not None
        else jnp.zeros((B, H, P, N), x.dtype)
    )

    kernel = functools.partial(_ssd_kernel, chunk=c)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(B, H, L // c),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
            pl.BlockSpec((1, c, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, c, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1, c, 1, N), lambda b, h, ci, g=group: (b, ci, h // g, 0)),
            pl.BlockSpec((1, c, 1, N), lambda b, h, ci, g=group: (b, ci, h // g, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        name="repro_ssd_scan",
    )(a, x, dt, b_mat, c_mat, h0)
    return y, h_final
