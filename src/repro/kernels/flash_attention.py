"""Flash attention Pallas kernel (full / causal / sliding-window, GQA-aware).

Used by every attention-bearing assigned architecture. Online-softmax
formulation: KV blocks stream as the innermost grid dimension while the
output accumulator, running max and running denominator stay in VMEM
scratch — O(Lq·D) memory instead of O(Lq·Lk).

GQA is handled in the BlockSpec index maps: the KV specs map query head
``h`` to KV head ``h // group``, so no materialized ``repeat`` of K/V.

Block sizes: (block_q=512, block_k=512) by default — q/k/v tiles are
(512·D)·2B each (D≤256 → ≤512 KiB), acc is (512·D)·4B; the VPU-heavy
exp/max run on (512,512) f32 tiles (1 MiB), total well under VMEM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512
_NEG_INF = -1e30  # finite sentinel: keeps masked-all-block math NaN-free


def _flash_kernel(
    q_ref,  # [1, 1, bq, D]
    k_ref,  # [1, 1, bk, D]
    v_ref,  # [1, 1, bk, D]
    o_ref,  # [1, 1, bq, D]
    acc_ref,  # VMEM [bq, D] f32
    m_ref,  # VMEM [bq] f32 running max
    l_ref,  # VMEM [bq] f32 running denom
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    bq: int,
    bk: int,
):
    kv_idx = pl.program_id(3)

    @pl.when(kv_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]  # [bq, D]
    k = k_ref[0, 0]  # [bk, D]
    v = v_ref[0, 0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq, bk]

    q_pos = pl.program_id(2) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    k_pos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    # Exact masking: exp(_NEG_INF - m) underflows to 0 already, but be sure.
    p = jnp.where(mask, p, 0.0)

    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(kv_idx == pl.num_programs(3) - 1)
    def _final():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, Hq, Lq, D]
    k: jax.Array,  # [B, Hkv, Lk, D]
    v: jax.Array,  # [B, Hkv, Lk, D]
    *,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}")
    group = hq // hkv
    scale_val = scale if scale is not None else 1.0 / (d**0.5)

    bq_ = min(bq, lq)
    bk_ = min(bk, lk)
    if lq % bq_ or lk % bk_:
        raise ValueError(
            f"Lq={lq} / Lk={lk} must be divisible by block sizes ({bq_}, {bk_})"
        )

    kernel = functools.partial(
        _flash_kernel,
        scale=scale_val,
        causal=causal,
        window=window,
        q_offset=q_offset,
        bq=bq_,
        bk=bk_,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, lq // bq_, lk // bk_),
        in_specs=[
            pl.BlockSpec((1, 1, bq_, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bk_, d), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk_, d), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, d), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
        ],
        interpret=interpret,
        name="repro_flash_attention",
    )(q, k, v)
