"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references (``assert_allclose`` targets in tests)
AND the CPU execution path: ``ops.py`` dispatches to these when not running
on TPU, so the whole framework runs and is testable on CPU while lowering to
the Pallas kernels on real hardware.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Tiled matmul oracle
# ---------------------------------------------------------------------------

def matmul(a: jax.Array, b: jax.Array, *, out_dtype=None) -> jax.Array:
    """C = A @ B with f32 accumulation (the MXU contract)."""
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(
        a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)


# ---------------------------------------------------------------------------
# Relayout pad/strip oracles (bridge divisibility padding, DESIGN.md §7/§10)
# ---------------------------------------------------------------------------

def pad_to(x: jax.Array, physical_shape: Tuple[int, int]) -> jax.Array:
    """Zero-pad ``x`` [m, n] up to ``physical_shape`` [mp, np]."""
    m, n = x.shape
    mp, np_ = int(physical_shape[0]), int(physical_shape[1])
    if (mp, np_) == (m, n):
        return x
    if mp < m or np_ < n:
        raise ValueError(f"cannot pad {x.shape} down to {physical_shape}")
    return jnp.pad(x, ((0, mp - m), (0, np_ - n)))


def strip_to(x: jax.Array, logical_shape: Tuple[int, int]) -> jax.Array:
    """Slice the divisibility padding off ``x`` [mp, np] down to [m, n]."""
    mp, np_ = x.shape
    m, n = int(logical_shape[0]), int(logical_shape[1])
    if (m, n) == (mp, np_):
        return x
    if m > mp or n > np_:
        raise ValueError(f"cannot strip {x.shape} up to {logical_shape}")
    return x[:m, :n]


# ---------------------------------------------------------------------------
# Flash attention oracle (full / causal / sliding-window)
# ---------------------------------------------------------------------------

def attention(
    q: jax.Array,  # [B, Hq, Lq, D]
    k: jax.Array,  # [B, Hkv, Lk, D]
    v: jax.Array,  # [B, Hkv, Lk, D]
    *,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Reference scaled-dot-product attention with GQA head grouping.

    ``window``: sliding-window width — query i attends to keys in
    ``(i_abs - window, i_abs]`` where ``i_abs = i + q_offset`` (decode uses
    q_offset = position of the first query token).
    """
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}"
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    qk = jnp.einsum(
        "bhgqd,bhkd->bhgqk",
        q.reshape(b, hkv, group, lq, d),
        k,
        preferred_element_type=jnp.float32,
    ) * scale

    lk = k.shape[2]
    q_pos = jnp.arange(lq)[:, None] + q_offset
    k_pos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    qk = jnp.where(mask[None, None, None], qk, -jnp.inf)

    p = jax.nn.softmax(qk, axis=-1)
    # Rows that mask out everything (can happen with window=0) -> zeros.
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)
    return out.reshape(b, hq, lq, d)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    q_chunk: Optional[int] = None,
) -> jax.Array:
    """Query-chunked attention (flash-structured XLA program).

    Streams query blocks through a ``lax.scan`` so peak memory is
    O(qc · Lk) instead of O(Lq · Lk) — this is what the real Pallas kernel
    does on TPU, and what the dry-run's memory analysis should see.
    Numerics match :func:`attention` exactly (same masked softmax per row).
    """
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d**0.5)

    qc = q_chunk or max(min(lq, (1 << 22) // max(lk, 1)), 16)
    while lq % qc:
        qc //= 2
    nq = lq // qc
    if nq <= 1:
        return attention(q, k, v, causal=causal, window=window, scale=scale, q_offset=q_offset)

    qr = q.reshape(b, hkv, group, nq, qc, d)
    k_pos = jnp.arange(lk)[None, :]

    @jax.checkpoint  # flash-style backward: recompute scores per chunk
    def chunk_out(qi, idx):
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qi, k, preferred_element_type=jnp.float32) * scale
        q_pos = (idx * qc + jnp.arange(qc))[:, None] + q_offset
        mask = jnp.ones((qc, lk), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)
        return jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)

    def chunk(carry, inputs):
        qi, idx = inputs                                   # [B,Hkv,G,qc,D], []
        return carry, chunk_out(qi, idx)

    _, outs = jax.lax.scan(
        chunk, None, (jnp.moveaxis(qr, 3, 0), jnp.arange(nq))
    )                                                      # [nq, B, Hkv, G, qc, D]
    out = jnp.moveaxis(outs, 0, 3).reshape(b, hq, lq, d)
    return out


def attention_stub(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Shape/dtype-correct O(L·D) stand-in for attention.

    Used ONLY by the dry-run's cost-fit variant compiles: the fit then
    measures everything-but-attention exactly, and the roofline adds the
    analytic flash-attention terms (repro.roofline.attention_model) back.
    Never used in a program that produces real numbers.
    """
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kv = (k.mean(axis=2, keepdims=True) + v.mean(axis=2, keepdims=True))  # [B,Hkv,1,D]
    kv = jnp.repeat(kv, group, axis=1)                                    # [B,Hq,1,D]
    return (q * kv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality) chunked-scan oracle
# ---------------------------------------------------------------------------

def ssd_scan(
    x: jax.Array,      # [B, L, H, P]   inputs per head
    dt: jax.Array,     # [B, L, H]      softplus-activated step sizes (>0)
    a: jax.Array,      # [H]            negative decay rates (A = -exp(a_log))
    b_mat: jax.Array,  # [B, L, G, N]   input projections (G groups)
    c_mat: jax.Array,  # [B, L, G, N]   output projections
    *,
    init_state: Optional[jax.Array] = None,  # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Reference SSD recurrence (sequential scan over time).

    h_t = exp(dt_t * a) * h_{t-1} + dt_t * x_t ⊗ b_t
    y_t = <h_t, c_t>

    Returns (y [B, L, H, P], final_state [B, H, P, N]).
    """
    B, L, H, P = x.shape
    G, N = b_mat.shape[2], b_mat.shape[3]
    assert H % G == 0
    rep = H // G
    bh = jnp.repeat(b_mat, rep, axis=2)  # [B, L, H, N]
    ch = jnp.repeat(c_mat, rep, axis=2)  # [B, L, H, N]

    decay = jnp.exp(dt * a[None, None, :])          # [B, L, H]
    inp = (dt[..., None, None] * x[..., :, None]) * bh[..., None, :]  # [B,L,H,P,N]

    h0 = init_state if init_state is not None else jnp.zeros((B, H, P, N), x.dtype)

    def step(h, t):
        d_t, u_t, c_t = t
        h = d_t[..., None, None] * h + u_t
        y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y

    xs = (
        jnp.moveaxis(decay, 1, 0),
        jnp.moveaxis(inp, 1, 0),
        jnp.moveaxis(ch, 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # [B, L, H, P]
    return y, h_final.astype(x.dtype)


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b_mat: jax.Array,
    c_mat: jax.Array,
    *,
    chunk: int = 64,
    init_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD (the parallel form the Pallas kernel implements).

    Within a chunk the recurrence is computed as masked "attention"
    (the duality); across chunks states are passed by a short scan. This is
    the algorithm of Dao & Gu (arXiv:2405.21060) §6, and the oracle for the
    kernel's internal structure; it must agree with :func:`ssd_scan`.
    """
    B, L, H, P = x.shape
    G, N = b_mat.shape[2], b_mat.shape[3]
    rep = H // G
    assert L % chunk == 0, f"L={L} must be divisible by chunk={chunk}"
    nc = L // chunk

    bh = jnp.repeat(b_mat, rep, axis=2)
    ch = jnp.repeat(c_mat, rep, axis=2)

    # reshape into chunks: [B, nc, chunk, H, ...]
    xr = x.reshape(B, nc, chunk, H, P)
    dtr = dt.reshape(B, nc, chunk, H)
    br = bh.reshape(B, nc, chunk, H, N)
    cr = ch.reshape(B, nc, chunk, H, N)

    la = dtr * a[None, None, None, :]          # log-decay per step  [B,nc,c,H]
    seg = jnp.cumsum(la, axis=2)               # within-chunk cumulative log decay

    # Intra-chunk ("attention") term: y_intra[t] = sum_{s<=t} C_t.B_s
    #   * exp(seg_t - seg_s) * dt_s * x_s
    att = jnp.einsum("bkthn,bkshn->bkhts", cr, br, preferred_element_type=jnp.float32)
    dseg = seg[:, :, :, None, :] - seg[:, :, None, :, :]  # [B,nc,t,s,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(mask[None, None, :, :, None], jnp.exp(dseg), 0.0)
    att = att * jnp.moveaxis(w, -1, 2)                     # [B,nc,H,t,s]
    y_intra = jnp.einsum(
        "bkhts,bkshp->bkthp", att, (dtr[..., None] * xr).astype(jnp.float32)
    )

    # Chunk-final states: h_chunk = sum_s exp(seg_last - seg_s) dt_s x_s b_s
    last = seg[:, :, -1:, :]                               # [B,nc,1,H]
    wst = jnp.exp(last - seg)                              # [B,nc,c,H]
    state_c = jnp.einsum(
        "bkshp,bkshn->bkhpn",
        (wst[..., None] * dtr[..., None] * xr).astype(jnp.float32),
        br.astype(jnp.float32),
    )                                                      # per-chunk state contribution
    chunk_decay = jnp.exp(jnp.sum(la, axis=2))             # [B,nc,H]

    h0 = init_state if init_state is not None else jnp.zeros((B, H, P, N), jnp.float32)
    h0 = h0.astype(jnp.float32)

    def pass_state(h, t):
        dec, sc = t
        h_in = h                                          # state entering this chunk
        h = dec[..., None, None] * h + sc
        return h, h_in

    h_final, h_enter = jax.lax.scan(
        pass_state,
        h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(state_c, 1, 0)),
    )
    h_enter = jnp.moveaxis(h_enter, 0, 1)                  # [B,nc,H,P,N]

    # Inter-chunk term: y_inter[t] = C_t . (exp(seg_t) * h_enter)
    y_inter = jnp.einsum(
        "bkthn,bkhpn->bkthp", (cr * jnp.exp(seg)[..., None]).astype(jnp.float32), h_enter
    )

    y = (y_intra + y_inter).reshape(B, L, H, P).astype(x.dtype)
    return y, h_final.astype(x.dtype)
