"""Pallas TPU kernels for the framework's compute hot spots.

- ``matmul.py``          — tiled local GEMM (the SUMMA inner kernel; the
                           paper's Elemental-GEMM hot spot)
- ``flash_attention.py`` — online-softmax attention (full/causal/window, GQA)
- ``ssd_scan.py``        — Mamba2 SSD chunked scan
- ``ops.py``             — dispatching wrappers (pallas on TPU, oracle on CPU)
- ``ref.py``             — pure-jnp oracles (correctness ground truth)
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
