"""train — optimizer, schedules, data pipeline, checkpointing, loop.

The data pipeline is deliberately framed as the "Spark side" of the system:
it produces row-sharded batches (``P(('pod','data'))``) exactly like the
paper's RDD partitions, and the train step consumes them under the 2D
compute sharding — the ingest boundary is the Alchemist bridge (DESIGN §4).
"""

from repro.train.optimizer import AdamW, OptState
from repro.train.schedule import constant, cosine_warmup
from repro.train.train_step import make_train_step
from repro.train.data import SyntheticTokens

__all__ = [
    "AdamW",
    "OptState",
    "constant",
    "cosine_warmup",
    "make_train_step",
    "SyntheticTokens",
]
