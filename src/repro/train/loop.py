"""The training loop driver: sharded init, jitted step, logging, checkpoints."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ArchConfig, InputShape
from repro.core.sharding import ShardingRules
from repro.models.registry import build_model
from repro.train import checkpoint as ckpt_mod
from repro.train.data import SyntheticTokens
from repro.train.optimizer import AdamW
from repro.train.schedule import cosine_warmup
from repro.train.train_step import make_train_step


def train(
    cfg: ArchConfig,
    shape: InputShape,
    mesh: Mesh,
    *,
    steps: int = 50,
    peak_lr: float = 3e-4,
    warmup: int = 10,
    seed: int = 0,
    microbatches: int = 1,
    remat: str = "none",
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    log_every: int = 10,
    log_fn: Callable[[str], None] = print,
) -> Dict[str, List[float]]:
    """Train ``cfg`` on synthetic data; returns the metric history."""
    rules = ShardingRules.default(mesh)
    model = build_model(cfg, mesh, rules, remat=remat)
    optimizer = AdamW(
        learning_rate=cosine_warmup(peak_lr, warmup, steps),
        moment_dtype=cfg.optimizer_dtype,
    )

    with mesh:
        params = model.init(jax.random.PRNGKey(seed))
        pspecs = model.param_partition_specs()
        params = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, pspecs
        )
        opt_state = optimizer.init(params)

        step_fn = jax.jit(
            make_train_step(model, optimizer, microbatches=microbatches),
            donate_argnums=(0, 1),
        )

        data = SyntheticTokens(cfg, shape, mesh, rules, seed=seed)
        history: Dict[str, List[float]] = {}
        t_start = time.perf_counter()
        for step in range(steps):
            batch = data.batch_at(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if log_every and (step % log_every == 0 or step == steps - 1):
                metrics = jax.device_get(metrics)
                for k, v in metrics.items():
                    history.setdefault(k, []).append(float(v))
                history.setdefault("step", []).append(step)
                log_fn(
                    f"step {step:5d} loss={float(metrics['loss']):.4f} "
                    f"acc={float(metrics.get('accuracy', 0)):.3f} "
                    f"gnorm={float(metrics.get('grad_norm', 0)):.3f}"
                )
            if ckpt_dir and ckpt_every and step and step % ckpt_every == 0:
                ckpt_mod.save(ckpt_dir, step, {"params": params, "opt": opt_state})
        wall = time.perf_counter() - t_start
        history["wall_seconds"] = [wall]
        log_fn(f"trained {steps} steps in {wall:.1f}s")
        if ckpt_dir:
            ckpt_mod.save(ckpt_dir, steps, {"params": params, "opt": opt_state})
    return history
