"""Checkpointing: sharded pytrees <-> on-disk npz + manifest.

Pure-stdlib (npz per leaf-group + a JSON manifest carrying the tree
structure, shapes, dtypes, step). Restore re-places leaves onto the given
shardings — so a checkpoint written on one mesh restores onto another
(reshape-free relayout via device_put), which is the engine's ROW->GRID
story applied to weights.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def save(directory: str, step: int, tree: Any, *, extra: Optional[Dict] = None) -> str:
    """Write a checkpoint; returns its path."""
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(name.split("_")[1])
        for name in os.listdir(directory)
        if name.startswith("step_")
    ]
    return max(steps) if steps else None


def restore(
    directory: str,
    step: int,
    like: Any,
    *,
    mesh: Optional[Mesh] = None,
    specs: Optional[Any] = None,
) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). With (mesh, specs) the leaves are placed sharded."""
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat_keys = _flatten_with_paths(like)
        leaves, treedef = jax.tree_util.tree_flatten(like)
        keys_in_order = list(flat_keys.keys())
        spec_leaves = (
            jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: x is None or hasattr(x, "__iter__") or True
            )
            if specs is not None
            else [None] * len(leaves)
        )
        if specs is not None:
            spec_flat = _flatten_with_paths(specs)
        out = []
        for i, key in enumerate(keys_in_order):
            arr = data[key]
            want = leaves[i]
            if arr.shape != tuple(want.shape):
                raise ValueError(
                    f"checkpoint leaf {key!r} has shape {arr.shape}, expected {tuple(want.shape)}"
                )
            arr = arr.astype(want.dtype)
            if mesh is not None and specs is not None:
                out.append(jax.device_put(arr, NamedSharding(mesh, spec_flat[key])))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
