"""AdamW with sharding-aware, dtype-configurable moment states.

Moments inherit each parameter's PartitionSpec (ZeRO-style: optimizer state
is as sharded as the weights). ``moment_dtype=bfloat16`` halves optimizer
HBM for the 480B-class configs (EXPERIMENTS.md memory notes) at the cost of
some update noise — the paper-faithful default is f32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any        # first moment, pytree like params
    nu: Any        # second moment


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array]  # schedule: step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"

    def init(self, params) -> OptState:
        dt = jnp.dtype(self.moment_dtype)
        def zeros(p):
            return jnp.zeros(p.shape, dt)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def state_partition_specs(self, param_specs) -> OptState:
        from jax.sharding import PartitionSpec as P

        return OptState(step=P(), mu=param_specs, nu=param_specs)

    def update(self, grads, state: OptState, params) -> Tuple[Any, OptState, dict]:
        dt = jnp.dtype(self.moment_dtype)
        step = state.step + 1

        gnorm = _global_norm(grads)
        if self.grad_clip_norm is not None:
            scale = jnp.minimum(1.0, self.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.learning_rate(step)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices, not norms
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(state.mu)
        flat_v = jax.tree_util.tree_leaves(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
        metrics = {"grad_norm": gnorm, "learning_rate": lr}
        return new_params, OptState(step=step, mu=new_mu, nu=new_nu), metrics


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(seq.astype(jnp.float32))) for seq in leaves)
    )
