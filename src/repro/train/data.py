"""Synthetic data pipeline — the "Spark ingest" side of the system.

Produces deterministic, seekable batches of token sequences, sharded
row-wise over the data axes exactly like the paper's RDD partitions
(each "executor" = data shard owns a contiguous slab of the batch). The
generator is a small Markov chain over the vocabulary, so the data has
learnable structure: training losses genuinely decrease, which the
end-to-end example (examples/train_e2e.py) asserts.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.core.sharding import ShardingRules, divisible_spec
from repro.models.registry import input_specs


@dataclasses.dataclass
class SyntheticTokens:
    """Markov-chain token stream with per-step deterministic batches."""

    cfg: ArchConfig
    shape: InputShape
    mesh: Mesh
    rules: Optional[ShardingRules] = None
    seed: int = 0
    branching: int = 8   # successors per state -> entropy floor ~ log(branching)

    def __post_init__(self):
        self.rules = self.rules or ShardingRules.default(self.mesh)
        rng = np.random.default_rng(self.seed)
        v = min(self.cfg.vocab, 4096)  # active vocabulary
        self._active_vocab = v
        # sparse transition table: state -> `branching` successors
        self._succ = rng.integers(0, v, size=(v, self.branching), dtype=np.int32)

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        """Deterministic batch for a step (seekable — checkpoint-resumable)."""
        key = jax.random.PRNGKey(self.seed * 1_000_003 + step)
        specs = input_specs(self.cfg, self.shape)
        out: Dict[str, jax.Array] = {}
        for name, s in specs.items():
            key, sub = jax.random.split(key)
            if name == "tokens":
                out[name] = self._markov_tokens(sub, s.shape)
            elif jnp.issubdtype(s.dtype, jnp.integer):
                out[name] = jax.random.randint(sub, s.shape, 0, self.cfg.vocab, jnp.int32)
            else:
                out[name] = (jax.random.normal(sub, s.shape, jnp.float32) * 0.02).astype(s.dtype)
        return self.shard(out)

    def _markov_tokens(self, key: jax.Array, shape) -> jax.Array:
        b, seq = shape
        succ = jnp.asarray(self._succ)
        k0, k1 = jax.random.split(key)
        start = jax.random.randint(k0, (b,), 0, self._active_vocab, jnp.int32)
        choices = jax.random.randint(k1, (b, seq), 0, self.branching, jnp.int32)

        def step(state, choice):
            nxt = succ[state, choice]
            return nxt, nxt

        _, toks = jax.lax.scan(step, start, choices.T)
        toks = jnp.concatenate([start[None], toks[:-1]], axis=0).T  # [B, L]
        return toks.astype(jnp.int32)

    def shard(self, batch: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Place every batch field row-sharded over the data axes (the RDD
        layout); this is where the ingest/compute bridge begins."""
        entry = self.rules.batch if len(self.rules.batch) != 1 else self.rules.batch[0]
        out = {}
        for name, x in batch.items():
            spec = divisible_spec(tuple(x.shape), P(*([entry] + [None] * (x.ndim - 1))), self.mesh)
            out[name] = jax.device_put(x, NamedSharding(self.mesh, spec))
        return out

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
