"""Train-step construction: grad + clip + AdamW update, with optional
microbatch gradient accumulation, under the model's partition specs."""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamW, OptState


def make_train_step(
    model,
    optimizer: AdamW,
    *,
    microbatches: int = 1,
) -> Callable:
    """Returns ``train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)``. With ``microbatches > 1`` the batch is
    split on axis 0 and gradients accumulate in f32 across a lax loop
    (activation memory / step-time tradeoff in the §Perf loop)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def single_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def accum_grads(params, batch):
        def slice_mb(x, i):
            mb = x.shape[0] // microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            acc, metrics_acc = carry
            mb = jax.tree_util.tree_map(lambda x: slice_mb(x, i), batch)
            g, m = single_grads(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), acc, g
            )
            metrics_acc = jax.tree_util.tree_map(lambda a, b: a + b, metrics_acc, m)
            return (acc, metrics_acc), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        m0 = jax.eval_shape(lambda: single_grads(params, jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(
                x, 0, x.shape[0] // microbatches, axis=0
            ), batch))[1])
        metrics0 = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
        (grads, metrics), _ = jax.lax.scan(
            body, (zeros, metrics0), jnp.arange(microbatches)
        )
        inv = 1.0 / microbatches
        grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        metrics = jax.tree_util.tree_map(lambda m: m * inv, metrics)
        return grads, metrics

    def train_step(params, opt_state: OptState, batch: Dict[str, jax.Array]):
        if microbatches > 1:
            grads, metrics = accum_grads(params, batch)
        else:
            grads, metrics = single_grads(params, batch)
        params, opt_state, opt_metrics = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step
