"""Learning-rate schedules (step -> lr callables, jit-safe)."""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp


def constant(lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_warmup(
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    *,
    final_fraction: float = 0.1,
) -> Callable[[jax.Array], jax.Array]:
    def sched(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup_steps, 1)
        prog = jnp.clip(
            (s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak_lr * (
            final_fraction + (1 - final_fraction) * 0.5 * (1 + jnp.cos(math.pi * prog))
        )
        return jnp.where(s < warmup_steps, warm, cos)

    return sched


def linear_warmup(peak_lr: float, warmup_steps: int) -> Callable[[jax.Array], jax.Array]:
    def sched(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        return peak_lr * jnp.minimum(s / max(warmup_steps, 1), 1.0)

    return sched
