"""PCA — the paper's motivating application (§4.2: "The computational
primitive underlying PCA is the SVD").

Column-centers A and runs the engine's truncated SVD; returns principal
components, scores and explained variance. Centering is done lazily via a
rank-one correction when ``center='implicit'`` so the (possibly huge) matrix
is never rewritten — the engine's AlMatrix stays untouched.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.linalg.svd import randomized_svd, truncated_svd


@functools.partial(
    jax.jit, static_argnames=("k", "method", "mesh", "oversample", "seed")
)
def pca(
    a: jax.Array,
    k: int,
    *,
    method: str = "lanczos",
    mesh: Optional[Mesh] = None,
    oversample: int = 10,
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k PCA of row-sample matrix A [n_samples, n_features].

    Returns (components [n_features, k], scores [n_samples, k],
    explained_variance [k]).
    """
    mean = jnp.mean(a, axis=0, keepdims=True)
    a_c = (a - mean).astype(a.dtype)
    if method == "lanczos":
        u, s, v = truncated_svd(a_c, k, oversample=oversample, mesh=mesh, seed=seed)
    elif method == "randomized":
        u, s, v = randomized_svd(a_c, k, oversample=oversample, mesh=mesh, seed=seed)
    else:
        raise ValueError(f"unknown PCA method {method!r}")
    n = a.shape[0]
    explained = (s.astype(jnp.float32) ** 2) / jnp.float32(max(n - 1, 1))
    scores = u * s[None, :]
    return v, scores, explained.astype(a.dtype)
