"""Communication-avoiding tall-skinny QR (TSQR).

The workhorse for orthogonalization of tall-skinny blocks (randomized SVD's
range finder, Lanczos restarts). Rows are sharded 1D over all mesh axes
(the ROW layout); each device QRs its slab, the small R factors are combined
in a single gather (or a binary tree for large device counts), and the local
Q factors are corrected.

Cost: one all-gather of [n x n] factors — independent of m. This is the
TPU analogue of the MPI TSQR in communication-avoiding linear algebra, and
is exactly the kind of routine the paper offloads.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.core.layouts import ROW

# jax >= 0.5 exposes shard_map at top level (replication check kw: check_vma);
# 0.4.x has it under experimental (kw: check_rep).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _NOCHECK_KW = {"check_vma": False}
else:  # pragma: no cover - exercised on jax 0.4.x only
    from jax.experimental.shard_map import shard_map as _shard_map

    _NOCHECK_KW = {"check_rep": False}


def _all_axes(mesh: Mesh):
    return tuple(mesh.axis_names)


def _num_devices(mesh: Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


def tsqr(a: jax.Array, mesh: Mesh, *, tree: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Reduced QR of a tall-skinny ROW-layout matrix.

    Returns (Q [m, n] ROW layout, R [n, n] replicated). Requires m >= n per
    device slab after padding (tall-skinny contract).
    """
    m, n = a.shape
    p = _num_devices(mesh)
    axes = _all_axes(mesh)

    pad = (-m) % p
    a_p = jnp.pad(a, ((0, pad), (0, 0))) if pad else a
    if a_p.shape[0] // p < n:
        # Not enough rows per shard to be "tall" — fall back to replicated QR.
        q, r = jnp.linalg.qr(a_p, mode="reduced")
        return q[:m], r

    spec = ROW.partition_spec(mesh)
    a_p = jax.lax.with_sharding_constraint(a_p, NamedSharding(mesh, spec))

    def local(a_loc: jax.Array) -> Tuple[jax.Array, jax.Array]:
        q1, r1 = jnp.linalg.qr(a_loc, mode="reduced")  # [m/p, n], [n, n]
        if p == 1:
            return q1, r1
        if tree:
            q_corr, r_final = _tree_combine(r1, axes, p)
        else:
            # one-shot: gather all R factors, QR the [p*n, n] stack everywhere
            rs = jax.lax.all_gather(r1, axes, axis=0, tiled=True)  # [p*n, n]
            q2, r_final = jnp.linalg.qr(rs, mode="reduced")        # [p*n, n]
            rank = _flat_rank(axes)
            q_corr = jax.lax.dynamic_slice_in_dim(q2, rank * n, n, axis=0)
        q = q1 @ q_corr
        # Sign-fix: make R's diagonal non-negative for determinism.
        sign = jnp.sign(jnp.where(jnp.diag(r_final) == 0, 1.0, jnp.diag(r_final)))
        return q * sign[None, :], r_final * sign[:, None]

    def _flat_rank(axis_names):
        # Axis sizes come from the (statically known) mesh: jax 0.4.x has no
        # jax.lax.axis_size, and the sizes are compile-time constants anyway.
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        rank = jax.lax.axis_index(axis_names[0])
        for ax in axis_names[1:]:
            rank = rank * sizes[ax] + jax.lax.axis_index(ax)
        return rank

    def _tree_combine(r1, axis_names, nproc):
        """Binary-tree R combination via ppermute (log2 p rounds)."""
        if nproc & (nproc - 1):
            raise ValueError(f"tree TSQR needs a power-of-two device count, got {nproc}")
        rank = _flat_rank(axis_names)
        q_corr = jnp.eye(r1.shape[0], dtype=r1.dtype)
        r_cur = r1
        step = 1
        while step < nproc:
            # partner exchange: lower of each pair stacks [r_self; r_partner]
            perm_down = [(i, i ^ step) for i in range(nproc)]
            r_other = _ppermute_all(r_cur, axis_names, perm_down)
            is_low = (rank & step) == 0
            # stack in a fixed order: low rank's R on top
            r_top = jnp.where(is_low, r_cur, r_other)
            r_bot = jnp.where(is_low, r_other, r_cur)
            q2, r_new = jnp.linalg.qr(jnp.concatenate([r_top, r_bot], axis=0), mode="reduced")
            n_ = r1.shape[0]
            block = jnp.where(is_low, q2[:n_], q2[n_:])
            q_corr = q_corr @ block
            r_cur = r_new
            step *= 2
        return q_corr, r_cur

    def _ppermute_all(x, axis_names, perm):
        # ppermute over the flattened axes: express as a single permutation
        # over the lexicographic rank by permuting each axis jointly.
        return jax.lax.ppermute(x, axis_names, perm)

    q, r_rep = _shard_map(
        lambda a_loc: local(a_loc),
        mesh=mesh,
        in_specs=(spec,),
        out_specs=(spec, jax.sharding.PartitionSpec(None, None)),
        # R is replicated by construction (gathered QR)
        **_NOCHECK_KW,
    )(a_p)
    return q[:m], r_rep
