"""Truncated SVD — the paper's flagship offloaded computation (§4.2).

Two algorithms, both engine routines:

- :func:`truncated_svd` — Lanczos (GKL) based, the paper-faithful ARPACK
  analogue (re-exported from :mod:`repro.linalg.lanczos`).
- :func:`randomized_svd` — Halko–Martinsson–Tropp randomized range finder +
  TSQR orthogonalization. The paper doesn't use it; it is the beyond-paper
  alternative: one (or q+1) passes over A instead of ~2(k+p) matvec passes,
  trading FLOPs for far less synchronization — exactly the overhead the
  paper blames Spark for.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import sharding as shardcore
from repro.core.layouts import GRID
from repro.linalg.lanczos import truncated_svd_lanczos
from repro.linalg.tsqr import tsqr


@functools.partial(jax.jit, static_argnames=("k", "oversample", "mesh", "seed"))
def truncated_svd(
    a: jax.Array,
    k: int,
    *,
    oversample: int = 10,
    mesh: Optional[Mesh] = None,
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-k SVD via Lanczos bidiagonalization (paper-faithful path)."""
    return truncated_svd_lanczos(a, k, oversample=oversample, mesh=mesh, seed=seed)


@functools.partial(
    jax.jit, static_argnames=("k", "oversample", "power_iters", "mesh", "seed")
)
def randomized_svd(
    a: jax.Array,
    k: int,
    *,
    oversample: int = 10,
    power_iters: int = 1,
    mesh: Optional[Mesh] = None,
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-k randomized SVD (beyond-paper engine routine).

    Y = A Ω; q rounds of power iteration with TSQR re-orthogonalization;
    B = QᵀA small; SVD(B) replicated. Synchronization: O(q) TSQRs instead of
    O(k) sequential matvec round-trips.
    """
    m, n = a.shape
    L = min(k + oversample, min(m, n))
    a32 = a.astype(jnp.float32)
    if mesh is not None:
        a32 = shardcore.constrain(a32, GRID.partition_spec(mesh), mesh)

    key = jax.random.PRNGKey(seed)
    omega = jax.random.normal(key, (n, L), jnp.float32)
    y = a32 @ omega  # [m, L]

    if mesh is not None:
        q, _ = tsqr(y, mesh)
        for _ in range(power_iters):
            z = a32.T @ q          # [n, L]
            qz, _ = tsqr(z, mesh)
            y = a32 @ qz
            q, _ = tsqr(y, mesh)
    else:
        q, _ = jnp.linalg.qr(y, mode="reduced")
        for _ in range(power_iters):
            z = a32.T @ q
            qz, _ = jnp.linalg.qr(z, mode="reduced")
            q, _ = jnp.linalg.qr(a32 @ qz, mode="reduced")

    b = q.T @ a32                      # [L, n] small
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    u = q @ ub[:, :k]
    return u.astype(a.dtype), s[:k].astype(a.dtype), vt[:k].T.astype(a.dtype)


def svd_reconstruction_error(
    a: jax.Array, u: jax.Array, s: jax.Array, v: jax.Array
) -> jax.Array:
    """Relative Frobenius error ||A - U diag(s) Vᵀ||_F / ||A||_F."""
    recon = (u * s[None, :]) @ v.T
    return jnp.linalg.norm(a - recon) / jnp.linalg.norm(a)
