"""Library wrappers — the paper's §3.4 sugar layer.

Paper: "library wrappers ... give the user a simple API ... one can easily
mimic the API used by, for instance, MLlib. This way, one would have to only
make minimal changes to existing code when switching from MLlib ... to an
MPI-based library called through Alchemist."

The Scala listing defines per-routine objects (``CondEst(alA)``); here a
:class:`LibraryWrapper` binds a client session + library name once and
exposes each routine as a method, so application code reads like a local
math library:

    from repro.linalg.wrappers import Elemental

    el = Elemental(ac)          # registers the ALI if needed
    cond = el.condest(al_a)
    u, s, v = el.truncated_svd(al_a, k=20)

Since DESIGN.md §9 every namespace dispatches through one
:class:`~repro.core.policy.ExecutionPolicy` object — the same objects the v2
``Session`` takes — instead of per-kind closures:

- direct methods (``el.gemm``)      → :class:`~repro.core.policy.Eager`
- ``el.submit.gemm`` (AlFuture)     → :class:`~repro.core.policy.Pipelined`
- ``el.lazy.gemm``   (LazyMatrix)   → :class:`~repro.core.policy.Planned`
  (takes ``n_outputs`` for multi-output routines)

so call chains pipeline (futures feed further routines or ``ac.collect``)
and lazy chains elide the bridge entirely, exactly as before — the wrapper
is now just sugar over the policy layer.
"""

from __future__ import annotations

from typing import Any

from repro.core.client import ClientCore
from repro.core.policy import Eager, ExecutionPolicy, Pipelined, Planned


class _RoutineNamespace:
    """Routine namespace dispatching through one execution policy.

    One generic call path for every kind (DESIGN.md §9): the bound policy
    object decides eager-blocking, future, or deferred-DAG execution, and
    the namespace only validates the routine name.
    """

    def __init__(self, wrapper: "LibraryWrapper", policy: ExecutionPolicy):
        self._wrapper = wrapper
        self._policy = policy

    def __getattr__(self, name: str):
        w = self._wrapper
        if name.startswith("_") or name not in w._routines:
            raise AttributeError(
                f"{type(w).__name__}.{self._policy.name} has no routine {name!r}; "
                f"available: {w._routines}"
            )

        def call(*args: Any, n_outputs: int = 1, **kwargs: Any) -> Any:
            return self._policy.dispatch(
                w._ac, w.library_name, name, args, kwargs, n_outputs=n_outputs
            )

        call.__name__ = name
        return call

    def __dir__(self):
        return sorted(set(super().__dir__()) | set(self._wrapper._routines))


class LibraryWrapper:
    """Binds (client, library) and exposes routines as methods."""

    library_name: str = ""
    library_path: str = ""

    def __init__(self, ac: ClientCore):
        self._ac = ac
        if self.library_name not in ac.session.libraries:
            ac.register_library(self.library_name, self.library_path)
        self._routines = ac.library(self.library_name).routine_names()
        self._eager = _RoutineNamespace(self, Eager())
        self.submit = _RoutineNamespace(self, Pipelined())
        self.lazy = _RoutineNamespace(self, Planned())

    def __getattr__(self, name: str):
        # Direct methods are the eager namespace: same policy-routed call
        # path as .submit/.lazy, blocking semantics.
        if name.startswith("_") or name not in self._routines:
            raise AttributeError(
                f"{type(self).__name__} has no routine {name!r}; "
                f"available: {self._routines}"
            )
        return getattr(self._eager, name)

    def __dir__(self):
        return sorted(set(super().__dir__()) | set(self._routines))


class Elemental(LibraryWrapper):
    """The built-in distributed-linalg library, MLlib-style."""

    library_name = "elemental"
    library_path = "repro.linalg.library:ElementalLib"
