"""Library wrappers — the paper's §3.4 sugar layer.

Paper: "library wrappers ... give the user a simple API ... one can easily
mimic the API used by, for instance, MLlib. This way, one would have to only
make minimal changes to existing code when switching from MLlib ... to an
MPI-based library called through Alchemist."

The Scala listing defines per-routine objects (``CondEst(alA)``); here a
:class:`LibraryWrapper` binds an AlchemistContext + library name once and
exposes each routine as a method, so application code reads like a local
math library:

    from repro.linalg.wrappers import Elemental

    el = Elemental(ac)          # registers the ALI if needed
    cond = el.condest(al_a)
    u, s, v = el.truncated_svd(al_a, k=20)

Every wrapper also carries an asynchronous view over the task-queue engine
(DESIGN.md §3): ``el.submit`` exposes the same routines but returns
:class:`~repro.core.futures.AlFuture` immediately, so call chains pipeline —
futures feed straight into further routines or into ``ac.collect``:

    f = el.submit.gemm(al_a, al_b)      # returns at once
    g = el.submit.gemm(f, al_b)         # chains on the unresolved future
    C = ac.collect(g)                   # materializes when ready

and a lazy view over the offload planner (DESIGN.md §6): ``el.lazy`` builds
deferred-op DAG nodes instead of executing, so chained calls elide the
bridge entirely and host-array arguments dedup against the session's
resident-matrix cache; multi-output routines take ``n_outputs``:

    u, s, v = el.lazy.truncated_svd(a, n_outputs=3, k=20)   # a: host ndarray
    p = el.lazy.gemm(a, u)              # a deduped, u never collected
    P = p.collect()                     # the one bridge crossing
"""

from __future__ import annotations

from typing import Any

from repro.core.engine import AlchemistContext
from repro.core.futures import AlFuture


class _RoutineNamespace:
    """Routine namespace dispatching through an alternate execution path.

    ``el.submit`` routes through ``run_async`` (futures), ``el.lazy`` through
    the offload planner (deferred-op DAG nodes, taking ``n_outputs``).
    """

    def __init__(self, wrapper: "LibraryWrapper", kind: str):
        self._wrapper = wrapper
        self._kind = kind

    def __getattr__(self, name: str):
        w = self._wrapper
        if name.startswith("_") or name not in w._routines:
            raise AttributeError(
                f"{type(w).__name__}.{self._kind} has no routine {name!r}; "
                f"available: {w._routines}"
            )

        if self._kind == "submit":
            def call(*args: Any, **kwargs: Any) -> AlFuture:
                return w._ac.run_async(w.library_name, name, *args, **kwargs)
        else:
            def call(*args: Any, n_outputs: int = 1, **kwargs: Any):
                return w._ac.planner.run(
                    w.library_name, name, *args, n_outputs=n_outputs, **kwargs
                )

        call.__name__ = name
        return call

    def __dir__(self):
        return sorted(set(super().__dir__()) | set(self._wrapper._routines))


class LibraryWrapper:
    """Binds (context, library) and exposes routines as methods."""

    library_name: str = ""
    library_path: str = ""

    def __init__(self, ac: AlchemistContext):
        self._ac = ac
        if self.library_name not in ac.session.libraries:
            ac.register_library(self.library_name, self.library_path)
        self._routines = ac.library(self.library_name).routine_names()
        self.submit = _RoutineNamespace(self, "submit")
        self.lazy = _RoutineNamespace(self, "lazy")

    def __getattr__(self, name: str):
        if name.startswith("_") or name not in self._routines:
            raise AttributeError(
                f"{type(self).__name__} has no routine {name!r}; "
                f"available: {self._routines}"
            )

        def call(*args: Any, **kwargs: Any):
            return self._ac.run(self.library_name, name, *args, **kwargs)

        call.__name__ = name
        return call

    def __dir__(self):
        return sorted(set(super().__dir__()) | set(self._routines))


class Elemental(LibraryWrapper):
    """The built-in distributed-linalg library, MLlib-style."""

    library_name = "elemental"
    library_path = "repro.linalg.library:ElementalLib"
