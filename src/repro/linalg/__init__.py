"""linalg — the "MPI-based library" the engine offloads to.

This package plays the role of Elemental + the authors' ARPACK-based
truncated-SVD code (paper §2.2, §4.2): distributed dense linear algebra on
the engine's 2D grid layout, implemented with shard_map/pjit + jax.lax
collectives, with the local GEMM hot spot backed by the Pallas tiled-matmul
kernel.

- ``gemm.py``    — distributed matmul: SUMMA (panel-streamed), all-gather
                   variant, and XLA-native einsum variant
- ``tsqr.py``    — communication-avoiding tall-skinny QR
- ``lanczos.py`` — Golub–Kahan–Lanczos bidiagonalization (ARPACK analogue)
- ``svd.py``     — truncated SVD (Lanczos) + randomized SVD
- ``pca.py``     — PCA on top of truncated SVD
- ``solvers.py`` — CG, ridge, power-iteration norm/cond estimation
- ``library.py`` — ``ElementalLib``: the ALI wrapper exposing all of the
                   above to the engine by routine name
"""

from repro.linalg import gemm, lanczos, pca, solvers, svd, tsqr

__all__ = ["gemm", "tsqr", "lanczos", "svd", "pca", "solvers"]
