"""Iterative solvers and norm/condition estimators.

The paper's §3.3 running example is a hypothetical ``condest`` routine in a
wrapped MPI library; we implement it for real (power iteration for σ_max,
CG-based inverse power iteration for σ_min), plus the CG/ridge solvers that
make the engine useful as an ML substrate.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import sharding as shardcore
from repro.core.layouts import GRID


def _constrain(a: jax.Array, mesh: Optional[Mesh]) -> jax.Array:
    if mesh is None:
        return a
    return shardcore.constrain(a, GRID.partition_spec(mesh), mesh)


@functools.partial(jax.jit, static_argnames=("num_iters", "mesh", "seed"))
def power_iteration(
    a: jax.Array,
    *,
    num_iters: int = 50,
    mesh: Optional[Mesh] = None,
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Largest singular value/right-vector of A via power iteration on AᵀA."""
    a32 = _constrain(a.astype(jnp.float32), mesh)
    n = a.shape[1]
    v = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)
    v = v / jnp.linalg.norm(v)

    def step(v, _):
        w = a32.T @ (a32 @ v)
        nw = jnp.linalg.norm(w)
        return w / jnp.where(nw > 0, nw, 1.0), nw

    v, norms = jax.lax.scan(step, v, None, length=num_iters)
    sigma = jnp.sqrt(norms[-1])
    return sigma.astype(a.dtype), v.astype(a.dtype)


def cg(
    matvec,
    b: jax.Array,
    *,
    num_iters: int = 64,
    tol: float = 1e-8,
) -> jax.Array:
    """Conjugate gradients for SPD ``matvec`` (fixed iteration count, jittable)."""
    x0 = jnp.zeros_like(b)

    def step(carry, _):
        x, r, p, rs = carry
        ap = matvec(p)
        denom = jnp.vdot(p, ap)
        alpha = jnp.where(jnp.abs(denom) > 1e-30, rs / denom, 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        beta = jnp.where(rs > 1e-30, rs_new / rs, 0.0)
        p = r + beta * p
        return (x, r, p, rs_new), jnp.sqrt(rs_new.real)

    r0 = b - matvec(x0)
    (x, _, _, _), _ = jax.lax.scan(
        step, (x0, r0, r0, jnp.vdot(r0, r0)), None, length=num_iters
    )
    return x


@functools.partial(jax.jit, static_argnames=("num_iters", "mesh"))
def ridge(
    a: jax.Array,
    b: jax.Array,
    lam: float,
    *,
    num_iters: int = 64,
    mesh: Optional[Mesh] = None,
) -> jax.Array:
    """Solve (AᵀA + λI) x = Aᵀ b by CG — distributed normal equations."""
    a32 = _constrain(a.astype(jnp.float32), mesh)
    rhs = a32.T @ b.astype(jnp.float32)

    def mv(x):
        return a32.T @ (a32 @ x) + jnp.float32(lam) * x

    return cg(mv, rhs, num_iters=num_iters).astype(a.dtype)


@functools.partial(
    jax.jit, static_argnames=("num_iters", "cg_iters", "mesh", "seed")
)
def condest(
    a: jax.Array,
    *,
    num_iters: int = 50,
    cg_iters: int = 128,
    mesh: Optional[Mesh] = None,
    seed: int = 0,
) -> jax.Array:
    """Estimate cond_2(A) = σ_max / σ_min (the paper's §3.3 example routine).

    σ_max by power iteration; σ_min by inverse power iteration on AᵀA, with
    the inverse applied by CG.
    """
    a32 = _constrain(a.astype(jnp.float32), mesh)
    sigma_max, _ = power_iteration(a32, num_iters=num_iters, mesh=None, seed=seed)
    n = a.shape[1]
    v = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,), jnp.float32)
    v = v / jnp.linalg.norm(v)

    def gram(x):
        return a32.T @ (a32 @ x)

    def inv_step(v, _):
        w = cg(gram, v, num_iters=cg_iters)
        nw = jnp.linalg.norm(w)
        return w / jnp.where(nw > 0, nw, 1.0), nw

    v, norms = jax.lax.scan(inv_step, v, None, length=max(num_iters // 5, 5))
    sigma_min = jnp.sqrt(1.0 / jnp.maximum(norms[-1], 1e-30))
    return (sigma_max.astype(jnp.float32) / sigma_min).astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("mesh",))
def frobenius_norm(a: jax.Array, *, mesh: Optional[Mesh] = None) -> jax.Array:
    a32 = _constrain(a.astype(jnp.float32), mesh)
    return jnp.sqrt(jnp.sum(a32 * a32)).astype(a.dtype)
