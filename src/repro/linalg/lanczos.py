"""Golub–Kahan–Lanczos bidiagonalization — the ARPACK analogue.

The paper's truncated SVD (§4.2) is "our own MPI-based implementation of the
truncated SVD using ARPACK and Elemental": ARPACK runs the (implicitly
restarted) Lanczos iteration, Elemental supplies the distributed matvec.

Here the same split: this module runs Golub–Kahan–Lanczos with full
reorthogonalization as a ``lax.scan`` (jit-friendly, fixed iteration count =
k + oversampling, the practical equivalent of ARPACK's Krylov subspace
dimension ``ncv``), while the distributed matvecs ``A v`` / ``Aᵀ u`` run
under GRID sharding constraints so XLA partitions them across the worker
grid. The small bidiagonal SVD happens replicated ("on the driver").

bf16 note (DESIGN.md §2): Krylov vectors and reorthogonalization run f32 —
bf16 Gram updates destroy orthogonality within a few iterations.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import sharding as shardcore
from repro.core.layouts import GRID


class BidiagState(NamedTuple):
    u: jax.Array       # [m] current left vector
    v: jax.Array       # [n] current right vector
    alpha: jax.Array   # [] current diagonal entry
    beta: jax.Array    # [] current superdiagonal entry
    us: jax.Array      # [L, m] left Krylov basis
    vs: jax.Array      # [L, n] right Krylov basis


def _reorth(x: jax.Array, basis: jax.Array, valid: jax.Array) -> jax.Array:
    """Two-pass classical Gram–Schmidt against rows of ``basis`` (masked)."""
    for _ in range(2):
        coeff = (basis @ x) * valid          # [L]
        x = x - basis.T @ coeff
    return x


def bidiagonalize(
    a: jax.Array,
    num_iters: int,
    *,
    mesh: Optional[Mesh] = None,
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Run ``num_iters`` GKL steps on A [m, n].

    Returns (U [L, m], V [L, n], alphas [L], betas [L]) with
    A ≈ Uᵀ B V where B = bidiag(alphas, betas[1:]).
    """
    m, n = a.shape
    L = num_iters
    a32 = a.astype(jnp.float32)
    if mesh is not None:
        a32 = shardcore.constrain(a32, GRID.partition_spec(mesh), mesh)

    key = jax.random.PRNGKey(seed)
    v0 = jax.random.normal(key, (n,), jnp.float32)
    v0 = v0 / jnp.linalg.norm(v0)

    def step(carry, i):
        v, u_prev, beta_prev, us, vs = carry
        # u_i = A v_i - beta_i u_{i-1}
        u = a32 @ v - beta_prev * u_prev
        valid_u = (jnp.arange(L) < i).astype(jnp.float32)
        u = _reorth(u, us, valid_u)
        alpha = jnp.linalg.norm(u)
        u = u / jnp.where(alpha > 1e-12, alpha, 1.0)

        # v_{i+1} = Aᵀ u_i - alpha_i v_i
        w = a32.T @ u - alpha * v
        vs_i = vs.at[i].set(v)
        valid_v = (jnp.arange(L) <= i).astype(jnp.float32)
        w = _reorth(w, vs_i, valid_v)
        beta = jnp.linalg.norm(w)
        v_next = w / jnp.where(beta > 1e-12, beta, 1.0)

        us_i = us.at[i].set(u)
        return (v_next, u, beta, us_i, vs_i), (alpha, beta)

    us0 = jnp.zeros((L, m), jnp.float32)
    vs0 = jnp.zeros((L, n), jnp.float32)
    carry0 = (v0, jnp.zeros((m,), jnp.float32), jnp.float32(0.0), us0, vs0)
    (v_last, u_last, beta_last, us, vs), (alphas, betas) = jax.lax.scan(
        step, carry0, jnp.arange(L)
    )
    return us, vs, alphas, betas


def truncated_svd_lanczos(
    a: jax.Array,
    k: int,
    *,
    oversample: int = 10,
    mesh: Optional[Mesh] = None,
    seed: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-k truncated SVD via GKL bidiagonalization.

    Returns (U [m, k], s [k], V [n, k]). ``k + oversample`` plays ARPACK's
    ``ncv`` role; the bidiagonal system is solved replicated, mirroring
    ARPACK-on-the-driver in MLlib/the paper's MPI code.
    """
    m, n = a.shape
    L = min(k + oversample, min(m, n))
    us, vs, alphas, betas = bidiagonalize(a, L, mesh=mesh, seed=seed)

    # GKL recurrence as implemented above:
    #   u_i = (A v_i - beta_{i-1} u_{i-1}) / alpha_i
    #     =>  A v_i  = alpha_i u_i + beta_{i-1} u_{i-1}
    #   v_{i+1} = (Aᵀ u_i - alpha_i v_i) / beta_i
    #     =>  Aᵀ u_i = alpha_i v_i + beta_i v_{i+1}
    # so A V = U B with upper-bidiagonal B: B[i,i] = alpha_i,
    # B[j,j+1] = beta_j.
    b_small = jnp.diag(alphas) + jnp.diag(betas[:-1], k=1)

    ub, s, vbt = jnp.linalg.svd(b_small, full_matrices=False)
    u_out = us.T @ ub[:, :k]          # [m, k]
    v_out = vs.T @ vbt.T[:, :k]       # [n, k]
    return u_out.astype(a.dtype), s[:k].astype(a.dtype), v_out.astype(a.dtype)
