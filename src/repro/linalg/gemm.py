"""Distributed GEMM on the engine's 2D grid — the paper's §4.1 workload.

Three schedules, all computing C[m,k] = A[m,n] @ B[n,k] with every operand
in GRID layout (rows over the data axes, cols over 'model'):

- :func:`summa`          — faithful SUMMA: the n-dimension is streamed in
  panels; each panel's A-column-block is broadcast along mesh rows and
  B-row-block along mesh columns, local GEMMs accumulate into stationary C.
  This is Elemental's schedule, and the paper-faithful baseline.
- :func:`gemm_allgather` — one-shot variant: all-gather A along 'model' and
  B along 'data', then a single local GEMM. Fewer, larger messages; higher
  peak memory (the panel/streaming tradeoff the perf loop explores).
- :func:`gemm_xla`       — ``jnp.matmul`` under sharding constraints: lets
  XLA's SPMD partitioner choose the schedule (the beyond-paper comparison).

All local GEMMs go through :func:`repro.kernels.ops.matmul` (Pallas on TPU).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.core.layouts import AXIS_DATA, AXIS_MODEL, AXIS_POD, GRID
from repro.core import sharding as shardcore
from repro.kernels import ops

# jax >= 0.5 exposes shard_map / lax.pvary at top level; 0.4.x has shard_map
# under experimental and no pvary (replication tracking arrived later, so the
# identity is a sound stand-in there).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - exercised on jax 0.4.x only
    from jax.experimental.shard_map import shard_map as _shard_map

_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def _row_axes(mesh: Mesh):
    return tuple(a for a in (AXIS_POD, AXIS_DATA) if a in mesh.axis_names)


def _grid_dims(mesh: Mesh):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    r = 1
    for a in _row_axes(mesh):
        r *= sizes[a]
    c = sizes.get(AXIS_MODEL, 1)
    return r, c


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


def _pad_cols(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[1]) % mult
    return jnp.pad(x, ((0, 0), (0, pad))) if pad else x


def summa(
    a: jax.Array,
    b: jax.Array,
    mesh: Mesh,
    *,
    panels: Optional[int] = None,
) -> jax.Array:
    """SUMMA C = A @ B, operands and result in GRID layout on ``mesh``.

    ``panels``: number of panels the contraction dimension is streamed in
    (defaults to lcm(grid rows, grid cols) — the coarsest exact panelling).
    Peak per-device memory beyond operands is one A-panel + one B-panel.
    """
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    r, c = _grid_dims(mesh)
    m, n = a.shape
    _, k = b.shape
    row_axes = _row_axes(mesh)

    if r == 1 and c == 1:
        return ops.matmul(a, b)

    # Panel count must be a multiple of lcm(r, c) so panels never straddle
    # shard boundaries; pad n to a multiple of n_panels (zero padding is
    # exact for GEMM), m to r, k to c.
    lcm_rc = math.lcm(r, c)
    n_panels = lcm_rc * max(1, -(-(panels or lcm_rc) // lcm_rc))
    a_p = _pad_cols(_pad_rows(a, r), n_panels)
    b_p = _pad_cols(_pad_rows(b, n_panels), c)
    np_ = a_p.shape[1]
    panel = np_ // n_panels
    loc_a_cols = np_ // c  # A's local column count
    loc_b_rows = np_ // r  # B's local row count

    grid_spec = GRID.partition_spec(mesh)
    a_p = jax.lax.with_sharding_constraint(a_p, NamedSharding(mesh, grid_spec))
    b_p = jax.lax.with_sharding_constraint(b_p, NamedSharding(mesh, grid_spec))

    row_entry = row_axes if len(row_axes) > 1 else row_axes[0]

    def local(a_loc: jax.Array, b_loc: jax.Array) -> jax.Array:
        # a_loc: [m/r, n/c]; b_loc: [n/r, k/c]
        row_rank = jax.lax.axis_index(row_axes[0])
        for ax in row_axes[1:]:
            row_rank = row_rank * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
        col_rank = jax.lax.axis_index(AXIS_MODEL) if AXIS_MODEL in mesh.axis_names else 0

        m_loc = a_loc.shape[0]
        k_loc = b_loc.shape[1]

        def body(t, acc):
            # global panel t occupies columns [t*panel, (t+1)*panel) of A —
            # owned by mesh column `oc`; and rows of B owned by mesh row `orow`.
            start = t * panel
            oc = start // loc_a_cols
            off_a = start - oc * loc_a_cols
            a_slice = jax.lax.dynamic_slice_in_dim(a_loc, off_a, panel, axis=1)
            a_panel = jax.lax.psum(
                jnp.where(col_rank == oc, a_slice, jnp.zeros_like(a_slice)),
                AXIS_MODEL,
            ) if AXIS_MODEL in mesh.axis_names else a_slice

            orow = start // loc_b_rows
            off_b = start - orow * loc_b_rows
            b_slice = jax.lax.dynamic_slice_in_dim(b_loc, off_b, panel, axis=0)
            b_panel = jax.lax.psum(
                jnp.where(row_rank == orow, b_slice, jnp.zeros_like(b_slice)),
                row_axes,
            )
            return acc + ops.matmul(a_panel, b_panel, out_dtype=jnp.float32)

        acc = jnp.zeros((m_loc, k_loc), jnp.float32)
        # mark the carry as device-varying so the fori_loop carry types match
        acc = _pvary(acc, tuple(mesh.axis_names))
        acc = jax.lax.fori_loop(0, n_panels, body, acc)
        return acc.astype(a_loc.dtype)

    c_p = _shard_map(
        local,
        mesh=mesh,
        in_specs=(grid_spec, grid_spec),
        out_specs=grid_spec,
    )(a_p, b_p)
    return c_p[:m, :k]


def gemm_allgather(a: jax.Array, b: jax.Array, mesh: Mesh) -> jax.Array:
    """All-gather-based GEMM: gather A along 'model', B along the row axes,
    one local GEMM. Minimal message count, maximal peak memory."""
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    r, c = _grid_dims(mesh)
    m, n = a.shape
    _, k = b.shape
    if r == 1 and c == 1:
        return ops.matmul(a, b)
    row_axes = _row_axes(mesh)
    # needs: r | m, c | k, lcm(r, c) | n (gathered dims line up exactly)
    lcm_rc = math.lcm(r, c)
    a_p = _pad_cols(_pad_rows(a, r), lcm_rc)
    b_p = _pad_cols(_pad_rows(b, lcm_rc), c)

    grid_spec = GRID.partition_spec(mesh)

    def local(a_loc: jax.Array, b_loc: jax.Array) -> jax.Array:
        a_row = a_loc
        if AXIS_MODEL in mesh.axis_names:
            a_row = jax.lax.all_gather(a_loc, AXIS_MODEL, axis=1, tiled=True)
        b_col = jax.lax.all_gather(b_loc, row_axes, axis=0, tiled=True)
        return ops.matmul(a_row, b_col)

    c_p = _shard_map(
        local, mesh=mesh, in_specs=(grid_spec, grid_spec), out_specs=grid_spec
    )(a_p, b_p)
    return c_p[:m, :k]


def gemm_xla(a: jax.Array, b: jax.Array, mesh: Mesh) -> jax.Array:
    """XLA-partitioned GEMM: constrain operands/result to GRID and let the
    SPMD partitioner pick the collective schedule."""
    spec = GRID.partition_spec(mesh)
    a = shardcore.constrain(a, spec, mesh)
    b = shardcore.constrain(b, spec, mesh)
    out = jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
    return shardcore.constrain(out, spec, mesh)


SCHEDULES = {
    "summa": summa,
    "allgather": gemm_allgather,
    "xla": gemm_xla,
}


@functools.partial(jax.jit, static_argnames=("mesh", "schedule"))
def multiply(a: jax.Array, b: jax.Array, mesh: Mesh, *, schedule: str = "summa") -> jax.Array:
    """Dispatch by schedule name (the engine routine entry point)."""
    try:
        fn = SCHEDULES[schedule]
    except KeyError:
        raise ValueError(
            f"unknown GEMM schedule {schedule!r}; known: {sorted(SCHEDULES)}"
        ) from None
    return fn(a, b, mesh)
