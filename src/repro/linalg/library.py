"""ElementalLib — the ALI wrapper exposing the linalg package to the engine.

This is the analogue of the paper's per-library shared object (§2.3, §3.5):
a thin adapter registering each routine by name. Spark-side code calls

    ac.register_library("elemental", "repro.linalg.library:ElementalLib")
    ac.run("elemental", "gemm", al_a, al_b)

and the engine resolves this class at registration time (the dlopen moment).

Routines receive distributed matrices as jax.Arrays already resident in the
session's GRID layout, scalar params from the Parameters codec, and — if
their signature asks for it — the session's worker-group ``mesh``.
"""

from __future__ import annotations


from repro.core.registry import Library
from repro.linalg import gemm as _gemm
from repro.linalg import pca as _pca
from repro.linalg import solvers as _solvers
from repro.linalg import svd as _svd
from repro.linalg import tsqr as _tsqr


class ElementalLib(Library):
    """Distributed dense linear algebra (Elemental + ARPACK analogue)."""

    name = "elemental"

    def __init__(self) -> None:
        super().__init__()
        self.register("gemm", self._gemm, doc="C = A @ B (SUMMA by default)")
        self.register("multiply", self._gemm, doc="alias of gemm")
        self.register("truncated_svd", self._truncated_svd,
                      doc="rank-k SVD via Lanczos/ARPACK-analogue")
        self.register("randomized_svd", self._randomized_svd,
                      doc="rank-k SVD via randomized range finder + TSQR")
        self.register("pca", self._pca, doc="top-k PCA (components, scores, var)")
        self.register("tsqr", self._tsqr, doc="tall-skinny QR: returns (Q, R)")
        self.register("condest", self._condest,
                      doc="2-norm condition estimate (the paper's §3.3 example)")
        self.register("ridge", self._ridge, doc="(AᵀA + λI)x = Aᵀb by CG")
        self.register("normest", self._normest, doc="Frobenius norm")
        self.register("sigma_max", self._sigma_max, doc="largest singular value")

    # Each adapter mirrors an ALI `run` branch: translate engine calling
    # convention -> library API.
    @staticmethod
    def _gemm(a, b, *, schedule: str = "summa", mesh=None):
        return _gemm.multiply(a, b, mesh, schedule=schedule)

    @staticmethod
    def _truncated_svd(a, *, k: int = 10, oversample: int = 10, seed: int = 0, mesh=None):
        u, s, v = _svd.truncated_svd(
            a, int(k), oversample=int(oversample), mesh=mesh, seed=int(seed)
        )
        return u, s, v

    @staticmethod
    def _randomized_svd(a, *, k: int = 10, oversample: int = 10, power_iters: int = 1,
                        seed: int = 0, mesh=None):
        u, s, v = _svd.randomized_svd(
            a, int(k), oversample=int(oversample), power_iters=int(power_iters),
            mesh=mesh, seed=int(seed))
        return u, s, v

    @staticmethod
    def _pca(a, *, k: int = 10, method: str = "lanczos", seed: int = 0, mesh=None):
        return _pca.pca(a, int(k), method=method, mesh=mesh, seed=int(seed))

    @staticmethod
    def _tsqr(a, *, tree: bool = False, mesh=None):
        return _tsqr.tsqr(a, mesh, tree=bool(tree))

    @staticmethod
    def _condest(a, *, num_iters: int = 50, mesh=None):
        return _solvers.condest(a, num_iters=int(num_iters), mesh=mesh)

    @staticmethod
    def _ridge(a, b, *, lam: float = 1e-3, num_iters: int = 64, mesh=None):
        # b arrives as an [n, 1] matrix through the bridge; return likewise.
        x = _solvers.ridge(a, b[:, 0], float(lam), num_iters=int(num_iters), mesh=mesh)
        return x[:, None]

    @staticmethod
    def _normest(a, *, mesh=None):
        return _solvers.frobenius_norm(a, mesh=mesh)

    @staticmethod
    def _sigma_max(a, *, num_iters: int = 50, mesh=None):
        s, _ = _solvers.power_iteration(a, num_iters=int(num_iters), mesh=mesh)
        return s
