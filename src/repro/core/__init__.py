"""core — the Alchemist engine: the paper's primary contribution, in JAX.

Pieces (paper terminology in brackets):

- ``engine.py``     — :class:`AlchemistEngine` (the Alchemist server: driver +
                      worker pool, admission-aware allocation, DESIGN.md §9).
- ``client.py``     — the v2 client surface: ``connect()`` →
                      :class:`Session` → :class:`AlArray`, over the
                      :class:`ClientCore` transport; the deprecated
                      :class:`AlchemistContext` shim (DESIGN.md §9).
- ``policy.py``     — :class:`ExecutionPolicy` (Eager / Pipelined / Planned):
                      when the DAG a session builds actually executes.
- ``session.py``    — per-client sessions with dedicated worker groups
                      [dedicated MPI communicator per connected application].
- ``handles.py``    — :class:`AlMatrix` matrix handles [AlMatrix proxies].
- ``layouts.py``    — layout descriptors: row-partitioned [Spark
                      IndexedRowMatrix], 2D grid [Elemental DistMatrix],
                      replicated; block-cyclic emulation.
- ``relayout.py``   — the bridge itself: resharding between layouts
                      [TCP socket transfer between executors and workers],
                      plus an analytic transfer-cost model [Tables 2–3].
- ``registry.py``   — dynamic library registry [ALI shared objects].
- ``params.py``     — typed scalar parameter packing [Parameters header].
- ``sharding.py``   — mesh-axis conventions shared by the whole framework.
- ``futures.py``    — :class:`AlFuture` deferred results (DESIGN.md §4).
- ``taskqueue.py``  — per-session FIFO workers (DESIGN.md §3).
- ``expr.py``       — deferred-op DAG + :class:`LazyMatrix` proxies
                      (DESIGN.md §6).
- ``planner.py``    — :class:`OffloadPlanner`: bridge-crossing elision,
                      resident-matrix dedup, CSE, async lowering
                      (DESIGN.md §6/§8).
- ``memgov.py``     — :class:`MemoryGovernor`: the engine-wide HBM budget —
                      spill/refill, admission claims (DESIGN.md §7-§8).
- ``resident.py``   — :class:`ResidentStore`: engine-level content-addressed
                      residency — refcounted cross-session placement and
                      migration-on-close (DESIGN.md §8).
- ``errors.py``     — structured error hierarchy.
"""

from repro.core.client import AlArray, AlchemistContext, ClientCore, Session, connect
from repro.core.engine import AlchemistEngine
from repro.core.expr import LazyMatrix, register_shape_rule
from repro.core.futures import AlFuture
from repro.core.handles import AlMatrix
from repro.core.layouts import GRID, REPLICATED, ROW, LayoutSpec
from repro.core.memgov import MemoryGovernor
from repro.core.planner import OffloadPlanner
from repro.core.policy import Eager, ExecutionPolicy, Pipelined, Planned
from repro.core.registry import Library, Routine
from repro.core.resident import ResidentStore
from repro.core.taskqueue import TaskQueue

__all__ = [
    "AlchemistEngine",
    "AlchemistContext",
    "AlArray",
    "AlFuture",
    "AlMatrix",
    "ClientCore",
    "connect",
    "Eager",
    "ExecutionPolicy",
    "LazyMatrix",
    "MemoryGovernor",
    "OffloadPlanner",
    "Pipelined",
    "Planned",
    "ResidentStore",
    "Session",
    "LayoutSpec",
    "ROW",
    "GRID",
    "REPLICATED",
    "Library",
    "Routine",
    "TaskQueue",
    "register_shape_rule",
]
