"""Deferred-expression DAG — the client side of the lazy offload planner.

DESIGN.md §6: the follow-up paper (arXiv:1805.11800) shows Alchemist's win
evaporating when an application naively collects every result back to Spark
between offloaded calls. The cure is structural: client-side operations build
a small DAG of deferred ops instead of executing eagerly, and the planner
(:mod:`repro.core.planner`) lowers the DAG onto the async task queue only
when a result is explicitly demanded. A value produced by one routine and
consumed by the next never crosses the bridge at all — it stays resident on
the session, exactly like the real Alchemist server's matrices that
"physically live on the MPI side".

Three node kinds:

- :class:`SendExpr`    — a host array that will become engine-resident; carries
  a content key so identical payloads dedup into one resident matrix.
- :class:`RunExpr`     — a deferred ``(library, routine)`` invocation whose args
  may be other nodes, :class:`~repro.core.handles.AlMatrix` handles, or
  scalars.
- :class:`ProjExpr`    — index ``i`` of a multi-output :class:`RunExpr`
  (``truncated_svd`` returns ``(U, s, V)``; each output is its own node).

:class:`LazyMatrix` is the user-facing wrapper: it holds a node plus the
planner that will execute it, supports ``@`` for deferred matmul, and
``collect()`` for the one explicit bridge crossing.

Every ElementalLib routine has a shape rule in :data:`SHAPE_RULES`, so
deferred chains validate at graph-build time (a mismatched ``gemm`` raises
:class:`~repro.core.errors.ShapeError` where it is written, not deep inside
the task queue) and the memory governor can reserve output bytes before a
routine runs (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import TYPE_CHECKING, Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.errors import ShapeError

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.core.planner import OffloadPlanner

_EXPR_IDS = itertools.count(1)


# ---------------------------------------------------------------------------
# Per-routine shape rules
# ---------------------------------------------------------------------------
#
# Each rule maps (arg shapes, params) -> one shape per routine output, letting
# LazyMatrix chains validate at graph-build time: a dimension mismatch raises
# a client-side ShapeError where the call is written, instead of surfacing as
# a deep task-queue failure after the DAG started executing. An entry of None
# in ``shapes`` means "unknown" (scalar arg, or an upstream node without a
# rule) — rules stay silent rather than guessing. Matrix outputs are 2-tuples;
# vectors are 1-tuples; scalars are ``()``.

ShapeLike = Optional[Tuple[int, ...]]
ShapeRule = Callable[[Sequence[ShapeLike], Dict[str, Any]], Tuple[ShapeLike, ...]]


def _require_2d(routine: str, pos: int, s: Tuple[int, ...]) -> None:
    if len(s) != 2:
        raise ShapeError(f"{routine}: operand {pos} must be 2D, got shape {s}")


def _rule_gemm(shapes: Sequence[ShapeLike], params: Dict[str, Any]):
    if len(shapes) < 2:
        raise ShapeError(f"gemm expects 2 matrix operands, got {len(shapes)}")
    a, b = shapes[0], shapes[1]
    if a is None or b is None:
        return (None,)
    _require_2d("gemm", 0, a)
    _require_2d("gemm", 1, b)
    if a[1] != b[0]:
        raise ShapeError(
            f"gemm: inner dimensions do not agree: {a[0]}x{a[1]} @ {b[0]}x{b[1]}"
        )
    return ((a[0], b[1]),)


def _svd_k(shapes: Sequence[ShapeLike], params: Dict[str, Any], routine: str):
    a = shapes[0] if shapes else None
    if a is None:
        return None, None
    _require_2d(routine, 0, a)
    if "k" not in params:
        # Not passed as a keyword (library default, or smuggled positionally
        # — which the keyword-only adapters reject at execution anyway):
        # don't validate or infer from an invented value.
        return a, None
    k = int(params["k"])
    if k < 1 or k > min(a):
        raise ShapeError(
            f"{routine}: k={k} out of range for a {a[0]}x{a[1]} matrix "
            f"(need 1 <= k <= {min(a)})"
        )
    return a, k


def _rule_truncated_svd(shapes, params, routine="truncated_svd"):
    a, k = _svd_k(shapes, params, routine)
    if a is None or k is None:
        return (None, None, None)
    return ((a[0], k), (k,), (a[1], k))  # U, s, V


def _rule_pca(shapes, params):
    a, k = _svd_k(shapes, params, "pca")
    if a is None or k is None:
        return (None, None, None)
    return ((a[1], k), (a[0], k), (k,))  # components, scores, explained_var


def _rule_tsqr(shapes, params):
    a = shapes[0] if shapes else None
    if a is None:
        return (None, None)
    _require_2d("tsqr", 0, a)
    if a[0] < a[1]:
        raise ShapeError(
            f"tsqr expects a tall-skinny matrix (rows >= cols), got {a[0]}x{a[1]}"
        )
    return ((a[0], a[1]), (a[1], a[1]))  # Q, R


def _rule_ridge(shapes, params):
    if len(shapes) < 2:
        raise ShapeError(f"ridge expects (A, b), got {len(shapes)} operands")
    a, b = shapes[0], shapes[1]
    if a is None or b is None:
        return (None,)
    _require_2d("ridge", 0, a)
    _require_2d("ridge", 1, b)
    if b != (a[0], 1):
        raise ShapeError(
            f"ridge: b must be {a[0]}x1 to match a {a[0]}x{a[1]} A, got {b[0]}x{b[1]}"
        )
    return ((a[1], 1),)


def _rule_scalar(routine: str) -> ShapeRule:
    def rule(shapes, params):
        a = shapes[0] if shapes else None
        if a is not None:
            _require_2d(routine, 0, a)
        return ((),)

    return rule


#: routine name -> shape rule, spanning every ElementalLib routine.
#: Third-party libraries extend this table at registration:
#: ``Library.register(..., shape_rule=...)`` routes through
#: :func:`register_shape_rule`, so their routines get the same graph-build
#: validation and governor output pricing as the built-ins (DESIGN.md §7).
SHAPE_RULES: Dict[str, ShapeRule] = {
    "gemm": _rule_gemm,
    "multiply": _rule_gemm,
    "truncated_svd": lambda s, p: _rule_truncated_svd(s, p, "truncated_svd"),
    "randomized_svd": lambda s, p: _rule_truncated_svd(s, p, "randomized_svd"),
    "pca": _rule_pca,
    "tsqr": _rule_tsqr,
    "ridge": _rule_ridge,
    "condest": _rule_scalar("condest"),
    "normest": _rule_scalar("normest"),
    "sigma_max": _rule_scalar("sigma_max"),
}


def register_shape_rule(
    routine: str, rule: ShapeRule, *, override: bool = False
) -> None:
    """Register a shape rule for a (third-party) routine name.

    The table is engine-global and keyed by routine name — the same key
    ``ac.run``/``OffloadPlanner.run`` dispatch on — so a registered rule
    immediately gives the routine graph-build ShapeError validation and
    output-byte pricing for governor admission (DESIGN.md §7). Registering a
    *different* rule under an existing name raises unless ``override=True``:
    two libraries silently disagreeing about one routine name is a bug, not
    a merge.
    """
    if not callable(rule):
        raise TypeError(f"shape rule for {routine!r} must be callable, got {rule!r}")
    existing = SHAPE_RULES.get(routine)
    if existing is not None and not _same_rule(existing, rule) and not override:
        raise ShapeError(
            f"routine {routine!r} already has a shape rule; pass override=True "
            "to replace it"
        )
    SHAPE_RULES[routine] = rule


def _same_rule(a: ShapeRule, b: ShapeRule) -> bool:
    """Are two rule callables the same rule? Identity, or the same code
    object — a library class defining its rule inline (lambda/nested def in
    ``__init__``) creates a fresh function per instantiation, and registering
    that library in a second session must not read as a conflict."""
    if a is b:
        return True
    code_a = getattr(a, "__code__", None)
    return code_a is not None and code_a is getattr(b, "__code__", None)


def arg_shape(a: Any) -> ShapeLike:
    """Best-known shape of a routine argument: Expr nodes and AlMatrix
    handles carry one; scalars and unknown upstream outputs are None."""
    s = getattr(a, "shape", None)
    if s is None:
        return None
    try:
        return tuple(int(d) for d in s)
    except (TypeError, ValueError):
        return None


def infer_run_shapes(
    routine: str,
    shapes: Sequence[ShapeLike],
    params: Dict[str, Any],
    n_outputs: Optional[int] = None,
) -> Optional[Tuple[ShapeLike, ...]]:
    """Apply the routine's shape rule; returns one shape per output, or None
    when no rule exists. Raises :class:`ShapeError` on operand mismatches and
    on an ``n_outputs`` that disagrees with the rule (only checked for
    multi-output requests: ``n_outputs=1`` legitimately means "hand me the
    whole result", whatever its arity)."""
    rule = SHAPE_RULES.get(routine)
    if rule is None:
        return None
    out = rule(list(shapes), dict(params))
    if n_outputs is not None and n_outputs > 1 and n_outputs != len(out):
        raise ShapeError(
            f"{routine} produces {len(out)} outputs, but n_outputs={n_outputs}"
        )
    return out


def peeked_state(val: Any) -> str:
    """Classify a planner-peeked value (``OffloadPlanner.peek``) into the
    uniform placement-state vocabulary the v2 handles expose (DESIGN.md §9):
    ``deferred`` (never lowered), ``pending`` (queued/in flight), or the
    underlying :class:`~repro.core.handles.AlMatrix` lifecycle state
    (``materialized``/``spilled``/``failed``/``freed``). Driver-side values
    (scalars, vectors, already-collected arrays) read as ``materialized``.
    Never forces execution. Shared by :class:`~repro.core.client.AlArray`
    and sparklike's ``LazyRowMatrix``."""
    from repro.core.futures import AlFuture
    from repro.core.handles import AlMatrix

    if val is None:
        return "deferred"
    if isinstance(val, AlFuture):
        if not val.done():
            return "pending"
        if val.exception() is not None:
            return "failed"
        val = val.result()
    return val.state if isinstance(val, AlMatrix) else "materialized"


def content_key(array: Any) -> Tuple:
    """Content-identity of a host array: (shape, dtype, sha1 of the bytes).

    This keys the planner's per-session resident-matrix cache: two sends of
    equal payloads resolve to one engine-resident matrix, regardless of
    whether the caller reused the ndarray object or rebuilt it.
    """
    key_fn = getattr(array, "content_key", None)
    if callable(key_fn):
        # Shard-staged wire payloads (transport.StagedShards) hash their
        # logical slabs in place — same (shape, dtype, sha1) triple, no
        # reassembly copy.
        return key_fn()
    arr = np.asarray(array)
    digest = hashlib.sha1(np.ascontiguousarray(arr).tobytes()).hexdigest()
    return (tuple(int(d) for d in arr.shape), str(arr.dtype), digest)


@dataclasses.dataclass(frozen=True, eq=False)
class Expr:
    """A node in the deferred-op DAG. Identity (not structure) keyed: the
    same node object consumed twice is one computation with two consumers."""

    id: int = dataclasses.field(default_factory=lambda: next(_EXPR_IDS), init=False)

    @property
    def shape(self) -> Optional[Tuple[int, int]]:
        return None

    @property
    def dtype(self):
        return None


@dataclasses.dataclass(frozen=True, eq=False)
class SendExpr(Expr):
    """A host→engine transfer, deferred. ``key`` is :func:`content_key` of
    the payload (computed once, at graph-build time)."""

    array: Any = None
    name: str = ""
    key: Tuple = ()
    _shape: Tuple[int, int] = ()
    _dtype: str = ""

    @staticmethod
    def of(array: Any, name: str = "", *, snapshot: bool = True) -> "SendExpr":
        # Snapshot mutable host arrays: the content key is computed now, and
        # a caller mutating the ndarray between graph build and lowering must
        # not ship different bytes under the old key (which would poison the
        # resident-matrix cache). jax.Arrays are immutable — no copy needed —
        # and internal callers that just materialized a private array
        # (e.g. sparklike offload's to_numpy()) pass snapshot=False to skip
        # the redundant O(m·n) copy.
        if isinstance(array, np.ndarray):
            if snapshot:
                array = np.array(array)  # fresh copy
        elif not hasattr(array, "shape"):
            array = np.array(array)  # lists etc.: conversion already copies
        arr = array
        if len(arr.shape) != 2:
            raise ValueError(
                f"SendExpr expects a 2D matrix, got shape {tuple(arr.shape)}"
            )
        return SendExpr(
            array=array,
            name=name,
            key=content_key(array),
            _shape=tuple(int(d) for d in arr.shape),
            _dtype=str(arr.dtype),
        )

    @property
    def shape(self) -> Tuple[int, int]:
        return self._shape

    @property
    def dtype(self) -> str:
        return self._dtype

    def __repr__(self) -> str:
        return f"SendExpr(id={self.id}, shape={self._shape}, name={self.name!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class RunExpr(Expr):
    """A deferred routine invocation. ``args`` entries are Expr nodes,
    AlMatrix handles (already resident), or plain scalars; ``params`` are
    codec-packable scalars only."""

    library: str = ""
    routine: str = ""
    args: Tuple[Any, ...] = ()
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    n_outputs: int = 1

    def output_shapes(self) -> Optional[Tuple[ShapeLike, ...]]:
        """One inferred shape per routine output via :data:`SHAPE_RULES`,
        or None when the routine has no rule. May raise ShapeError."""
        return infer_run_shapes(
            self.routine,
            [arg_shape(a) for a in self.args],
            self.params,
            self.n_outputs,
        )

    @property
    def shape(self) -> Optional[Tuple[int, int]]:
        try:
            shapes = self.output_shapes()
        except ShapeError:
            # Construction already validated; a late error (e.g. an upstream
            # shape learned afterwards) surfaces on execution, not here.
            return None
        if shapes and len(shapes) == 1 and shapes[0] is not None and len(shapes[0]) == 2:
            return shapes[0]
        return None

    def __repr__(self) -> str:
        return (
            f"RunExpr(id={self.id}, {self.library}.{self.routine}, "
            f"args={len(self.args)}, n_outputs={self.n_outputs})"
        )


@dataclasses.dataclass(frozen=True, eq=False)
class ProjExpr(Expr):
    """Output ``index`` of a multi-output :class:`RunExpr`."""

    parent: RunExpr = None
    index: int = 0

    @property
    def shape(self) -> Optional[Tuple[int, int]]:
        try:
            shapes = self.parent.output_shapes()
        except ShapeError:
            return None
        if shapes is None or self.index >= len(shapes):
            return None
        s = shapes[self.index]
        return s if s is not None and len(s) == 2 else None

    def __repr__(self) -> str:
        return f"ProjExpr(id={self.id}, parent={self.parent.id}, index={self.index})"


def iter_nodes(root: Expr):
    """Yield the DAG under ``root`` in dependency order (producers first)."""
    seen = set()

    def walk(node: Expr):
        if node.id in seen:
            return
        seen.add(node.id)
        if isinstance(node, ProjExpr):
            yield from walk(node.parent)
        elif isinstance(node, RunExpr):
            for a in node.args:
                if isinstance(a, Expr):
                    yield from walk(a)
        yield node

    yield from walk(root)


class LazyMatrix:
    """Client-side proxy for a deferred engine-resident matrix.

    Mirrors the paper's AlMatrix contract one level earlier: where an
    AlMatrix is a handle to data already on the engine, a LazyMatrix is a
    handle to data the planner has not even moved yet. Operations chain
    without executing; only :meth:`collect` crosses the bridge.
    """

    # Binary ops with ndarrays must reach our reflected operators: without
    # this, `ndarray @ LazyMatrix` coerces the proxy into a 0-d object array
    # and raises inside numpy before __rmatmul__ is ever consulted.
    __array_ufunc__ = None
    __array_priority__ = 1000

    def __init__(self, expr: Expr, planner: "OffloadPlanner"):
        self.expr = expr
        self.planner = planner

    @property
    def shape(self) -> Optional[Tuple[int, int]]:
        return self.expr.shape

    @property
    def dtype(self):
        return self.expr.dtype

    # -- chaining -----------------------------------------------------------
    def __matmul__(self, other: Any) -> "LazyMatrix":
        lib, routine = self.planner.matmul_routine
        return self.planner.run(lib, routine, self, other)

    def __rmatmul__(self, other: Any) -> "LazyMatrix":
        lib, routine = self.planner.matmul_routine
        return self.planner.run(lib, routine, other, self)

    # -- execution ----------------------------------------------------------
    def materialize(self):
        """Force execution; returns the engine-side value (an AlMatrix
        handle, or a driver-side scalar/vector) without crossing the bridge
        for matrix data."""
        return self.planner.materialize(self)

    def collect(self):
        """Execute the DAG under this node and bring the result client-side
        — the single explicit bridge crossing."""
        return self.planner.collect(self)

    def __repr__(self) -> str:
        return f"LazyMatrix({self.expr!r})"
