"""Deferred-expression DAG — the client side of the lazy offload planner.

DESIGN.md §6: the follow-up paper (arXiv:1805.11800) shows Alchemist's win
evaporating when an application naively collects every result back to Spark
between offloaded calls. The cure is structural: client-side operations build
a small DAG of deferred ops instead of executing eagerly, and the planner
(:mod:`repro.core.planner`) lowers the DAG onto the async task queue only
when a result is explicitly demanded. A value produced by one routine and
consumed by the next never crosses the bridge at all — it stays resident on
the session, exactly like the real Alchemist server's matrices that
"physically live on the MPI side".

Three node kinds:

- :class:`SendExpr`    — a host array that will become engine-resident; carries
  a content key so identical payloads dedup into one resident matrix.
- :class:`RunExpr`     — a deferred ``(library, routine)`` invocation whose args
  may be other nodes, :class:`~repro.core.handles.AlMatrix` handles, or
  scalars.
- :class:`ProjExpr`    — index ``i`` of a multi-output :class:`RunExpr`
  (``truncated_svd`` returns ``(U, s, V)``; each output is its own node).

:class:`LazyMatrix` is the user-facing wrapper: it holds a node plus the
planner that will execute it, supports ``@`` for deferred matmul, and
``collect()`` for the one explicit bridge crossing.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import TYPE_CHECKING, Any, Dict, Optional, Tuple

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.core.planner import OffloadPlanner

_EXPR_IDS = itertools.count(1)


def content_key(array: Any) -> Tuple:
    """Content-identity of a host array: (shape, dtype, sha1 of the bytes).

    This keys the planner's per-session resident-matrix cache: two sends of
    equal payloads resolve to one engine-resident matrix, regardless of
    whether the caller reused the ndarray object or rebuilt it.
    """
    arr = np.asarray(array)
    digest = hashlib.sha1(np.ascontiguousarray(arr).tobytes()).hexdigest()
    return (tuple(int(d) for d in arr.shape), str(arr.dtype), digest)


@dataclasses.dataclass(frozen=True, eq=False)
class Expr:
    """A node in the deferred-op DAG. Identity (not structure) keyed: the
    same node object consumed twice is one computation with two consumers."""

    id: int = dataclasses.field(default_factory=lambda: next(_EXPR_IDS), init=False)

    @property
    def shape(self) -> Optional[Tuple[int, int]]:
        return None

    @property
    def dtype(self):
        return None


@dataclasses.dataclass(frozen=True, eq=False)
class SendExpr(Expr):
    """A host→engine transfer, deferred. ``key`` is :func:`content_key` of
    the payload (computed once, at graph-build time)."""

    array: Any = None
    name: str = ""
    key: Tuple = ()
    _shape: Tuple[int, int] = ()
    _dtype: str = ""

    @staticmethod
    def of(array: Any, name: str = "", *, snapshot: bool = True) -> "SendExpr":
        # Snapshot mutable host arrays: the content key is computed now, and
        # a caller mutating the ndarray between graph build and lowering must
        # not ship different bytes under the old key (which would poison the
        # resident-matrix cache). jax.Arrays are immutable — no copy needed —
        # and internal callers that just materialized a private array
        # (e.g. sparklike offload's to_numpy()) pass snapshot=False to skip
        # the redundant O(m·n) copy.
        if isinstance(array, np.ndarray):
            if snapshot:
                array = np.array(array)  # fresh copy
        elif not hasattr(array, "shape"):
            array = np.array(array)  # lists etc.: conversion already copies
        arr = array
        if len(arr.shape) != 2:
            raise ValueError(
                f"SendExpr expects a 2D matrix, got shape {tuple(arr.shape)}"
            )
        return SendExpr(
            array=array,
            name=name,
            key=content_key(array),
            _shape=tuple(int(d) for d in arr.shape),
            _dtype=str(arr.dtype),
        )

    @property
    def shape(self) -> Tuple[int, int]:
        return self._shape

    @property
    def dtype(self) -> str:
        return self._dtype

    def __repr__(self) -> str:
        return f"SendExpr(id={self.id}, shape={self._shape}, name={self.name!r})"


@dataclasses.dataclass(frozen=True, eq=False)
class RunExpr(Expr):
    """A deferred routine invocation. ``args`` entries are Expr nodes,
    AlMatrix handles (already resident), or plain scalars; ``params`` are
    codec-packable scalars only."""

    library: str = ""
    routine: str = ""
    args: Tuple[Any, ...] = ()
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    n_outputs: int = 1

    @property
    def shape(self) -> Optional[Tuple[int, int]]:
        # Shape inference only where it is unambiguous (gemm); other routines
        # leave metadata unknown until execution.
        if self.routine in ("gemm", "multiply") and len(self.args) >= 2:
            a, b = self.args[0], self.args[1]
            sa = a.shape if isinstance(a, Expr) else getattr(a, "shape", None)
            sb = b.shape if isinstance(b, Expr) else getattr(b, "shape", None)
            if sa and sb:
                return (sa[0], sb[1])
        return None

    def __repr__(self) -> str:
        return (
            f"RunExpr(id={self.id}, {self.library}.{self.routine}, "
            f"args={len(self.args)}, n_outputs={self.n_outputs})"
        )


@dataclasses.dataclass(frozen=True, eq=False)
class ProjExpr(Expr):
    """Output ``index`` of a multi-output :class:`RunExpr`."""

    parent: RunExpr = None
    index: int = 0

    def __repr__(self) -> str:
        return f"ProjExpr(id={self.id}, parent={self.parent.id}, index={self.index})"


def iter_nodes(root: Expr):
    """Yield the DAG under ``root`` in dependency order (producers first)."""
    seen = set()

    def walk(node: Expr):
        if node.id in seen:
            return
        seen.add(node.id)
        if isinstance(node, ProjExpr):
            yield from walk(node.parent)
        elif isinstance(node, RunExpr):
            for a in node.args:
                if isinstance(a, Expr):
                    yield from walk(a)
        yield node

    yield from walk(root)


class LazyMatrix:
    """Client-side proxy for a deferred engine-resident matrix.

    Mirrors the paper's AlMatrix contract one level earlier: where an
    AlMatrix is a handle to data already on the engine, a LazyMatrix is a
    handle to data the planner has not even moved yet. Operations chain
    without executing; only :meth:`collect` crosses the bridge.
    """

    # Binary ops with ndarrays must reach our reflected operators: without
    # this, `ndarray @ LazyMatrix` coerces the proxy into a 0-d object array
    # and raises inside numpy before __rmatmul__ is ever consulted.
    __array_ufunc__ = None
    __array_priority__ = 1000

    def __init__(self, expr: Expr, planner: "OffloadPlanner"):
        self.expr = expr
        self.planner = planner

    @property
    def shape(self) -> Optional[Tuple[int, int]]:
        return self.expr.shape

    @property
    def dtype(self):
        return self.expr.dtype

    # -- chaining -----------------------------------------------------------
    def __matmul__(self, other: Any) -> "LazyMatrix":
        lib, routine = self.planner.matmul_routine
        return self.planner.run(lib, routine, self, other)

    def __rmatmul__(self, other: Any) -> "LazyMatrix":
        lib, routine = self.planner.matmul_routine
        return self.planner.run(lib, routine, other, self)

    # -- execution ----------------------------------------------------------
    def materialize(self):
        """Force execution; returns the engine-side value (an AlMatrix
        handle, or a driver-side scalar/vector) without crossing the bridge
        for matrix data."""
        return self.planner.materialize(self)

    def collect(self):
        """Execute the DAG under this node and bring the result client-side
        — the single explicit bridge crossing."""
        return self.planner.collect(self)

    def __repr__(self) -> str:
        return f"LazyMatrix({self.expr!r})"
