"""Structured error hierarchy for the Alchemist engine."""

from __future__ import annotations


class AlchemistError(Exception):
    """Base class for all engine errors."""


class SessionError(AlchemistError):
    """Session lifecycle problems (stopped context, double-stop, ...)."""


class WorkerAllocationError(AlchemistError):
    """Not enough free workers to satisfy an allocation request.

    Mirrors the paper's "assuming a sufficient number of workers is
    available" failure mode (§2.4, §3.2 step 3).
    """


class AdmissionTimeout(WorkerAllocationError):
    """A queued ``connect()`` waited out its admission timeout (DESIGN.md §9).

    Subclasses :class:`WorkerAllocationError`: callers that handled the old
    fail-fast allocation error keep working when queued admission is enabled.
    Raised *before* any worker group, session, or governor registration
    exists, so there is nothing to clean up.
    """


class LibraryError(AlchemistError):
    """Unknown library / routine, or a routine signature mismatch."""


class HandleError(AlchemistError):
    """Invalid or foreign AlMatrix handle (wrong session, freed, ...)."""


class LayoutError(AlchemistError):
    """Illegal layout conversion or a layout/mesh mismatch."""


class ShapeError(AlchemistError):
    """A deferred-op DAG failed shape inference at graph-build time: routine
    operands whose dimensions cannot compose (caught client-side, where the
    paper's driver would reject the call, instead of deep in the task queue)."""


class ParameterError(AlchemistError):
    """Bad scalar-parameter pack/unpack (Parameters header analogue)."""


class TaskError(AlchemistError):
    """Asynchronous task-queue failures: a future that timed out, a queue
    used after close, or a pending handle whose producing task failed
    (the original exception is chained as ``__cause__``)."""
