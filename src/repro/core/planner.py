"""OffloadPlanner — lowers the deferred-op DAG onto the async task queue.

DESIGN.md §6. The planner owns the three optimizations that keep a chained
sparklike→Alchemist pipeline from paying the bridge between every call:

1. **Bridge-crossing elision.** A :class:`~repro.core.expr.RunExpr` arg that
   is itself a deferred routine output is lowered to the producer's
   ``run_async`` future and consumed engine-side — the collect + re-send
   round trip a naive pipeline performs there is elided (counted in
   ``session.stats.elided_crossings``, one per elided round trip).
2. **Resident-matrix dedup.** Sends are keyed by payload content
   (:func:`repro.core.expr.content_key`); a second send of equal bytes in the
   same session reuses the already-resident matrix
   (``session.stats.resident_reuses``). The cache checks handle liveness, so
   a freed matrix is transparently re-sent. Dedup is two-level (DESIGN.md
   §8): behind the session-local memo sits the engine's content-addressed
   :class:`~repro.core.resident.ResidentStore` — bytes another session
   already placed on the engine (or content migrated out of a closed
   session) attach instead of crossing the bridge
   (``session.stats.cross_session_reuses``).
3. **Async pipelining.** Lowering emits ``send_async``/``run_async`` in
   dependency order and never blocks: independent subgraphs interleave on the
   session's FIFO exactly as in DESIGN.md §3, and only an explicit
   :meth:`collect` materializes.
4. **Common-subexpression elimination.** :meth:`run` memoizes structurally
   identical routine invocations — same ``(library, routine)``, same arg
   *node ids* (or handle ids/scalars), same canonical params and arity — so
   a DAG that rebuilds the same compute node twice lowers it once
   (``session.stats.cse_hits``). Identity is by node id on purpose: two
   sends of equal bytes stay distinct nodes (their dedup is the content
   layer's job), and CSE only fires for genuinely shared subexpressions.
   ``run(..., cse=False)`` opts a call out (e.g. routines that are
   intentionally re-randomized between calls).

The planner is per-client (one per :class:`~repro.core.client.ClientCore`,
reached via ``ac.planner`` — so one per v2 ``Session`` and per legacy
``AlchemistContext`` alike), so its caches are session-scoped like the
relayout plan cache, and its counters land in the same
``session.stats.summary()``. Under the v2 surface (DESIGN.md §9) *every*
client call builds nodes here; the session's ExecutionPolicy only decides
when :meth:`OffloadPlanner.lower` runs.

Two DESIGN.md §7 responsibilities ride on the DAG:

- **Graph-build shape validation.** :meth:`OffloadPlanner.run` applies the
  per-routine shape rules (:data:`repro.core.expr.SHAPE_RULES`), so a
  dimension mismatch raises a client-side ShapeError at the call site.
- **Last-use spill hints.** The planner knows each intermediate's final
  consumer; when that consumer's task completes, the produced matrices are
  hinted to the session's memory governor as preferred spill victims. A
  spilled intermediate is still an elided crossing — consuming it later costs
  a host→device refill, never a bridge round trip.
"""

from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Any, Dict, Tuple, Union

import numpy as np

from repro.core import futures as futures_mod
from repro.core import handles as handles_mod
from repro.core.errors import SessionError, ShapeError
from repro.core.expr import (
    Expr,
    LazyMatrix,
    ProjExpr,
    RunExpr,
    SendExpr,
    content_key,
    iter_nodes,
)
from repro.core.futures import AlFuture
from repro.core.handles import AlMatrix

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.client import ClientCore

LazyLike = Union[LazyMatrix, Expr]


class _Uncacheable(Exception):
    """A param value with no trustworthy canonical identity: the call must
    opt out of CSE rather than risk a false memo hit (repr() truncates big
    ndarrays, so two different arrays can print identically)."""


def _canon_params(params: Dict[str, Any]) -> Tuple:
    """Hashable canonical form of a routine's keyword params (codec scalars
    and small lists), order-insensitive, for the CSE signature. Raises
    :class:`_Uncacheable` for values whose identity cannot be captured."""

    def canon(v: Any) -> Any:
        if isinstance(v, np.ndarray):
            return ("nd", content_key(v))
        if isinstance(v, (list, tuple)):
            return ("seq", tuple(canon(x) for x in v))
        if isinstance(v, dict):
            return ("map", tuple(sorted((k, canon(x)) for k, x in v.items())))
        if isinstance(v, AlMatrix):
            return ("mat", v.id)
        if isinstance(v, np.generic):
            return v.item()
        try:
            hash(v)
            return v
        except TypeError:
            raise _Uncacheable(repr(type(v))) from None

    return tuple(sorted((k, canon(v)) for k, v in params.items()))


class OffloadPlanner:
    """Builds and executes deferred-op DAGs for one Alchemist session."""

    #: (library, routine) used by ``LazyMatrix.__matmul__``.
    matmul_routine: Tuple[str, str] = ("elemental", "gemm")

    def __init__(self, ac: "ClientCore"):
        self.ac = ac
        # content key -> AlFuture-of-handle / AlMatrix already resident
        self._resident: Dict[Tuple, Any] = {}
        # structural RunExpr signature -> the LazyMatrix (or tuple of
        # projections) already built for it (CSE, DESIGN.md §8)
        self._cse: Dict[Tuple, Any] = {}
        # expr id -> lowered value (AlFuture / AlMatrix / scalar)
        self._lowered: Dict[int, Any] = {}
        # DAG last-use tracking for the memory governor: expr id -> number of
        # consumers whose tasks have not yet completed, and the set of nodes
        # whose out-edges were already counted (lowering is idempotent; the
        # count must be too).
        self._remaining_uses: Dict[int, int] = {}
        self._counted: set = set()
        # Reentrant: held across the whole recursive lowering walk, so two
        # threads collecting DAGs that share a node cannot both dispatch it
        # (submission is non-blocking; futures are resolved outside the lock).
        self._lock = threading.RLock()

    # -- graph building ------------------------------------------------------
    def send(self, array: Any, name: str = "", *, snapshot: bool = True) -> LazyMatrix:
        """Defer a host→engine transfer. Nothing moves until a consumer of
        this node is collected; equal payloads share one resident matrix.

        ``snapshot=False`` skips the defensive copy of host ndarrays — only
        for arrays the caller guarantees are private and never mutated
        (the content key is computed now; shipped bytes must match it).
        """
        return LazyMatrix(SendExpr.of(array, name=name, snapshot=snapshot), self)

    def run(
        self,
        library: str,
        routine: str,
        *args: Any,
        n_outputs: int = 1,
        cse: bool = True,
        **params: Any,
    ):
        """Defer ``library.routine``. Args may be LazyMatrix nodes, AlMatrix
        handles, host ndarrays (auto-wrapped as deferred sends, so they dedup
        too), or scalars. With ``n_outputs > 1`` returns a tuple of
        LazyMatrix, one per output of the routine.

        Chains validate as they are built: routines with a shape rule
        (every ElementalLib routine) raise a client-side ShapeError here on
        mismatched operand dimensions, instead of failing deep inside the
        task queue at execution time.

        Structurally identical invocations — same routine, same arg node
        ids, same canonical params — are memoized (common-subexpression
        elimination, counted as ``cse_hits``): the same LazyMatrix comes
        back, so the compute lowers at most once per DAG. Pass ``cse=False``
        for routines that must re-execute per call."""
        if n_outputs < 1:
            raise SessionError(f"n_outputs must be >= 1, got {n_outputs}")
        wrapped = tuple(self._wrap_arg(a) for a in args)
        sig = None
        if cse:
            try:
                sig = (
                    library,
                    routine,
                    tuple(self._arg_sig(a) for a in wrapped),
                    _canon_params(params),
                    n_outputs,
                )
            except _Uncacheable:
                sig = None  # a param defeats canonicalization: never memoize
        if sig is not None:
            with self._lock:
                hit = self._cse.get(sig)
            if hit is not None:
                # Freed results re-lower transparently through _stale();
                # failed ones keep propagating — both exactly the semantics
                # of consuming the original node twice.
                self.ac.session.stats.record_cse_hit()
                return hit
        node = RunExpr(
            library=library,
            routine=routine,
            args=wrapped,
            params=dict(params),
            n_outputs=n_outputs,
        )
        node.output_shapes()  # graph-build validation; raises ShapeError
        if n_outputs == 1:
            out = LazyMatrix(node, self)
        else:
            out = tuple(
                LazyMatrix(ProjExpr(parent=node, index=i), self)
                for i in range(n_outputs)
            )
        if sig is not None:
            with self._lock:
                self._cse.setdefault(sig, out)
        return out

    @staticmethod
    def _arg_sig(a: Any) -> Tuple:
        """Structural identity of one RunExpr argument for the CSE memo:
        node id for Expr operands (content dedup stays the send layer's
        job), handle id for resident matrices, value for codec scalars."""
        if isinstance(a, Expr):
            return ("expr", a.id)
        if isinstance(a, AlMatrix):
            return ("mat", a.id)
        return ("val", type(a).__name__, repr(a))

    def _wrap_arg(self, a: Any) -> Any:
        if isinstance(a, LazyMatrix):
            if a.planner is not self:
                raise SessionError(
                    "LazyMatrix belongs to a different planner/session; "
                    "collect it and re-send instead"
                )
            return a.expr
        if isinstance(a, Expr) or isinstance(a, AlMatrix):
            return a
        if isinstance(a, np.ndarray) and a.ndim == 2:
            return SendExpr.of(a)
        return a  # scalar / string / None — travels through the param codec

    # -- execution -----------------------------------------------------------
    def materialize(self, lazy: LazyLike):
        """Lower (if needed) and resolve the node's engine-side value: an
        AlMatrix handle for matrix outputs, a host scalar/vector for
        non-distributed outputs. No matrix data crosses the bridge."""
        return futures_mod.resolve(self.lower(lazy))

    def collect(self, lazy: LazyLike):
        """Execute the DAG under ``lazy`` and return its value client-side.

        Matrix results cross the bridge here and only here; scalar/vector
        results (already driver-side, per the paper's split) pass through.
        """
        val = self.materialize(lazy)
        if isinstance(val, AlMatrix):
            return self.ac.collect(val)
        if isinstance(val, (tuple, list)):
            return type(val)(
                self.ac.collect(v) if isinstance(v, AlMatrix) else v for v in val
            )
        return val

    def lower(self, lazy: LazyLike) -> Any:
        """Lower the DAG under ``lazy`` onto the session's task queue and
        return the root's future (or already-lowered value) without blocking.
        Idempotent: every node is lowered at most once per planner."""
        node = lazy.expr if isinstance(lazy, LazyMatrix) else lazy
        if not isinstance(node, Expr):
            return node
        with self._lock:
            self._count_uses(node)
        return self._lower(node)

    def _count_uses(self, root: Expr) -> None:
        """Record each node's consumer count (DAG last-use info for the
        memory governor). Caller holds the lock; each node's out-edges are
        counted once, so repeated lower() calls on overlapping DAGs only add
        the genuinely new consumers."""
        for node in iter_nodes(root):
            if node.id in self._counted:
                continue
            self._counted.add(node.id)
            if isinstance(node, RunExpr):
                children = [a for a in node.args if isinstance(a, Expr)]
            elif isinstance(node, ProjExpr):
                children = [node.parent]
            else:
                children = []
            for child in children:
                self._remaining_uses[child.id] = (
                    self._remaining_uses.get(child.id, 0) + 1
                )

    def _consumed(self, node: Expr) -> None:
        """A consumer task of ``node`` completed. At zero remaining uses the
        node's engine-resident outputs are hinted to the governor as past
        their DAG last use — preferred spill victims, still live."""
        hint_val = None
        with self._lock:
            left = self._remaining_uses.get(node.id)
            if left is None:
                return
            left -= 1
            self._remaining_uses[node.id] = left
            if left > 0:
                return
            hint_val = self._lowered.get(node.id)
            if isinstance(node, ProjExpr):
                # A projection is a pass-through: its last use is also one
                # more consumption of the parent routine's output tuple.
                parent = node.parent
            else:
                parent = None
        self._hint_idle_value(hint_val)
        if parent is not None:
            self._consumed(parent)

    def _hint_idle_value(self, val: Any) -> None:
        memgov = self.ac.session.memgov
        if isinstance(val, AlFuture):
            if not val.done() or val.exception() is not None:
                return
            val = val.result()
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, AlMatrix):
                memgov.hint_idle(v)

    def _lower(self, node: Expr) -> Any:
        with self._lock:
            hit = self._lowered.get(node.id)
            if hit is not None:
                # A node whose engine-resident result has since been freed
                # must be re-lowered (the documented transparent re-send /
                # re-run), not handed back stale.
                if not self._stale(node, hit):
                    return hit
                del self._lowered[node.id]
            if isinstance(node, SendExpr):
                val = self._lower_send(node)
            elif isinstance(node, RunExpr):
                val = self._lower_run(node)
            elif isinstance(node, ProjExpr):
                parent = self._lower(node.parent)
                val = self._project(parent, node.index)
            else:  # pragma: no cover - defensive
                raise SessionError(f"cannot lower node {node!r}")
            self._lowered[node.id] = val
            return val

    def _lower_send(self, node: SendExpr) -> Any:
        sess = self.ac.session
        stats = sess.stats
        cached = self._resident.get(node.key)
        if cached is not None and self._is_live(cached):
            # The naive pipeline would push these bytes across the bridge
            # again; the planner hands back the already-resident matrix.
            # A *spilled* resident matrix still counts: its bytes live in the
            # engine's host store and refill on consumption — host↔device
            # traffic, never a bridge crossing. Touching the governor resets
            # its LRU age so imminent reuse isn't immediately re-spilled.
            stats.record_resident_reuse()
            val = cached
            if isinstance(val, AlFuture) and val.done() and val.exception() is None:
                val = val.result()
            if isinstance(val, AlMatrix):
                sess.memgov.touch(val)
            return cached
        # Session-local memo missed: consult the engine's content index
        # (DESIGN.md §8). A placement this session already holds (e.g. an
        # eager send of the same bytes) is reused in place; content resident
        # only elsewhere attaches without a bridge crossing; a genuine miss
        # sends — publishing the snapshot payload so later sessions (and this
        # session after a close/migration cycle) can attach to it.
        store = self.ac._content_store()
        if store is not None:
            entry = store.lookup(node.key)
            mine = entry.live_handle_for(sess.id) if entry is not None else None
            if mine is not None:
                stats.record_resident_reuse()
                sess.memgov.touch(mine)
                self._resident[node.key] = mine
                return mine
        fut = self.ac._submit_send(
            node.array,
            name=node.name,
            block=False,
            key=node.key,
            payload=node.array if isinstance(node.array, np.ndarray) else None,
        )
        self._resident[node.key] = fut
        return fut

    def _lower_run(self, node: RunExpr) -> AlFuture:
        stats = self.ac.session.stats
        lowered_args = []
        consumed_exprs = []
        for a in node.args:
            if isinstance(a, (RunExpr, ProjExpr)):
                # Engine-resident intermediate consumed in place: one
                # collect + re-send round trip the naive execution would
                # have paid is elided (even when the governor has spilled it
                # in the meantime — the refill is host→device, not a bridge
                # crossing).
                stats.record_elision()
                lowered_args.append(self._lower(a))
                consumed_exprs.append(a)
            elif isinstance(a, Expr):
                lowered_args.append(self._lower(a))
                consumed_exprs.append(a)
            else:
                lowered_args.append(a)
        stats.record_planned_op()
        try:
            out_shapes = node.output_shapes()  # governor reservation hint
        except ShapeError:
            out_shapes = None  # late mismatch: surfaces at execution
        fut = self.ac.run_async(
            node.library,
            node.routine,
            *lowered_args,
            _out_shapes=out_shapes,
            _out_dtype=self._arg_dtype(node),
            **node.params,
        )
        if consumed_exprs:
            # DAG last-use accounting: once this routine's task completes,
            # each Expr operand has one fewer outstanding consumer; at zero
            # the governor is hinted that its matrices are spill-preferred.
            args_tuple = tuple(consumed_exprs)
            fut.add_done_callback(
                lambda _parent: [self._consumed(a) for a in args_tuple]
            )
        return fut

    @staticmethod
    def _arg_dtype(node: RunExpr) -> Any:
        """Best-known operand dtype for the governor's output-byte pricing —
        the engine can't see it through still-pending futures. Send nodes and
        live handles carry a dtype; run/projection operands don't, so the
        walk recurses to the leaves (a chain of f64 gemms must price f64
        even when every direct operand is itself a deferred run)."""
        stack = list(node.args)
        seen = set()
        while stack:
            a = stack.pop(0)
            if isinstance(a, Expr):
                if a.id in seen:
                    continue
                seen.add(a.id)
            dt = getattr(a, "dtype", None)
            if dt:
                return dt
            if isinstance(a, ProjExpr):
                stack.append(a.parent)
            elif isinstance(a, RunExpr):
                stack.extend(a.args)
        return None

    @staticmethod
    def _project(parent: Any, index: int) -> Any:
        def pick(value: Any) -> Any:
            if not isinstance(value, (tuple, list)):
                raise SessionError(
                    f"routine returned a single output; cannot project index {index} "
                    "(was n_outputs set too high?)"
                )
            return value[index]

        if isinstance(parent, AlFuture):
            return parent.then(pick, label=f"{parent.label}[{index}]")
        return pick(parent)

    @staticmethod
    def _is_live(entry: Any) -> bool:
        """Is a resident-cache entry still usable? Futures still in flight
        are; resolved ones are checked against the handle lifecycle (a freed
        or failed matrix must be re-sent, not reused)."""
        if isinstance(entry, AlMatrix):
            return entry.is_live
        if isinstance(entry, AlFuture):
            if not entry.done():
                return True
            if entry.exception() is not None:
                return False
            val = entry.result()
            return val.is_live if isinstance(val, AlMatrix) else True
        return False

    def _stale(self, node: Expr, entry: Any) -> bool:
        """Should a memoized lowering be discarded and the node re-lowered?

        Sends: whenever the resident matrix is no longer live (freed or the
        transfer failed — re-sending is idempotent). Runs/projections: only
        when a produced matrix was freed; a *failed* routine keeps
        propagating its error rather than being silently retried.
        """
        if isinstance(node, SendExpr):
            return not self._is_live(entry)
        val = entry
        if isinstance(val, AlFuture):
            if not val.done() or val.exception() is not None:
                return False
            val = val.result()
        vals = val if isinstance(val, (tuple, list)) else (val,)
        return any(isinstance(v, AlMatrix) and v.state == handles_mod.FREED for v in vals)

    def peek(self, lazy: LazyLike) -> Any:
        """The node's already-lowered value (future/handle/scalar), or None
        if lowering hasn't happened — never triggers execution. Lets callers
        (e.g. sparklike's LazyRowMatrix) observe resident/spilled state."""
        node = lazy.expr if isinstance(lazy, LazyMatrix) else lazy
        if not isinstance(node, Expr):
            return node
        with self._lock:
            return self._lowered.get(node.id)

    def lowered_ids(self) -> set:
        """Ids of every expr node with a memoized lowering — the lineage
        ledger for fleet recovery (DESIGN.md §14): snapshotted at failure
        time it names the DAG prefix whose engine-side outputs were lost;
        intersected with a post-replay snapshot it bounds what actually
        re-ran (the planner only re-lowers what a materialization demands,
        so replay ⊆ lost by construction — the benchmark asserts it)."""
        with self._lock:
            return set(self._lowered)

    # -- maintenance ---------------------------------------------------------
    def reset(self) -> None:
        """Drop the lowering memo and resident cache (e.g. after bulk frees).
        Already-dispatched work is unaffected."""
        with self._lock:
            self._resident.clear()
            self._cse.clear()
            self._lowered.clear()
            self._remaining_uses.clear()
            self._counted.clear()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "resident_entries": len(self._resident),
                "cse_entries": len(self._cse),
                "lowered_nodes": len(self._lowered),
                "tracked_last_uses": len(self._remaining_uses),
            }

    def __repr__(self) -> str:
        s = self.stats()
        return (
            f"OffloadPlanner(session={self.ac.session.id}, "
            f"resident={s['resident_entries']}, lowered={s['lowered_nodes']})"
        )
