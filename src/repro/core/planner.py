"""OffloadPlanner — lowers the deferred-op DAG onto the async task queue.

DESIGN.md §6. The planner owns the three optimizations that keep a chained
sparklike→Alchemist pipeline from paying the bridge between every call:

1. **Bridge-crossing elision.** A :class:`~repro.core.expr.RunExpr` arg that
   is itself a deferred routine output is lowered to the producer's
   ``run_async`` future and consumed engine-side — the collect + re-send
   round trip a naive pipeline performs there is elided (counted in
   ``session.stats.elided_crossings``, one per elided round trip).
2. **Resident-matrix dedup.** Sends are keyed by payload content
   (:func:`repro.core.expr.content_key`); a second send of equal bytes in the
   same session reuses the already-resident matrix
   (``session.stats.resident_reuses``). The cache checks handle liveness, so
   a freed matrix is transparently re-sent.
3. **Async pipelining.** Lowering emits ``send_async``/``run_async`` in
   dependency order and never blocks: independent subgraphs interleave on the
   session's FIFO exactly as in DESIGN.md §3, and only an explicit
   :meth:`collect` materializes.

The planner is per-:class:`~repro.core.engine.AlchemistContext` (reached via
``ac.planner``), so its caches are session-scoped like the relayout plan
cache, and its counters land in the same ``session.stats.summary()``.
"""

from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Any, Dict, Optional, Tuple, Union

import numpy as np

from repro.core import futures as futures_mod
from repro.core import handles as handles_mod
from repro.core.errors import SessionError
from repro.core.expr import Expr, LazyMatrix, ProjExpr, RunExpr, SendExpr
from repro.core.futures import AlFuture
from repro.core.handles import AlMatrix

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.engine import AlchemistContext

LazyLike = Union[LazyMatrix, Expr]


class OffloadPlanner:
    """Builds and executes deferred-op DAGs for one Alchemist session."""

    #: (library, routine) used by ``LazyMatrix.__matmul__``.
    matmul_routine: Tuple[str, str] = ("elemental", "gemm")

    def __init__(self, ac: "AlchemistContext"):
        self.ac = ac
        # content key -> AlFuture-of-handle / AlMatrix already resident
        self._resident: Dict[Tuple, Any] = {}
        # expr id -> lowered value (AlFuture / AlMatrix / scalar)
        self._lowered: Dict[int, Any] = {}
        # Reentrant: held across the whole recursive lowering walk, so two
        # threads collecting DAGs that share a node cannot both dispatch it
        # (submission is non-blocking; futures are resolved outside the lock).
        self._lock = threading.RLock()

    # -- graph building ------------------------------------------------------
    def send(self, array: Any, name: str = "", *, snapshot: bool = True) -> LazyMatrix:
        """Defer a host→engine transfer. Nothing moves until a consumer of
        this node is collected; equal payloads share one resident matrix.

        ``snapshot=False`` skips the defensive copy of host ndarrays — only
        for arrays the caller guarantees are private and never mutated
        (the content key is computed now; shipped bytes must match it).
        """
        return LazyMatrix(SendExpr.of(array, name=name, snapshot=snapshot), self)

    def run(
        self, library: str, routine: str, *args: Any, n_outputs: int = 1, **params: Any
    ):
        """Defer ``library.routine``. Args may be LazyMatrix nodes, AlMatrix
        handles, host ndarrays (auto-wrapped as deferred sends, so they dedup
        too), or scalars. With ``n_outputs > 1`` returns a tuple of
        LazyMatrix, one per output of the routine."""
        if n_outputs < 1:
            raise SessionError(f"n_outputs must be >= 1, got {n_outputs}")
        wrapped = tuple(self._wrap_arg(a) for a in args)
        node = RunExpr(
            library=library,
            routine=routine,
            args=wrapped,
            params=dict(params),
            n_outputs=n_outputs,
        )
        if n_outputs == 1:
            return LazyMatrix(node, self)
        return tuple(
            LazyMatrix(ProjExpr(parent=node, index=i), self) for i in range(n_outputs)
        )

    def _wrap_arg(self, a: Any) -> Any:
        if isinstance(a, LazyMatrix):
            if a.planner is not self:
                raise SessionError(
                    "LazyMatrix belongs to a different planner/session; "
                    "collect it and re-send instead"
                )
            return a.expr
        if isinstance(a, Expr) or isinstance(a, AlMatrix):
            return a
        if isinstance(a, np.ndarray) and a.ndim == 2:
            return SendExpr.of(a)
        return a  # scalar / string / None — travels through the param codec

    # -- execution -----------------------------------------------------------
    def materialize(self, lazy: LazyLike):
        """Lower (if needed) and resolve the node's engine-side value: an
        AlMatrix handle for matrix outputs, a host scalar/vector for
        non-distributed outputs. No matrix data crosses the bridge."""
        return futures_mod.resolve(self.lower(lazy))

    def collect(self, lazy: LazyLike):
        """Execute the DAG under ``lazy`` and return its value client-side.

        Matrix results cross the bridge here and only here; scalar/vector
        results (already driver-side, per the paper's split) pass through.
        """
        val = self.materialize(lazy)
        if isinstance(val, AlMatrix):
            return self.ac.collect(val)
        if isinstance(val, (tuple, list)):
            return type(val)(
                self.ac.collect(v) if isinstance(v, AlMatrix) else v for v in val
            )
        return val

    def lower(self, lazy: LazyLike) -> Any:
        """Lower the DAG under ``lazy`` onto the session's task queue and
        return the root's future (or already-lowered value) without blocking.
        Idempotent: every node is lowered at most once per planner."""
        node = lazy.expr if isinstance(lazy, LazyMatrix) else lazy
        if not isinstance(node, Expr):
            return node
        return self._lower(node)

    def _lower(self, node: Expr) -> Any:
        with self._lock:
            hit = self._lowered.get(node.id)
            if hit is not None:
                # A node whose engine-resident result has since been freed
                # must be re-lowered (the documented transparent re-send /
                # re-run), not handed back stale.
                if not self._stale(node, hit):
                    return hit
                del self._lowered[node.id]
            if isinstance(node, SendExpr):
                val = self._lower_send(node)
            elif isinstance(node, RunExpr):
                val = self._lower_run(node)
            elif isinstance(node, ProjExpr):
                parent = self._lower(node.parent)
                val = self._project(parent, node.index)
            else:  # pragma: no cover - defensive
                raise SessionError(f"cannot lower node {node!r}")
            self._lowered[node.id] = val
            return val

    def _lower_send(self, node: SendExpr) -> Any:
        stats = self.ac.session.stats
        cached = self._resident.get(node.key)
        if cached is not None and self._is_live(cached):
            # The naive pipeline would push these bytes across the bridge
            # again; the planner hands back the already-resident matrix.
            stats.record_resident_reuse()
            return cached
        fut = self.ac.send_async(node.array, name=node.name)
        self._resident[node.key] = fut
        return fut

    def _lower_run(self, node: RunExpr) -> AlFuture:
        stats = self.ac.session.stats
        lowered_args = []
        for a in node.args:
            if isinstance(a, (RunExpr, ProjExpr)):
                # Engine-resident intermediate consumed in place: one
                # collect + re-send round trip the naive execution would
                # have paid is elided.
                stats.record_elision()
                lowered_args.append(self._lower(a))
            elif isinstance(a, Expr):
                lowered_args.append(self._lower(a))
            else:
                lowered_args.append(a)
        stats.record_planned_op()
        return self.ac.run_async(node.library, node.routine, *lowered_args, **node.params)

    @staticmethod
    def _project(parent: Any, index: int) -> Any:
        def pick(value: Any) -> Any:
            if not isinstance(value, (tuple, list)):
                raise SessionError(
                    f"routine returned a single output; cannot project index {index} "
                    "(was n_outputs set too high?)"
                )
            return value[index]

        if isinstance(parent, AlFuture):
            return parent.then(pick, label=f"{parent.label}[{index}]")
        return pick(parent)

    @staticmethod
    def _is_live(entry: Any) -> bool:
        """Is a resident-cache entry still usable? Futures still in flight
        are; resolved ones are checked against the handle lifecycle (a freed
        or failed matrix must be re-sent, not reused)."""
        if isinstance(entry, AlMatrix):
            return entry.is_live
        if isinstance(entry, AlFuture):
            if not entry.done():
                return True
            if entry.exception() is not None:
                return False
            val = entry.result()
            return val.is_live if isinstance(val, AlMatrix) else True
        return False

    def _stale(self, node: Expr, entry: Any) -> bool:
        """Should a memoized lowering be discarded and the node re-lowered?

        Sends: whenever the resident matrix is no longer live (freed or the
        transfer failed — re-sending is idempotent). Runs/projections: only
        when a produced matrix was freed; a *failed* routine keeps
        propagating its error rather than being silently retried.
        """
        if isinstance(node, SendExpr):
            return not self._is_live(entry)
        val = entry
        if isinstance(val, AlFuture):
            if not val.done() or val.exception() is not None:
                return False
            val = val.result()
        vals = val if isinstance(val, (tuple, list)) else (val,)
        return any(isinstance(v, AlMatrix) and v.state == handles_mod.FREED for v in vals)

    # -- maintenance ---------------------------------------------------------
    def reset(self) -> None:
        """Drop the lowering memo and resident cache (e.g. after bulk frees).
        Already-dispatched work is unaffected."""
        with self._lock:
            self._resident.clear()
            self._lowered.clear()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "resident_entries": len(self._resident),
                "lowered_nodes": len(self._lowered),
            }

    def __repr__(self) -> str:
        s = self.stats()
        return (
            f"OffloadPlanner(session={self.ac.session.id}, "
            f"resident={s['resident_entries']}, lowered={s['lowered_nodes']})"
        )
