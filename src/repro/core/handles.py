"""AlMatrix handles — proxies for engine-resident distributed matrices.

Paper §3.3: "Alchemist uses matrix handles in the form of AlMatrix objects,
which act as proxies for the distributed data sets stored on Alchemist. ...
Only when the user explicitly converts this object into an RDD will the data
in the matrix be sent between Alchemist to Spark."

Here the handle wraps an engine-resident ``jax.Array`` plus its layout tag.
Chained library calls pass handles; the client collect path is the only one
that reshards data back to the client's row layout — so, exactly as in the
paper, the bridge is crossed only on explicit request.

Under the v2 surface (DESIGN.md §9) AlMatrix is the *engine-side* handle
behind the uniform client-facing :class:`~repro.core.client.AlArray`: an
AlArray's expression node lowers to (a future of) an AlMatrix, and the
lifecycle states below are exactly what ``AlArray.state`` reports once
execution has started (``deferred`` exists only client-side, before any
handle is created).

With the asynchronous task-queue engine (DESIGN.md §3-§4) a handle has a
lifecycle::

    pending ──materialize()──▶ materialized ──free()──▶ freed
        │                        │        ▲
        │                     spill()   refill()   (memory governor, §7)
        │                        ▼        │
        └──fail(exc)──▶ failed   spilled ─┘   (data() re-raises via TaskError)

``send_async`` creates the handle immediately in the *pending* state — shape
and dtype are known up front, so metadata-only operations (and packing the
handle into a parameter frame) never wait — and the session's queue worker
materializes it when the transfer actually runs. ``data()`` on a pending
handle blocks until materialization; within one session that never happens
(the FIFO queue materializes producers before consumers run), but a handle
shared across engine internals may legitimately wait.

Two DESIGN.md §7 concerns also live here:

- **Spill/refill.** Under HBM pressure the session's memory governor may move
  a resident matrix to a pinned host store (state *spilled*); the handle stays
  live, and ``data()`` transparently refills it device-side on next use.
- **Divisibility padding.** The bridge pads uneven dims for ``device_put``
  (DESIGN.md §7); ``pads`` records the physical zero rows/cols so ``data()``
  always returns the logical matrix.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Optional, Tuple

import jax

from repro.core.errors import HandleError, TaskError
from repro.core.layouts import LayoutSpec

_ID_COUNTER = itertools.count(1)

# Handle lifecycle states.
PENDING = "pending"
MATERIALIZED = "materialized"
SPILLED = "spilled"  # resident bytes moved to the host store (memory governor)
FAILED = "failed"
FREED = "freed"


@dataclasses.dataclass
class AlMatrix:
    """Handle to a matrix resident on the engine's worker group.

    Attributes:
      id: unique handle id (per engine process).
      shape/dtype: logical matrix metadata (always known to the client).
      layout: engine-side layout the data is stored in.
      session_id: owning session; handles are session-scoped like the paper's
        per-application matrix namespaces.
      name: optional human label for logs.
    """

    shape: Tuple[int, int]
    dtype: jax.numpy.dtype
    layout: LayoutSpec
    session_id: int
    name: str = ""
    id: int = dataclasses.field(default_factory=lambda: next(_ID_COUNTER))
    #: physical minus logical extent per dim: the zero rows/cols the bridge
    #: appended so ``device_put`` divisibility holds (DESIGN.md §7).
    pads: Tuple[int, int] = (0, 0)
    #: content key of the engine ResidentStore entry this handle is a
    #: per-session placement of, or None for session-private matrices
    #: (routine outputs, cyclic-layout sends). Store-backed handles pin their
    #: entry; free/close unpin it through the session layer (DESIGN.md §8).
    store_key: Optional[Tuple] = dataclasses.field(default=None, repr=False)
    #: the logical host payload this placement was produced from, when the
    #: engine holds one (the store entry's snapshot). Lets the governor spill
    #: without a ``device_get`` and refill/serve collects from host bytes the
    #: engine already owns.
    _host_fallback: Optional[object] = dataclasses.field(default=None, repr=False)
    #: True while this handle is a pending *attach* placement: it consumes
    #: the store entry's payload rather than producing it, so
    #: ``ResidentStore.ensure_payload`` must never block on it as a source
    #: (an attach waiting on its own — or a sibling attach's — pending handle
    #: would deadlock the task-queue workers).
    _placement_only: bool = dataclasses.field(default=False, repr=False)
    _data: Optional[jax.Array] = dataclasses.field(default=None, repr=False)
    _state: str = dataclasses.field(default=MATERIALIZED, repr=False)
    _error: Optional[BaseException] = dataclasses.field(default=None, repr=False)
    _ready: Optional[threading.Event] = dataclasses.field(default=None, repr=False)
    #: the session's MemoryGovernor, attached at registration; handles its
    #: spill/refill + accounting. None for governor-less (unit-test) handles.
    _governor: Optional[object] = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        # Only handles explicitly constructed as PENDING (Session.
        # new_pending_handle) get a materialization event. A metadata-only
        # handle built without data stays MATERIALIZED-with-no-data so that
        # data() fast-fails with HandleError instead of blocking on an event
        # nothing will ever set.
        if self._state == PENDING and self._ready is None:
            self._ready = threading.Event()

    # -- lifecycle ----------------------------------------------------------
    @property
    def state(self) -> str:
        return self._state

    def materialize(self, data: jax.Array, pads: Tuple[int, int] = (0, 0)) -> None:
        """Engine-side: attach the resident (physical) array to a pending
        handle; ``pads`` is its divisibility padding over the logical shape."""
        if self._state == FREED:
            raise HandleError(f"AlMatrix {self.id} materialized after free()")
        self._data = data
        self.pads = (int(pads[0]), int(pads[1]))
        self._state = MATERIALIZED
        if self._ready is not None:
            self._ready.set()

    def fail(self, exc: BaseException) -> None:
        """Engine-side: the producing task died; data() will re-raise."""
        self._error = exc
        self._state = FAILED
        if self._ready is not None:
            self._ready.set()

    def free(self) -> None:
        """Release engine-side storage (the client keeps only metadata)."""
        self._data = None
        self._state = FREED
        if self._governor is not None:
            self._governor.discard(self)  # drop host-store bytes + accounting
        if self._ready is not None:
            self._ready.set()  # unblock any waiter; data() raises HandleError

    # -- data access --------------------------------------------------------
    def data(self, timeout: Optional[float] = None) -> jax.Array:
        """Engine-internal accessor. Client code should use ctx.collect().

        Blocks while the handle is pending (its producing task has not run
        yet); raises HandleError once freed, TaskError if the producer failed.
        A spilled handle is transparently refilled by the session's memory
        governor; a padded one is sliced back to its logical shape.
        """
        if self._state == PENDING and self._ready is not None:
            if not self._ready.wait(timeout):
                raise TaskError(
                    f"AlMatrix {self.id} ({self.name!r}) still pending after {timeout}s"
                )
        if self._governor is not None:
            # Governed read: hold the governor lock across the whole
            # check-refill-slice sequence so a concurrent spill on the queue
            # worker can never null _data between our check and the slice.
            with self._governor.lock:
                return self._read()
        return self._read()

    def _read(self) -> jax.Array:
        if self._state == SPILLED:
            if self._governor is None:
                raise HandleError(
                    f"AlMatrix {self.id} ({self.name!r}) is spilled with no governor"
                )
            self._governor.refill(self)
        if self._state == FREED:
            raise HandleError(f"AlMatrix {self.id} ({self.name!r}) has been freed")
        if self._state == FAILED:
            raise TaskError(
                f"AlMatrix {self.id} ({self.name!r}) failed to materialize"
            ) from self._error
        if self._data is None:
            raise HandleError(f"AlMatrix {self.id} ({self.name!r}) has no resident data")
        if self._governor is not None:
            self._governor.touch(self)
        if self.pads != (0, 0):
            return self._data[: self.shape[0], : self.shape[1]]
        return self._data

    @property
    def is_live(self) -> bool:
        """Usable as a routine input: pending (producer queued), resident, or
        spilled (host-side; refilled on next read). Freed/failed handles must
        be re-produced — the planner's resident cache keys off this to decide
        reuse vs re-send."""
        return self._state in (PENDING, MATERIALIZED, SPILLED)

    # -- metadata -----------------------------------------------------------
    @property
    def num_rows(self) -> int:
        return self.shape[0]

    @property
    def num_cols(self) -> int:
        return self.shape[1]

    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * jax.numpy.dtype(self.dtype).itemsize

    def physical_nbytes(self) -> int:
        """Device-resident footprint: logical extent plus divisibility pads.
        This is what the memory governor charges against the HBM budget."""
        n = 1
        for d, p in zip(self.shape, self.pads):
            n *= d + p
        return n * jax.numpy.dtype(self.dtype).itemsize

    @property
    def _freed(self) -> bool:  # backwards-compat for older callers
        return self._state == FREED

    def __repr__(self) -> str:  # keep reprs small in logs
        return (
            f"AlMatrix(id={self.id}, shape={self.shape}, dtype={jax.numpy.dtype(self.dtype).name}, "
            f"layout={self.layout.name}, session={self.session_id}, state={self._state}"
            + (f", name={self.name!r}" if self.name else "")
            + ")"
        )
