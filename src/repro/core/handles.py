"""AlMatrix handles — proxies for engine-resident distributed matrices.

Paper §3.3: "Alchemist uses matrix handles in the form of AlMatrix objects,
which act as proxies for the distributed data sets stored on Alchemist. ...
Only when the user explicitly converts this object into an RDD will the data
in the matrix be sent between Alchemist to Spark."

Here the handle wraps an engine-resident ``jax.Array`` plus its layout tag.
Chained library calls pass handles; `AlchemistContext.collect()` is the only
path that reshards data back to the client's row layout — so, exactly as in
the paper, the bridge is crossed only on explicit request.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Tuple

import jax

from repro.core.errors import HandleError
from repro.core.layouts import LayoutSpec

_ID_COUNTER = itertools.count(1)


@dataclasses.dataclass
class AlMatrix:
    """Handle to a matrix resident on the engine's worker group.

    Attributes:
      id: unique handle id (per engine process).
      shape/dtype: logical matrix metadata (always known to the client).
      layout: engine-side layout the data is stored in.
      session_id: owning session; handles are session-scoped like the paper's
        per-application matrix namespaces.
      name: optional human label for logs.
    """

    shape: Tuple[int, int]
    dtype: jax.numpy.dtype
    layout: LayoutSpec
    session_id: int
    name: str = ""
    id: int = dataclasses.field(default_factory=lambda: next(_ID_COUNTER))
    _data: Optional[jax.Array] = dataclasses.field(default=None, repr=False)
    _freed: bool = dataclasses.field(default=False, repr=False)

    @property
    def num_rows(self) -> int:
        return self.shape[0]

    @property
    def num_cols(self) -> int:
        return self.shape[1]

    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * jax.numpy.dtype(self.dtype).itemsize

    def data(self) -> jax.Array:
        """Engine-internal accessor. Client code should use ctx.collect()."""
        if self._freed:
            raise HandleError(f"AlMatrix {self.id} ({self.name!r}) has been freed")
        if self._data is None:
            raise HandleError(f"AlMatrix {self.id} ({self.name!r}) has no resident data")
        return self._data

    def free(self) -> None:
        """Release engine-side storage (the client keeps only metadata)."""
        self._data = None
        self._freed = True

    def __repr__(self) -> str:  # keep reprs small in logs
        return (
            f"AlMatrix(id={self.id}, shape={self.shape}, dtype={jax.numpy.dtype(self.dtype).name}, "
            f"layout={self.layout.name}, session={self.session_id}"
            + (f", name={self.name!r}" if self.name else "")
            + ")"
        )
