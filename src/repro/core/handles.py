"""AlMatrix handles — proxies for engine-resident distributed matrices.

Paper §3.3: "Alchemist uses matrix handles in the form of AlMatrix objects,
which act as proxies for the distributed data sets stored on Alchemist. ...
Only when the user explicitly converts this object into an RDD will the data
in the matrix be sent between Alchemist to Spark."

Here the handle wraps an engine-resident ``jax.Array`` plus its layout tag.
Chained library calls pass handles; `AlchemistContext.collect()` is the only
path that reshards data back to the client's row layout — so, exactly as in
the paper, the bridge is crossed only on explicit request.

With the asynchronous task-queue engine (DESIGN.md §3-§4) a handle has a
lifecycle::

    pending ──materialize()──▶ materialized ──free()──▶ freed
        │
        └──fail(exc)──▶ failed        (data() re-raises, wrapped in TaskError)

``send_async`` creates the handle immediately in the *pending* state — shape
and dtype are known up front, so metadata-only operations (and packing the
handle into a parameter frame) never wait — and the session's queue worker
materializes it when the transfer actually runs. ``data()`` on a pending
handle blocks until materialization; within one session that never happens
(the FIFO queue materializes producers before consumers run), but a handle
shared across engine internals may legitimately wait.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Optional, Tuple

import jax

from repro.core.errors import HandleError, TaskError
from repro.core.layouts import LayoutSpec

_ID_COUNTER = itertools.count(1)

# Handle lifecycle states.
PENDING = "pending"
MATERIALIZED = "materialized"
FAILED = "failed"
FREED = "freed"


@dataclasses.dataclass
class AlMatrix:
    """Handle to a matrix resident on the engine's worker group.

    Attributes:
      id: unique handle id (per engine process).
      shape/dtype: logical matrix metadata (always known to the client).
      layout: engine-side layout the data is stored in.
      session_id: owning session; handles are session-scoped like the paper's
        per-application matrix namespaces.
      name: optional human label for logs.
    """

    shape: Tuple[int, int]
    dtype: jax.numpy.dtype
    layout: LayoutSpec
    session_id: int
    name: str = ""
    id: int = dataclasses.field(default_factory=lambda: next(_ID_COUNTER))
    _data: Optional[jax.Array] = dataclasses.field(default=None, repr=False)
    _state: str = dataclasses.field(default=MATERIALIZED, repr=False)
    _error: Optional[BaseException] = dataclasses.field(default=None, repr=False)
    _ready: Optional[threading.Event] = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        # Only handles explicitly constructed as PENDING (Session.
        # new_pending_handle) get a materialization event. A metadata-only
        # handle built without data stays MATERIALIZED-with-no-data so that
        # data() fast-fails with HandleError instead of blocking on an event
        # nothing will ever set.
        if self._state == PENDING and self._ready is None:
            self._ready = threading.Event()

    # -- lifecycle ----------------------------------------------------------
    @property
    def state(self) -> str:
        return self._state

    def materialize(self, data: jax.Array) -> None:
        """Engine-side: attach the resident array to a pending handle."""
        if self._state == FREED:
            raise HandleError(f"AlMatrix {self.id} materialized after free()")
        self._data = data
        self._state = MATERIALIZED
        if self._ready is not None:
            self._ready.set()

    def fail(self, exc: BaseException) -> None:
        """Engine-side: the producing task died; data() will re-raise."""
        self._error = exc
        self._state = FAILED
        if self._ready is not None:
            self._ready.set()

    def free(self) -> None:
        """Release engine-side storage (the client keeps only metadata)."""
        self._data = None
        self._state = FREED
        if self._ready is not None:
            self._ready.set()  # unblock any waiter; data() raises HandleError

    # -- data access --------------------------------------------------------
    def data(self, timeout: Optional[float] = None) -> jax.Array:
        """Engine-internal accessor. Client code should use ctx.collect().

        Blocks while the handle is pending (its producing task has not run
        yet); raises HandleError once freed, TaskError if the producer failed.
        """
        if self._state == PENDING and self._ready is not None:
            if not self._ready.wait(timeout):
                raise TaskError(
                    f"AlMatrix {self.id} ({self.name!r}) still pending after {timeout}s"
                )
        if self._state == FREED:
            raise HandleError(f"AlMatrix {self.id} ({self.name!r}) has been freed")
        if self._state == FAILED:
            raise TaskError(
                f"AlMatrix {self.id} ({self.name!r}) failed to materialize"
            ) from self._error
        if self._data is None:
            raise HandleError(f"AlMatrix {self.id} ({self.name!r}) has no resident data")
        return self._data

    @property
    def is_live(self) -> bool:
        """Usable as a routine input: pending (producer queued) or resident.
        Freed/failed handles must be re-produced — the planner's resident
        cache keys off this to decide reuse vs re-send."""
        return self._state in (PENDING, MATERIALIZED)

    # -- metadata -----------------------------------------------------------
    @property
    def num_rows(self) -> int:
        return self.shape[0]

    @property
    def num_cols(self) -> int:
        return self.shape[1]

    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * jax.numpy.dtype(self.dtype).itemsize

    @property
    def _freed(self) -> bool:  # backwards-compat for older callers
        return self._state == FREED

    def __repr__(self) -> str:  # keep reprs small in logs
        return (
            f"AlMatrix(id={self.id}, shape={self.shape}, dtype={jax.numpy.dtype(self.dtype).name}, "
            f"layout={self.layout.name}, session={self.session_id}, state={self._state}"
            + (f", name={self.name!r}" if self.name else "")
            + ")"
        )
