"""ExecutionPolicy — pluggable execution strategies for the v2 client surface.

DESIGN.md §9. Before v2 the repo exposed *three* parallel client APIs — eager
``ac.send/run/collect``, async ``send_async/run_async`` futures, and the
planner's ``LazyMatrix`` DAG — each with its own handle type and failure
surface. The Cray follow-up (arXiv:1910.01354) keeps multiple frontends
maintainable only behind one coherent core interface; v2 collapses the choice
into a *policy object* selected per session (or per ``with session.policy(...)``
scope), not per call:

- :class:`Eager`     — every :class:`~repro.core.client.AlArray` node is
  lowered and resolved the moment it is built: the call blocks until its
  engine-side value exists, exactly like the v1 synchronous API.
- :class:`Pipelined` — nodes are lowered (dispatched onto the session's task
  queue) as they are built but never waited on: transfers and compute
  pipeline like the v1 ``*_async`` surface, with uniform ``AlArray`` handles
  instead of raw futures.
- :class:`Planned`   — the default. Nothing executes until a result is
  demanded (``.data()`` / ``.result()`` / ``await``); the whole DAG reaches
  the :class:`~repro.core.planner.OffloadPlanner` at once, so CSE,
  content-dedup, and bridge-crossing elision see maximal scope.

All three build the *same* expression DAG and execute through the *same*
planner and task queue — a policy only chooses **when** lowering happens, so
results are bit-identical across policies (the v2 acceptance property).

The same three objects also back the legacy
:class:`~repro.linalg.wrappers.LibraryWrapper` namespaces (``el.<routine>`` /
``el.submit.<routine>`` / ``el.lazy.<routine>``) through :meth:`dispatch`, so
the wrapper's per-kind closures collapsed into one policy-routed call path.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any, Dict, Tuple, Type, Union

from repro.core import futures as futures_mod
from repro.core.errors import SessionError

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.core.client import ClientCore
    from repro.core.planner import OffloadPlanner


class ExecutionPolicy:
    """How (and when) a session executes the expression nodes it builds.

    Subclasses override two hooks:

    - :meth:`apply` — called by the v2 :class:`~repro.core.client.Session`
      each time an :class:`~repro.core.client.AlArray` node is built; decides
      whether lowering happens now (and whether to block on it).
    - :meth:`dispatch` — called by the legacy library-wrapper namespaces with
      a raw ``(library, routine, args, params)`` invocation; returns whatever
      that namespace historically returned (resolved values, an
      :class:`~repro.core.futures.AlFuture`, or a
      :class:`~repro.core.expr.LazyMatrix`).

    Policies are stateless and shareable across sessions; ``Eager()``,
    ``Eager``, and the string ``"eager"`` all resolve to the same behaviour
    through :func:`as_policy`.
    """

    name: str = "policy"

    # -- v2 surface -----------------------------------------------------------
    def apply(self, planner: "OffloadPlanner", lazy: Any) -> None:
        """An ``AlArray`` node was just built under this policy."""
        raise NotImplementedError

    # -- legacy wrapper surface ----------------------------------------------
    def dispatch(
        self,
        ac: "ClientCore",
        library: str,
        routine: str,
        args: Tuple[Any, ...],
        params: Dict[str, Any],
        n_outputs: int = 1,
    ) -> Any:
        """One routine invocation from a wrapper namespace."""
        raise NotImplementedError

    def _reject_n_outputs(self, n_outputs: int) -> None:
        if n_outputs != 1:
            raise SessionError(
                f"n_outputs is a planner concept; the {self.name} policy returns "
                "the routine's full result — use Planned (or the .lazy namespace) "
                "to project individual outputs"
            )

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


class Eager(ExecutionPolicy):
    """Execute every node as it is built; block until its value exists."""

    name = "eager"

    def apply(self, planner: "OffloadPlanner", lazy: Any) -> None:
        # Lower and wait: by the time the call returns, the node's engine-side
        # value (handle or driver scalar) is materialized — v1 sync semantics.
        futures_mod.resolve(planner.lower(lazy))

    def dispatch(self, ac, library, routine, args, params, n_outputs=1):
        self._reject_n_outputs(n_outputs)
        return ac.run_eager(library, routine, *args, **params)


class Pipelined(ExecutionPolicy):
    """Dispatch every node as it is built; never wait (v1 async semantics)."""

    name = "pipelined"

    def apply(self, planner: "OffloadPlanner", lazy: Any) -> None:
        planner.lower(lazy)  # enqueue, don't block

    def dispatch(self, ac, library, routine, args, params, n_outputs=1):
        self._reject_n_outputs(n_outputs)
        return ac.run_async(library, routine, *args, **params)


class Planned(ExecutionPolicy):
    """Defer everything until a result is demanded (the v2 default)."""

    name = "planned"

    def apply(self, planner: "OffloadPlanner", lazy: Any) -> None:
        pass  # the force (.data()/.result()/await) lowers the whole DAG

    def dispatch(self, ac, library, routine, args, params, n_outputs=1):
        return ac.planner.run(library, routine, *args, n_outputs=n_outputs, **params)


#: accepted spellings for each policy, for ``connect(policy=...)`` and
#: ``session.policy(...)``.
_POLICIES: Dict[str, Type[ExecutionPolicy]] = {
    "eager": Eager,
    "pipelined": Pipelined,
    "planned": Planned,
}

PolicyLike = Union[ExecutionPolicy, Type[ExecutionPolicy], str, None]


def as_policy(policy: PolicyLike, default: Type[ExecutionPolicy] = Planned) -> ExecutionPolicy:
    """Normalize a policy spec — instance, class, name, or None — to an
    :class:`ExecutionPolicy` instance."""
    if policy is None:
        return default()
    if isinstance(policy, ExecutionPolicy):
        return policy
    if isinstance(policy, type) and issubclass(policy, ExecutionPolicy):
        return policy()
    if isinstance(policy, str):
        cls = _POLICIES.get(policy.lower())
        if cls is not None:
            return cls()
        raise SessionError(
            f"unknown execution policy {policy!r}; choose from {sorted(_POLICIES)}"
        )
    raise SessionError(f"cannot interpret {policy!r} as an ExecutionPolicy")
