"""MemoryGovernor — budgeted spill/refill of engine-resident matrices.

DESIGN.md §7. Alchemist's value proposition is keeping matrices resident on
the engine so drivers avoid repeated transfers (arXiv:1806.01270), but the
resident-matrix cache pins everything in HBM until an explicit free — exactly
the memory pressure the deployment follow-up flags as the limiting factor for
long offload pipelines (arXiv:1910.01354). The governor bounds it:

- every materialized :class:`~repro.core.handles.AlMatrix` is **charged** its
  physical byte footprint (logical extent plus divisibility padding) against
  a per-session HBM budget;
- before a send stages bytes or a routine materializes outputs, the task
  **admits** the incoming footprint: least-recently-used resident matrices —
  preferring ones the offload planner has hinted as past their DAG last use —
  are **spilled** to a pinned host store (``jax.device_get``) until the new
  bytes fit;
- a spilled handle stays *live*: its next consumption (``data()``) triggers a
  transparent **refill** — a ``device_put`` through the session's cached
  relayout plan — so pipelines whose working set exceeds the budget complete
  with identical numerics, just extra host↔device traffic;
- ``reserve``/``unreserve`` track bytes promised by not-yet-executed queued
  tasks (``send_async``/``run_async`` reserve before enqueueing), so
  ``pressure()`` forecasts demand beyond what is already resident.

The governor is deliberately an *accounting* model — it charges the bytes the
engine placed, rather than querying allocator internals — which keeps the
policy identical on emulated-CPU meshes and real HBM. All spill/refill
mutations run on the session's single task-queue worker; the lock only guards
the counters that client threads read (reservations, stats snapshots).

With ``budget=None`` (the default) nothing spills and the governor is pure
bookkeeping: ``hbm_high_water`` still lands in ``session.stats.summary()``.
"""

from __future__ import annotations

import contextlib
import itertools
import threading
from typing import TYPE_CHECKING, Dict, Iterable, List, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import handles as handles_mod
from repro.core.errors import HandleError
from repro.core.handles import AlMatrix

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.core.session import Session

_CLOCK = itertools.count(1)


class MemoryGovernor:
    """Per-session HBM budget: charge, spill, refill (DESIGN.md §7)."""

    def __init__(self, budget: Optional[int] = None, name: str = "memgov"):
        if budget is not None and budget <= 0:
            raise ValueError(f"hbm budget must be positive or None, got {budget}")
        self.budget = budget
        self.name = name
        self._session: Optional["Session"] = None
        self._lock = threading.RLock()
        # handle id -> handle, for every charged (materialized or spilled)
        # matrix; _charged holds the bytes each one was charged at.
        self._handles: Dict[int, AlMatrix] = {}
        self._charged: Dict[int, int] = {}
        # the pinned host store: physical (padded) payloads of spilled handles
        self._host_store: Dict[int, np.ndarray] = {}
        self._touch: Dict[int, int] = {}
        self._pin_counts: Dict[int, int] = {}
        self._idle: Set[int] = set()  # planner last-use hints: spill these first
        self._used = 0
        self._reserved = 0

    def bind(self, session: "Session") -> None:
        """Attach the owning session (mesh + relayout cache + stats)."""
        self._session = session

    def set_budget(self, budget: Optional[int]) -> None:
        """Change the budget (e.g. a scoped override via
        ``offload.offloaded(ac, hbm_budget=...)``), with the same validation
        as construction. Serialized against admissions: an admit() in flight
        on the queue worker finishes under the budget it snapshotted."""
        if budget is not None and budget <= 0:
            raise ValueError(f"hbm budget must be positive or None, got {budget}")
        with self._lock:
            self.budget = budget

    @property
    def lock(self) -> threading.RLock:
        """The governor's reentrant lock. Handle reads hold it across the
        check-refill-slice sequence (`AlMatrix.data()`), so a client-thread
        read can never observe a half-spilled handle from the queue worker."""
        return self._lock

    # -- accounting ----------------------------------------------------------
    @property
    def used(self) -> int:
        """Bytes currently charged against the budget (device-resident)."""
        return self._used

    @property
    def reserved(self) -> int:
        """Bytes promised by queued-but-not-yet-executed tasks."""
        return self._reserved

    def pressure(self) -> int:
        """Forecast demand: resident bytes plus outstanding reservations."""
        with self._lock:
            return self._used + self._reserved

    def reserve(self, nbytes: int) -> int:
        """Client-side, before enqueueing: promise ``nbytes`` of residency.
        Returns the reservation size (pass it back to :meth:`unreserve`)."""
        nbytes = max(int(nbytes), 0)
        with self._lock:
            self._reserved += nbytes
        return nbytes

    def unreserve(self, nbytes: int) -> None:
        """Task-side: the reservation was converted to a charge (or the task
        failed); drop it from the forecast."""
        with self._lock:
            self._reserved = max(self._reserved - max(int(nbytes), 0), 0)

    # -- admission -----------------------------------------------------------
    def admit(self, nbytes: int, exclude: Iterable[int] = ()) -> int:
        """Make room for ``nbytes`` of incoming residency: spill unpinned
        victims (planner-hinted idle first, then least-recently-used) until
        ``used + nbytes`` fits the budget. Returns the number of spills.

        Admission is *best effort*: if everything else is pinned or the
        incoming matrix alone exceeds the budget, the bytes are admitted
        anyway — the governor bounds memory, it never deadlocks the pipeline.
        """
        nbytes = max(int(nbytes), 0)
        spills = 0
        excluded = set(exclude)
        # The pick-spill window runs under the lock: a concurrent refill on
        # another thread (itself an admission) must not spill our chosen
        # victim between the pick and the spill. The budget is snapshotted
        # under the same lock — a scoped override expiring mid-admission
        # (offloaded() exit flips it back to None) must not yank the loop's
        # comparison out from under it.
        with self._lock:
            budget = self.budget
            if budget is None:
                return 0
            while self._used + nbytes > budget:
                victim = self._pick_victim(excluded)
                if victim is None:
                    break
                self.spill(victim)
                spills += 1
        return spills

    def _pick_victim(self, excluded: Set[int]) -> Optional[AlMatrix]:
        with self._lock:
            candidates: List[AlMatrix] = [
                h
                for hid, h in self._handles.items()
                if hid not in excluded
                and not self._pin_counts.get(hid)
                and h.state == handles_mod.MATERIALIZED
                and h._data is not None
            ]
            if not candidates:
                return None
            # Planner-hinted idle matrices (past their DAG last use) first,
            # then least-recently-touched.
            return min(
                candidates,
                key=lambda h: (h.id not in self._idle, self._touch.get(h.id, 0)),
            )

    # -- charge / discard ----------------------------------------------------
    def charge(self, h: AlMatrix) -> None:
        """Register a newly materialized matrix and charge its footprint."""
        h._governor = self
        nbytes = h.physical_nbytes()
        with self._lock:
            prev = self._charged.get(h.id, 0)
            self._handles[h.id] = h
            self._charged[h.id] = nbytes
            self._used += nbytes - prev
            self._touch[h.id] = next(_CLOCK)
            self._idle.discard(h.id)
            self._record_high_water()

    def discard(self, h: AlMatrix) -> None:
        """The handle was freed: drop its charge and any host-store bytes."""
        with self._lock:
            self._handles.pop(h.id, None)
            self._used -= self._charged.pop(h.id, 0)
            self._host_store.pop(h.id, None)
            self._touch.pop(h.id, None)
            self._pin_counts.pop(h.id, None)
            self._idle.discard(h.id)

    def touch(self, h: AlMatrix) -> None:
        """Record a consumption: resets LRU age and clears any idle hint."""
        with self._lock:
            if h.id in self._handles:
                self._touch[h.id] = next(_CLOCK)
                self._idle.discard(h.id)

    def hint_idle(self, h: AlMatrix) -> None:
        """Planner hint: the DAG holds no further uses of this matrix — make
        it a preferred spill victim (it may still be collected or reused; a
        hint is a priority, not a free)."""
        with self._lock:
            if h.id in self._handles:
                self._idle.add(h.id)

    @contextlib.contextmanager
    def pinned(self, hs: Iterable[AlMatrix]):
        """Keep ``hs`` unspillable while a task consumes them (a refilled
        input must not be re-spilled by the admission of the next one)."""
        ids = [h.id for h in hs if isinstance(h, AlMatrix)]
        with self._lock:
            for hid in ids:
                self._pin_counts[hid] = self._pin_counts.get(hid, 0) + 1
        try:
            yield
        finally:
            with self._lock:
                for hid in ids:
                    left = self._pin_counts.get(hid, 1) - 1
                    if left > 0:
                        self._pin_counts[hid] = left
                    else:
                        self._pin_counts.pop(hid, None)

    # -- spill / refill ------------------------------------------------------
    def spill(self, h: AlMatrix) -> None:
        """Move a resident matrix's physical bytes to the host store.

        The whole transition runs under the governor lock: a concurrent
        ``data()`` on another thread (handles hold the same lock across its
        check-refill-slice sequence) sees the handle either fully resident or
        fully spilled, never ``_data is None`` mid-flight.
        """
        with self._lock:
            if h.state != handles_mod.MATERIALIZED or h._data is None:
                raise HandleError(f"cannot spill AlMatrix {h.id} in state {h.state!r}")
            host = np.asarray(jax.device_get(h._data))
            nbytes = self._charged.get(h.id, h.physical_nbytes())
            self._host_store[h.id] = host
            self._used -= nbytes
            self._charged[h.id] = 0
            h._data = None
            h._state = handles_mod.SPILLED
        stats = self._stats()
        if stats is not None:
            stats.record_spill(nbytes)

    def refill(self, h: AlMatrix) -> None:
        """Re-place a spilled matrix on the worker group. Runs on the first
        consumption after the spill (``AlMatrix.data()``); uses the session's
        cached relayout plan for the ``device_put`` and may itself spill other
        matrices to make room. Atomic under the governor lock, like spill."""
        with self._lock:
            host = self._host_store.get(h.id)
            if host is None or self._session is None:
                raise HandleError(
                    f"AlMatrix {h.id} ({h.name!r}) has no spilled payload to refill"
                )
            self.admit(host.nbytes, exclude={h.id})
            sess = self._session
            # The host payload is the *physical* (already padded, already
            # permuted) form, so src == dst: the cached plan is a pure
            # placement — no permutation, and pads only if this physical
            # shape was born unpadded (a routine output) and needs them for
            # the device_put.
            plan, _hit = sess.relayout_cache.plan(
                tuple(host.shape), host.dtype, h.layout, h.layout, sess.mesh
            )
            arr = plan.apply(jnp.asarray(host))
            h._data = arr
            h.pads = (arr.shape[0] - h.shape[0], arr.shape[1] - h.shape[1])
            h._state = handles_mod.MATERIALIZED
            self._host_store.pop(h.id, None)
            self.charge(h)
        stats = self._stats()
        if stats is not None:
            stats.record_refill(int(host.nbytes))

    def host_payload(self, h: AlMatrix) -> Optional[np.ndarray]:
        """The spilled physical payload, or None if ``h`` is not spilled.
        Lets the collect path serve client-bound bytes straight from the
        host store — no refill, no admission cascade — while the handle
        stays spilled for any later engine-side consumption."""
        with self._lock:
            return self._host_store.get(h.id)

    # -- introspection -------------------------------------------------------
    def spilled_handles(self) -> List[AlMatrix]:
        with self._lock:
            return [h for h in self._handles.values() if h.state == handles_mod.SPILLED]

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {
                "budget": self.budget or 0,
                "used": self._used,
                "reserved": self._reserved,
                "resident_handles": sum(
                    1
                    for h in self._handles.values()
                    if h.state == handles_mod.MATERIALIZED
                ),
                "spilled_handles": len(self._host_store),
                "host_store_bytes": sum(a.nbytes for a in self._host_store.values()),
            }

    def clear(self) -> None:
        """Session teardown: drop every charge and host-store payload."""
        with self._lock:
            self._handles.clear()
            self._charged.clear()
            self._host_store.clear()
            self._touch.clear()
            self._pin_counts.clear()
            self._idle.clear()
            self._used = 0
            self._reserved = 0

    def _stats(self):
        return self._session.stats if self._session is not None else None

    def _record_high_water(self) -> None:
        # caller holds self._lock
        stats = self._stats()
        if stats is not None:
            stats.record_hbm_usage(self._used)

    def __repr__(self) -> str:
        s = self.snapshot()
        return (
            f"MemoryGovernor(budget={s['budget']}, used={s['used']}, "
            f"resident={s['resident_handles']}, spilled={s['spilled_handles']})"
        )
