"""MemoryGovernor — engine-wide budgeted spill/refill of resident matrices.

DESIGN.md §7/§8. Alchemist's value proposition is keeping matrices resident
on the engine so drivers avoid repeated transfers (arXiv:1806.01270), but
residency pins HBM until an explicit free — exactly the memory pressure the
deployment follow-up flags as the limiting factor for long offload pipelines
(arXiv:1910.01354). The governor bounds it, and it bounds it **engine-wide**:
one governor per :class:`~repro.core.engine.AlchemistEngine`, shared by every
connected session, so multi-tenant pressure is charged against a single
budget instead of N independent ones that sum to N× the hardware.

- every materialized :class:`~repro.core.handles.AlMatrix` of every session
  is **charged** its physical byte footprint (logical extent plus
  divisibility padding) against the shared budget;
- before a send/attach stages bytes or a routine materializes outputs, the
  task **admits** the incoming footprint: least-recently-used resident
  matrices — preferring ones a planner has hinted as past their DAG last
  use — are **spilled** until the new bytes fit. Victims are chosen *across
  sessions*, but a matrix pinned by a live run in any session is never
  spilled;
- a spilled handle stays *live*: its next consumption (``data()``) triggers
  a transparent **refill** through its own session's cached relayout plan.
  Store-backed placements (DESIGN.md §8) spill for free — their logical
  payload already sits host-side on the entry, so the spill just drops the
  device bytes and the refill re-places from the payload;
- ``reserve``/``unreserve`` track bytes promised by not-yet-executed queued
  tasks across all sessions, so ``pressure()`` forecasts engine demand.

The **effective budget** is the minimum of the engine's base budget
(``AlchemistEngine(hbm_budget=...)`` or :meth:`set_budget`) and every live
session's requested budget (``AlchemistContext(hbm_budget=...)`` →
:meth:`request_budget`): the most conservative live constraint wins, which
keeps single-session semantics identical to the old per-session governor
while giving concurrent sessions one shared ceiling.

The governor is deliberately an *accounting* model — it charges the bytes
the engine placed, rather than querying allocator internals — which keeps
the policy identical on emulated-CPU meshes and real HBM. Per-handle stats
(spill/refill/high-water) land on the owning session's ``SessionStats``;
:attr:`high_water` tracks the engine-wide maximum for multi-tenant gates.

With no budget anywhere (the default) nothing spills and the governor is
pure bookkeeping.

**The asynchronous data plane (DESIGN.md §10).** Spill copy-outs are enqueued
onto a dedicated :class:`~repro.core.taskqueue.TransferExecutor` (a bounded
double-buffer ring) so the owning session's queue worker overlaps the next
task's compute with the previous victim's D2H. Only the *state transition*
runs under the governor lock; the bytes stream on the transfer thread, with
an ``in_flight_spill_bytes`` ledger tracking victims whose device reference
is still held pending copy. A refill of a still-in-flight victim *joins* the
pending copy — it cancels the job and restores the retained device array,
zero copies — and a collect of one waits on the job's event. Host staging
buffers come from a small reuse pool and are donated back after refill,
eliminating one host copy per spill/refill cycle; a buffer served to a client
(``host_payload``) is marked read-only and never recycled, and a buffer the
refill's zero-copy ``device_put`` aliased stays owned by the device array
(pooling it would let a later gather corrupt the resident matrix).
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import threading
import time
from typing import TYPE_CHECKING, Dict, Iterable, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import handles as handles_mod
from repro.core.errors import HandleError
from repro.core.handles import AlMatrix
from repro.core.relayout import FUSED_PATHS, pad_amounts
from repro.core.taskqueue import TransferExecutor

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.core.session import Session

_CLOCK = itertools.count(1)


@dataclasses.dataclass
class _SpillJob:
    """One victim's pending copy-out on the transfer ring.

    ``array`` holds the device reference until the copy lands (or a refill
    joins / a free cancels); whoever nulls it under the governor lock also
    decrements the in-flight ledger, exactly once. ``event`` is set when the
    job reaches a terminal state (done, cancelled, failed) — collect-side
    waiters key off it.
    """

    handle: AlMatrix
    array: Optional[jax.Array]
    nbytes: int
    state: str = "queued"  # queued -> copying -> done | cancelled | failed
    event: threading.Event = dataclasses.field(default_factory=threading.Event)


class _StagingPool:
    """Small pool of reusable host staging buffers for spill copy-outs.

    ``release`` refuses read-only buffers: ``host_payload`` marks a buffer
    read-only the moment it escapes to a client (collects may serve it
    zero-copy), so an escaped buffer can never be handed to a later spill's
    ``gather`` and corrupted under the client.
    """

    def __init__(self, max_buffers: int = 4):
        self._free: List[np.ndarray] = []
        self._lock = threading.Lock()
        self.max_buffers = max_buffers
        self.reuses = 0

    def acquire(self, shape, dtype) -> np.ndarray:
        with self._lock:
            for i, buf in enumerate(self._free):
                if buf.shape == tuple(shape) and buf.dtype == dtype:
                    self.reuses += 1
                    return self._free.pop(i)
        return np.empty(tuple(shape), dtype)

    def release(self, buf) -> None:
        if not isinstance(buf, np.ndarray) or not buf.flags.writeable:
            return  # escaped to a client, or a foreign (store-owned) payload
        with self._lock:
            if len(self._free) < self.max_buffers and all(b is not buf for b in self._free):
                self._free.append(buf)

    def clear(self) -> None:
        with self._lock:
            self._free.clear()


def _aliases_host(arr: jax.Array, host: np.ndarray) -> bool:
    """True if any device shard of ``arr`` shares memory with ``host``. On CPU
    backends a sharded/donated ``device_put`` of a numpy array is zero-copy —
    the placed array's backing store IS the host buffer — so a staging buffer
    aliased by a live device array must never return to the pool: a later
    spill's gather would write the victim's bytes straight through the alias
    into the resident matrix."""
    try:
        base = host.ctypes.data
        end = base + host.nbytes
        for shard in arr.addressable_shards:
            ptr = shard.data.unsafe_buffer_pointer()
            if base <= ptr < end:
                return True
        return False
    except Exception:  # pragma: no cover - exotic runtimes: assume aliased
        return True


def _validate_budget(budget: Optional[int]) -> Optional[int]:
    if budget is not None and budget <= 0:
        raise ValueError(f"hbm budget must be positive or None, got {budget}")
    return budget


class MemoryGovernor:
    """Engine-wide HBM budget: charge, spill, refill (DESIGN.md §7/§8)."""

    def __init__(
        self,
        budget: Optional[int] = None,
        name: str = "memgov",
        async_spill: bool = True,
    ):
        self._base_budget = _validate_budget(budget)
        self.name = name
        self._sessions: Dict[int, "Session"] = {}
        self._session_budgets: Dict[int, int] = {}
        self._lock = threading.RLock()
        # handle id -> handle, for every charged (materialized or spilled)
        # matrix of any session; _charged holds the bytes each one was
        # charged at.
        self._handles: Dict[int, AlMatrix] = {}
        self._charged: Dict[int, int] = {}
        # the pinned host store: physical (padded) payloads of spilled
        # handles that have no store-entry fallback to refill from.
        self._host_store: Dict[int, np.ndarray] = {}
        self._touch: Dict[int, int] = {}
        self._pin_counts: Dict[int, int] = {}
        self._idle: Set[int] = set()  # planner last-use hints: spill these first
        self._used = 0
        self._reserved = 0
        #: engine-wide maximum of simultaneously charged bytes — the number
        #: the multi-tenant acceptance gate bounds against the shared budget.
        self.high_water = 0
        # Asynchronous data plane (DESIGN.md §10): pending copy-outs by
        # handle id, the device bytes they still retain, the transfer ring
        # (built lazily on first async spill), and the host staging pool.
        self.async_spill = bool(async_spill)
        self._in_flight: Dict[int, _SpillJob] = {}
        self._in_flight_bytes = 0
        self._transfer: Optional[TransferExecutor] = None
        self._staging = _StagingPool()
        # Pressure watermarks (DESIGN.md §12): fractions of the effective
        # budget gating new *private* placements in the scheduler, with
        # hysteresis — block above high, resume only below low.
        self._watermarks: Optional[Tuple[float, float]] = None
        self._gated = False
        # Shared-group views (DESIGN.md §12): view handle id -> source handle
        # id. A view is never charged (its bytes belong to the source
        # placement); instead the source is pinned so it cannot be spilled
        # out from under a reader in another session.
        self._view_sources: Dict[int, int] = {}

    # -- session membership ---------------------------------------------------
    def attach_session(
        self, session: "Session", hbm_budget: Optional[int] = None
    ) -> None:
        """A session connected: route its handles' spill/refill through its
        mesh + relayout cache, and fold its requested budget into the shared
        ceiling. Validates the budget *before* registering anything — a
        rejected budget must not leave a ghost session in the engine-wide
        ledger."""
        _validate_budget(hbm_budget)
        with self._lock:
            self._sessions[session.id] = session
            if hbm_budget is not None:
                self._session_budgets[session.id] = hbm_budget

    def detach_session(self, session_id: int) -> None:
        """Session closed: its handles were freed/migrated by the session
        layer; drop its budget request from the shared ceiling."""
        with self._lock:
            self._sessions.pop(session_id, None)
            self._session_budgets.pop(session_id, None)

    def bind(self, session: "Session") -> None:
        """Backwards-compatible alias of :meth:`attach_session`."""
        self.attach_session(session)

    @property
    def budget(self) -> Optional[int]:
        """The effective shared budget: min over the engine's base budget and
        every live session's request; None when nothing constrains."""
        with self._lock:
            constraints = [b for b in self._session_budgets.values()]
            if self._base_budget is not None:
                constraints.append(self._base_budget)
            return min(constraints) if constraints else None

    @property
    def base_budget(self) -> Optional[int]:
        """The engine's own budget, before session requests tighten it — what
        a scoped override (``offloaded(hbm_budget=...)``) must save/restore;
        restoring the *effective* value would bake one session's request into
        the engine for good."""
        with self._lock:
            return self._base_budget

    def set_budget(self, budget: Optional[int]) -> None:
        """Change the engine's base budget (e.g. a scoped override via
        ``offload.offloaded(ac, hbm_budget=...)``), with the same validation
        as construction. Serialized against admissions: an admit() in flight
        on a queue worker finishes under the budget it snapshotted."""
        _validate_budget(budget)
        with self._lock:
            self._base_budget = budget

    def request_budget(self, session_id: int, budget: Optional[int]) -> None:
        """Fold a per-session budget request into the shared ceiling."""
        with self._lock:
            if budget is None:
                self._session_budgets.pop(session_id, None)
            else:
                self._session_budgets[session_id] = _validate_budget(budget)

    def requested_budget(self, session_id: int) -> Optional[int]:
        """The session's current budget request (None if it has none) — what
        a scoped per-session override must save and restore."""
        with self._lock:
            return self._session_budgets.get(session_id)

    @property
    def lock(self) -> threading.RLock:
        """The governor's reentrant lock. Handle reads hold it across the
        check-refill-slice sequence (`AlMatrix.data()`), so a client-thread
        read can never observe a half-spilled handle from a queue worker."""
        return self._lock

    @property
    def staging(self) -> _StagingPool:
        """The host staging-buffer pool — shared with the wire's shard-direct
        receive path (DESIGN.md §13), so slabs recycle across receives and
        spill copy-outs alike."""
        return self._staging

    def transfer_ring(self) -> TransferExecutor:
        """The bounded double-buffer transfer executor (DESIGN.md §10) —
        also the ring the shard-direct receiver rides for eager per-shard
        ``device_put``s overlapping socket reads."""
        return self._executor()

    def unbudgeted(self) -> bool:
        """True when no HBM budget constrains admission (engine-wide or
        per-session). The shard-direct receiver only issues *eager* device
        puts in this regime: under a budget, bytes may not land on device
        before ``admit()`` has made room, so puts defer to the send task."""
        with self._lock:
            return self._base_budget is None and not self._session_budgets

    # -- accounting ----------------------------------------------------------
    @property
    def used(self) -> int:
        """Bytes currently charged against the budget (device-resident)."""
        return self._used

    @property
    def reserved(self) -> int:
        """Bytes promised by queued-but-not-yet-executed tasks."""
        return self._reserved

    def pressure(self) -> int:
        """Forecast demand: resident bytes plus outstanding reservations."""
        with self._lock:
            return self._used + self._reserved

    def reserve(self, nbytes: int) -> int:
        """Client-side, before enqueueing: promise ``nbytes`` of residency.
        Returns the reservation size (pass it back to :meth:`unreserve`)."""
        nbytes = max(int(nbytes), 0)
        with self._lock:
            self._reserved += nbytes
        return nbytes

    def unreserve(self, nbytes: int) -> None:
        """Task-side: the reservation was converted to a charge (or the task
        failed); drop it from the forecast."""
        with self._lock:
            self._reserved = max(self._reserved - max(int(nbytes), 0), 0)

    # -- pressure watermarks (DESIGN.md §12) ---------------------------------
    def set_watermarks(self, high: float, low: float) -> None:
        """Enable (or retune) the admission pressure gate.

        ``high``/``low`` are fractions of the *effective* budget. When
        ``pressure()`` rises above ``high * budget`` new private placements
        stop admitting; they resume only once pressure falls below
        ``low * budget`` (hysteresis, so admission does not flap at the
        boundary). Pass via ``AlchemistEngine(pressure_watermarks=(h, l))``.
        """
        if not (0.0 < low <= high):
            raise ValueError(
                f"watermarks must satisfy 0 < low <= high, got high={high}, low={low}"
            )
        with self._lock:
            self._watermarks = (float(high), float(low))
            self._gated = False

    def clear_watermarks(self) -> None:
        """Disable the pressure gate (the free-pool count gates alone)."""
        with self._lock:
            self._watermarks = None
            self._gated = False

    @property
    def watermarks(self) -> Optional[Tuple[float, float]]:
        with self._lock:
            return self._watermarks

    @property
    def has_watermarks(self) -> bool:
        return self._watermarks is not None

    def admission_gate(self) -> bool:
        """True while governor pressure should block new private placements.

        With no watermarks (or no effective budget) the gate is always open.
        The hysteresis state flips closed when pressure exceeds the high
        watermark and reopens only below the low one.
        """
        with self._lock:
            if self._watermarks is None:
                return False
            budget = self.budget
            if budget is None:
                return False
            high, low = self._watermarks
            pressure = self._used + self._reserved
            if self._gated:
                if pressure < low * budget:
                    self._gated = False
            elif pressure > high * budget:
                self._gated = True
            return self._gated

    # -- admission -----------------------------------------------------------
    def admit(self, nbytes: int, exclude: Iterable[int] = ()) -> int:
        """Make room for ``nbytes`` of incoming residency — spilling unpinned
        victims (planner-hinted idle first, then least-recently-used, chosen
        across every session) until ``used + nbytes`` fits the shared budget —
        and **claim** the bytes: ``used`` grows by ``nbytes`` immediately, so
        a concurrent admission from another session cannot fill the approved
        room before the caller materializes into it (the engine-wide budget
        must hold across interleaved sessions, not just within one FIFO).
        Pair every admit with :meth:`settle` once the real charge landed (or
        the task failed). Returns the number of spills.

        Admission is *best effort*: if everything else is pinned or the
        incoming matrix alone exceeds the budget, the bytes are admitted
        anyway — the governor bounds memory, it never deadlocks the pipeline.
        """
        nbytes = max(int(nbytes), 0)
        spills = 0
        excluded = set(exclude)
        deferred: List[_SpillJob] = []
        # The pick-spill window runs under the lock: a concurrent refill on
        # another thread (itself an admission) must not spill our chosen
        # victim between the pick and the spill. The budget is snapshotted
        # under the same lock — a scoped override expiring mid-admission
        # (offloaded() exit flips it back) must not yank the loop's
        # comparison out from under it. Victim copy-outs land on the transfer
        # ring; when the ring is full they are deferred and copied
        # synchronously *after* the lock is released below (the satellite fix
        # for the old device_get-under-lock stall), so concurrent sessions'
        # reads never queue behind a bulk copy.
        with self._lock:
            budget = self.budget
            if budget is not None:
                while self._used + nbytes > budget:
                    victim = self._pick_victim(excluded)
                    if victim is None:
                        break
                    self.spill(victim, _deferred=deferred)
                    spills += 1
            self._used += nbytes
            self.high_water = max(self.high_water, self._used)
        for job in deferred:
            self._copy_out(job, on_ring=False)
        return spills

    def settle(self, nbytes: int) -> None:
        """Release an :meth:`admit` claim. Callers converting the claim into
        real charges do both under one lock hold —

            with memgov.lock:
                memgov.settle(admitted)
                memgov.charge(h)          # or new_handle(...), which charges

        — so no other session's admission can slip into the gap between the
        claim ending and the charge landing."""
        nbytes = max(int(nbytes), 0)
        with self._lock:
            self._used -= nbytes

    def _pick_victim(self, excluded: Set[int]) -> Optional[AlMatrix]:
        with self._lock:
            candidates: List[AlMatrix] = [
                h
                for hid, h in self._handles.items()
                if hid not in excluded
                and not self._pin_counts.get(hid)
                and h.state == handles_mod.MATERIALIZED
                and h._data is not None
            ]
            if not candidates:
                return None
            # Planner-hinted idle matrices (past their DAG last use) first,
            # then least-recently-touched — regardless of owning session.
            return min(
                candidates,
                key=lambda h: (h.id not in self._idle, self._touch.get(h.id, 0)),
            )

    # -- charge / discard ----------------------------------------------------
    def charge(self, h: AlMatrix) -> None:
        """Register a newly materialized matrix and charge its footprint."""
        h._governor = self
        nbytes = h.physical_nbytes()
        with self._lock:
            prev = self._charged.get(h.id, 0)
            self._handles[h.id] = h
            self._charged[h.id] = nbytes
            self._used += nbytes - prev
            self._touch[h.id] = next(_CLOCK)
            self._idle.discard(h.id)
            self._record_high_water(h)

    def discard(self, h: AlMatrix) -> None:
        """The handle was freed: drop its charge, any host-store bytes, and
        cancel a copy-out still in flight (its device reference just drops)."""
        with self._lock:
            self._handles.pop(h.id, None)
            self._used -= self._charged.pop(h.id, 0)
            popped = self._host_store.pop(h.id, None)
            if popped is not None:
                self._staging.release(popped)
            job = self._in_flight.pop(h.id, None)
            if job is not None:
                if job.array is not None:
                    job.array = None
                    self._in_flight_bytes -= job.nbytes
                job.state = "cancelled"
                job.event.set()
            self._touch.pop(h.id, None)
            self._pin_counts.pop(h.id, None)
            self._idle.discard(h.id)
            # Shared-group view teardown: the reader is gone, release its
            # pin on the source placement (which may itself already be gone
            # — the get() default absorbs that race).
            src_id = self._view_sources.pop(h.id, None)
            if src_id is not None:
                left = self._pin_counts.get(src_id, 0) - 1
                if left > 0:
                    self._pin_counts[src_id] = left
                else:
                    self._pin_counts.pop(src_id, None)

    def touch(self, h: AlMatrix) -> None:
        """Record a consumption: resets LRU age and clears any idle hint."""
        with self._lock:
            if h.id in self._handles:
                self._touch[h.id] = next(_CLOCK)
                self._idle.discard(h.id)

    def hint_idle(self, h: AlMatrix) -> None:
        """Planner hint: the DAG holds no further uses of this matrix — make
        it a preferred spill victim (it may still be collected or reused; a
        hint is a priority, not a free)."""
        with self._lock:
            if h.id in self._handles:
                self._idle.add(h.id)

    @contextlib.contextmanager
    def pinned(self, hs: Iterable[AlMatrix]):
        """Keep ``hs`` unspillable while a task consumes them (a refilled
        input must not be re-spilled by the admission of the next one) —
        respected by admissions from *every* session."""
        ids = [h.id for h in hs if isinstance(h, AlMatrix)]
        with self._lock:
            for hid in ids:
                self._pin_counts[hid] = self._pin_counts.get(hid, 0) + 1
        try:
            yield
        finally:
            with self._lock:
                for hid in ids:
                    left = self._pin_counts.get(hid, 1) - 1
                    if left > 0:
                        self._pin_counts[hid] = left
                    else:
                        self._pin_counts.pop(hid, None)

    def register_view(self, view: AlMatrix, source: AlMatrix) -> None:
        """Register a shared-group read view over another session's handle.

        The view shares the source's device array, so it is **not** charged
        (charging would double-count the same bytes); instead the source is
        pinned for the view's lifetime so no admission in any session can
        spill the bytes out from under the reader. The pin drops in
        :meth:`discard` when the view handle is freed.
        """
        with self._lock:
            view._governor = self
            self._view_sources[view.id] = source.id
            self._pin_counts[source.id] = self._pin_counts.get(source.id, 0) + 1

    # -- spill / refill ------------------------------------------------------
    def spill(self, h: AlMatrix, *, _deferred: Optional[List[_SpillJob]] = None) -> None:
        """Move a resident matrix's bytes off the worker group.

        Store-backed placements (a live ``_host_fallback``) spill for free:
        the engine already holds their logical payload host-side, so only the
        device array is dropped. Everything else becomes a :class:`_SpillJob`
        copy-out into the pinned host store. Only the *state transition* runs
        under the governor lock — a concurrent ``data()`` on another thread
        (handles hold the same lock across its check-refill-slice sequence)
        sees the handle either fully resident or fully spilled, never
        ``_data is None`` mid-flight — while the bytes stream on the transfer
        ring (or synchronously outside the lock when the ring is full or
        ``async_spill`` is off). The job retains the device reference until
        the copy lands, so a prompt refill joins it instead of re-reading the
        device; ``in_flight_spill_bytes`` ledgers exactly those bytes.
        """
        job: Optional[_SpillJob] = None
        with self._lock:
            if h.state != handles_mod.MATERIALIZED or h._data is None:
                raise HandleError(f"cannot spill AlMatrix {h.id} in state {h.state!r}")
            nbytes = self._charged.get(h.id, h.physical_nbytes())
            if h._host_fallback is None:
                job = _SpillJob(handle=h, array=h._data, nbytes=nbytes)
                self._in_flight[h.id] = job
                self._in_flight_bytes += nbytes
            self._used -= nbytes
            self._charged[h.id] = 0
            h._data = None
            h._state = handles_mod.SPILLED
        stats = self._stats_for(h)
        if stats is not None:
            stats.record_spill(nbytes)
        if job is None:
            return
        if self.async_spill and self._executor().try_submit(
            lambda: self._copy_out(job, on_ring=True)
        ):
            if stats is not None:
                stats.record_transfer_depth(self._transfer.depth())
            return
        # Ring full (double-buffer bound) or async disabled: copy on the
        # caller — after the admit loop's lock release when reached via
        # admission (_deferred), immediately otherwise.
        if _deferred is not None:
            _deferred.append(job)
        else:
            self._copy_out(job, on_ring=False)

    def _executor(self) -> TransferExecutor:
        with self._lock:
            if self._transfer is None or self._transfer._closed:
                self._transfer = TransferExecutor(name=f"{self.name}-transfer")
            return self._transfer

    def _gather_host(self, arr: jax.Array) -> np.ndarray:
        """Device→host copy into a pooled staging buffer (per-shard, one host
        write each); falls back to a plain ``device_get`` for arrays whose
        shards aren't addressable."""
        buf = self._staging.acquire(tuple(arr.shape), np.dtype(arr.dtype))
        try:
            for shard in arr.addressable_shards:
                buf[shard.index] = np.asarray(shard.data)
            return buf
        except Exception:  # pragma: no cover - non-addressable topologies
            self._staging.release(buf)
            return np.asarray(jax.device_get(arr))

    def _copy_out(self, job: _SpillJob, *, on_ring: bool) -> None:
        """Stream one spill victim's bytes to the host store.

        Runs on the transfer thread (``on_ring=True``) or the spilling caller
        (sync fallback). Claims the job under the lock, copies outside it,
        then installs under the lock again — a refill that joined (cancelled)
        the job meanwhile wins, and the gathered buffer goes back to the
        staging pool. Overlap accounting (ring copies only): the slice of the
        copy's wall time during which the owning session's queue worker was
        busy is compute the copy hid behind.
        """
        with self._lock:
            if job.state != "queued" or job.array is None:
                job.event.set()  # joined or cancelled before the copy began
                return
            job.state = "copying"
            arr = job.array
            sess = self._sessions.get(job.handle.session_id)
        tasks = sess.tasks if sess is not None else None
        busy0 = tasks.busy_ns() if tasks is not None else 0
        t0 = time.perf_counter_ns()
        try:
            host = self._gather_host(arr)
        except BaseException:  # pragma: no cover - device_get failure
            # The device reference is still good: restore residency rather
            # than lose the only copy of the bytes.
            with self._lock:
                if job.array is not None and self._in_flight.get(job.handle.id) is job:
                    job.array = None
                    self._in_flight_bytes -= job.nbytes
                    self._in_flight.pop(job.handle.id, None)
                    h = job.handle
                    if h.state == handles_mod.SPILLED and h.id in self._handles:
                        h._data = arr
                        h._state = handles_mod.MATERIALIZED
                        self._charged[h.id] = job.nbytes
                        self._used += job.nbytes
                job.state = "failed"
            job.event.set()
            return
        wall_ns = time.perf_counter_ns() - t0
        busy1 = tasks.busy_ns() if tasks is not None else 0
        installed = False
        with self._lock:
            if job.array is not None and self._in_flight.get(job.handle.id) is job:
                job.array = None
                self._in_flight_bytes -= job.nbytes
                self._in_flight.pop(job.handle.id, None)
                job.state = "done"
                if job.handle.state == handles_mod.SPILLED and job.handle.id in self._handles:
                    self._host_store[job.handle.id] = host
                    installed = True
        if not installed:
            self._staging.release(host)  # a join/free won the race
        job.event.set()
        if on_ring and sess is not None:
            sess.stats.record_spill_copy(wall_ns, min(max(busy1 - busy0, 0), wall_ns))

    def refill(self, h: AlMatrix) -> None:
        """Re-place a spilled matrix on its session's worker group. Runs on
        the first consumption after the spill (``AlMatrix.data()``); may
        itself spill other matrices to make room. Atomic under the governor
        lock, like spill's transition.

        Two paths:

        - **join**: the victim's copy-out is still in flight, so its bytes
          never left the device — cancel the job and restore the retained
          device reference. Zero copies, and crucially zero *waiting*: refill
          runs with the governor lock held (``data()``), and blocking here on
          the transfer thread (which needs the lock to finish) would deadlock.
        - **replay**: ``device_put`` the host payload back through the
          session's cached relayout plan. The staging buffer is passed to the
          plan directly (no intermediate ``jnp.asarray`` device bounce) with
          the final put marked donatable, and a pool-owned buffer is donated
          back to the staging pool afterwards — one host copy saved per
          spill/refill cycle. Exception: on CPU backends the sharded/donated
          put is *zero-copy* (the placed array's backing store IS the host
          buffer), so a buffer the new device array aliases is dropped from
          the pool instead — recycling it would let a later spill's gather
          write a victim's bytes through the alias into this live matrix.
        """
        with self._lock:
            sess = self._sessions.get(h.session_id)
            job = self._in_flight.get(h.id)
            if job is not None and job.array is not None:
                # Join the pending copy: take back the device reference.
                arr = job.array
                job.array = None
                self._in_flight_bytes -= job.nbytes
                self._in_flight.pop(h.id, None)
                job.state = "cancelled"
                job.event.set()
                self.admit(job.nbytes, exclude={h.id})
                h._data = arr
                h._state = handles_mod.MATERIALIZED
                self.settle(job.nbytes)  # claim -> charge, atomic: lock held
                self.charge(h)
                nbytes_refilled = job.nbytes
                fused = False
            else:
                host = self._host_store.get(h.id)
                if host is None:
                    host = h._host_fallback
                if host is None or sess is None:
                    raise HandleError(
                        f"AlMatrix {h.id} ({h.name!r}) has no spilled payload to refill"
                    )
                # Claim exactly what charge(h) will land: the *physical*
                # extent (a logical store payload gains divisibility pads at
                # placement) priced at the handle's declared dtype. Claiming
                # host.nbytes would under-admit by the pad bytes and silently
                # overshoot the budget at the charge.
                pr, pc = pad_amounts(tuple(host.shape), h.layout, sess.mesh)
                claim = (
                    (host.shape[0] + pr)
                    * (host.shape[1] + pc)
                    * jnp.dtype(h.dtype).itemsize
                )
                self.admit(claim, exclude={h.id})
                # Host-store payloads are the *physical* (already padded,
                # already permuted) form and store fallbacks the logical one;
                # either way src == dst, so the cached plan is a pure
                # placement — no permutation, and pads exactly when the
                # payload needs them for the device_put. The put consumes the
                # host buffer directly; only a dtype the device would
                # canonicalize anyway (f64 without x64 mode) is converted
                # host-side first, so the plan key matches the placed array.
                canon = jax.dtypes.canonicalize_dtype(host.dtype)
                x = host if canon == host.dtype else np.asarray(host, dtype=canon)
                plan, _hit = sess.relayout_cache.plan(
                    tuple(x.shape), canon, h.layout, h.layout, sess.mesh
                )
                arr = plan.apply(x, donate=True)
                fused = plan.fused_path in FUSED_PATHS
                h._data = arr
                h.pads = (arr.shape[0] - h.shape[0], arr.shape[1] - h.shape[1])
                h._state = handles_mod.MATERIALIZED
                popped = self._host_store.pop(h.id, None)
                if popped is not None and not _aliases_host(arr, popped):
                    self._staging.release(popped)  # refused if client-escaped
                self.settle(claim)  # claim -> charge, atomic: lock is held
                self.charge(h)
                nbytes_refilled = int(host.nbytes)
        stats = self._stats_for(h)
        if stats is not None:
            stats.record_refill(nbytes_refilled)
            if fused:
                stats.record_fused_relayout()

    def host_payload(self, h: AlMatrix, timeout: float = 120.0) -> Optional[np.ndarray]:
        """The spilled payload (physical from the host store, or the store
        entry's logical fallback), or None if ``h`` is not spilled. Lets the
        collect path serve client-bound bytes straight from host memory — no
        refill, no admission cascade — while the handle stays spilled for any
        later engine-side consumption.

        If the spill's copy-out is still in flight, joins it by waiting on
        the job's event *outside* the governor lock (the transfer thread
        needs the lock to install the payload). A pool-owned buffer is marked
        read-only before it escapes: collects may serve it zero-copy to the
        client, so it must never be recycled for a later spill's gather.
        """
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                if h.state != handles_mod.SPILLED:
                    return None
                host = self._host_store.get(h.id)
                if host is not None:
                    if host.flags.writeable:
                        host.flags.writeable = False  # escaped: never recycle
                    return host
                if h._host_fallback is not None:
                    return h._host_fallback
                job = self._in_flight.get(h.id)
                if job is None:
                    return None
            remaining = deadline - time.monotonic()
            if remaining <= 0 or not job.event.wait(remaining):
                raise HandleError(
                    f"AlMatrix {h.id} ({h.name!r}) spill copy-out did not land "
                    f"within {timeout}s"
                )

    # -- introspection -------------------------------------------------------
    def spilled_handles(self) -> List[AlMatrix]:
        with self._lock:
            return [h for h in self._handles.values() if h.state == handles_mod.SPILLED]

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {
                "budget": self.budget or 0,
                "used": self._used,
                "reserved": self._reserved,
                "high_water": self.high_water,
                "sessions": len(self._sessions),
                "resident_handles": sum(
                    1
                    for h in self._handles.values()
                    if h.state == handles_mod.MATERIALIZED
                ),
                "spilled_handles": sum(
                    1
                    for h in self._handles.values()
                    if h.state == handles_mod.SPILLED
                ),
                "host_store_bytes": sum(a.nbytes for a in self._host_store.values()),
                "in_flight_spill_bytes": self._in_flight_bytes,
                "staging_reuses": self._staging.reuses,
                "shared_views": len(self._view_sources),
            }

    def clear(self) -> None:
        """Engine teardown: drop every charge and host-store payload, cancel
        in-flight copy-outs, and stop the transfer ring (it is rebuilt lazily
        if the governor spills again)."""
        with self._lock:
            for job in self._in_flight.values():
                if job.array is not None:
                    job.array = None
                    self._in_flight_bytes -= job.nbytes
                job.state = "cancelled"
                job.event.set()
            self._in_flight.clear()
            self._in_flight_bytes = 0
            transfer, self._transfer = self._transfer, None
            self._handles.clear()
            self._charged.clear()
            self._host_store.clear()
            self._touch.clear()
            self._pin_counts.clear()
            self._idle.clear()
            self._view_sources.clear()
            self._gated = False
            self._staging.clear()
            self._used = 0
            self._reserved = 0
        if transfer is not None:
            transfer.close(wait=True, timeout=10.0)

    def _stats_for(self, h: AlMatrix):
        sess = self._sessions.get(h.session_id)
        return sess.stats if sess is not None else None

    def _record_high_water(self, h: AlMatrix) -> None:
        # caller holds self._lock; per-session stats see the engine-wide
        # usage at their own charge moments, self.high_water the global max
        self.high_water = max(self.high_water, self._used)
        stats = self._stats_for(h)
        if stats is not None:
            stats.record_hbm_usage(self._used)

    def __repr__(self) -> str:
        s = self.snapshot()
        return (
            f"MemoryGovernor(budget={s['budget']}, used={s['used']}, "
            f"sessions={s['sessions']}, resident={s['resident_handles']}, "
            f"spilled={s['spilled_handles']})"
        )
