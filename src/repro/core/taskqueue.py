"""TaskQueue — per-session FIFO workers behind the asynchronous ACI.

DESIGN.md §3: the engine's concurrency unit is the *session*. Each session
owns one TaskQueue: a FIFO of send/run/collect tasks drained by a single
daemon worker thread. One worker per session keeps every session's operations
strictly ordered (the paper's per-application command stream, §2.4) while
letting *different* sessions — which own disjoint mesh slices — genuinely
overlap: their workers dispatch to XLA independently, and JAX's async
dispatch means a dispatched routine keeps computing while the same worker
already stages the next transfer.

The queue is intentionally tiny: tasks are plain callables, results flow
through :class:`~repro.core.futures.AlFuture`, and a barrier is just a no-op
task whose future the caller waits on. ServeEngine reuses the same class for
request batches, so the primitive is engine-wide, not Alchemist-specific.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Optional

from repro.core.errors import TaskError
from repro.core.futures import AlFuture

_SHUTDOWN = object()


class TaskQueue:
    """A FIFO of callables drained by one lazily-started daemon worker."""

    def __init__(self, name: str = "taskqueue"):
        self.name = name
        self._q: "queue.Queue" = queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._closed = False
        self.tasks_submitted = 0
        self.tasks_completed = 0
        self.tasks_failed = 0
        # Deepest backlog ever observed at submit time: how far ahead of the
        # worker the client ran. The memory governor's reservations track the
        # bytes side of the same pipelining (DESIGN.md §7).
        self.max_backlog = 0
        # Cumulative ns the worker spent executing tasks, plus the start of
        # the currently-running task (None while idle). The data plane's
        # overlap accounting (DESIGN.md §10) diffs busy_ns() across an async
        # spill copy-out to measure how much compute the copy hid behind.
        self._busy_total_ns = 0
        self._busy_since: Optional[int] = None

    # -- submission ----------------------------------------------------------
    def submit(self, fn: Callable[[], Any], *, label: str = "") -> AlFuture:
        """Enqueue ``fn`` for the worker; returns the future of its result."""
        future = AlFuture(label=label or getattr(fn, "__name__", "task"))
        with self._lock:
            if self._closed:
                raise TaskError(f"TaskQueue {self.name!r} is closed")
            self.tasks_submitted += 1
            self._q.put((fn, future))
            self.max_backlog = max(self.max_backlog, self._q.qsize())
            self._ensure_worker()
        return future

    def barrier(self, timeout: Optional[float] = None) -> None:
        """Block until every task submitted before this call has finished."""
        with self._lock:
            thread = self._thread
            if thread is None:
                return  # no worker was ever started: nothing in flight
            if self._closed:
                # close(wait=False) leaves the worker draining in the
                # background; "all tasks finished" then means "worker exited"
                # (it stops at the shutdown sentinel, which is queued last).
                future = None
            else:
                future = AlFuture(label=f"{self.name}:barrier")
                # Counted as submitted: the worker counts it completed, and
                # the submitted == completed + failed + pending invariant is
                # what the soak tests lean on.
                self.tasks_submitted += 1
                self._q.put((lambda: None, future))
        if future is not None:
            future.result(timeout)
            return
        thread.join(timeout)
        if thread.is_alive():
            raise TaskError(
                f"TaskQueue {self.name!r} barrier: worker still draining after {timeout}s"
            )

    # -- worker --------------------------------------------------------------
    def _ensure_worker(self) -> None:
        # caller holds self._lock
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._drain, name=f"{self.name}-worker", daemon=True
            )
            self._thread.start()

    def _drain(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _SHUTDOWN:
                    return
                fn, future = item
                self._busy_since = time.perf_counter_ns()
                try:
                    future._set_result(fn())
                    self.tasks_completed += 1
                except BaseException as exc:  # noqa: BLE001 — propagate via future
                    self.tasks_failed += 1
                    future._set_exception(exc)
                finally:
                    start = self._busy_since
                    self._busy_since = None
                    if start is not None:
                        self._busy_total_ns += time.perf_counter_ns() - start
            finally:
                self._q.task_done()

    # -- lifecycle -----------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    def pending(self) -> int:
        """Approximate number of tasks not yet picked up by the worker."""
        return self._q.qsize()

    def busy_ns(self) -> int:
        """Cumulative ns the worker has spent executing tasks, including the
        one currently running. Monotone; racy reads are fine (the single
        writer is the worker thread, and the overlap accounting that diffs
        this only needs a lower bound on busy time)."""
        total, since = self._busy_total_ns, self._busy_since
        if since is not None:
            total += max(time.perf_counter_ns() - since, 0)
        return total

    def close(self, wait: bool = True, timeout: Optional[float] = None) -> None:
        """Stop accepting tasks; optionally drain what's already queued.

        Idempotent. With ``wait=False`` the already-queued tasks still run
        (the worker drains them in the background) but we don't block on them.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
            thread = self._thread
            if thread is not None:
                self._q.put(_SHUTDOWN)
        if wait and thread is not None:
            thread.join(timeout)
            if thread.is_alive():
                raise TaskError(
                    f"TaskQueue {self.name!r} failed to drain within {timeout}s"
                )

    def stats(self) -> dict:
        return {
            "submitted": self.tasks_submitted,
            "completed": self.tasks_completed,
            "failed": self.tasks_failed,
            "max_backlog": self.max_backlog,
        }

    def __repr__(self) -> str:
        return (
            f"TaskQueue({self.name!r}, submitted={self.tasks_submitted}, "
            f"completed={self.tasks_completed}, failed={self.tasks_failed}, "
            f"closed={self._closed})"
        )


class TransferExecutor:
    """Dedicated copy worker behind the asynchronous data plane (DESIGN.md §10).

    One daemon thread drains D2H copy-out jobs so a session's queue worker can
    dispatch the next task while the previous spill victim's bytes stream to
    host. The ring is a bounded double buffer: at most ``ring`` jobs may be
    queued or copying at once, so device memory overshoot from not-yet-copied
    victims is capped at two matrices. :meth:`try_submit` is strictly
    non-blocking — the memory governor calls it under its lock, and the worker
    needs that same lock to complete a job, so a blocking submit would
    deadlock; a full ring returns None and the caller copies synchronously.
    """

    def __init__(self, name: str = "transfer", ring: int = 2):
        self.name = name
        self.ring = ring
        self._q: "queue.Queue" = queue.Queue()
        self._slots = threading.Semaphore(ring)
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self._in_flight = 0
        self.submitted = 0
        self.rejected = 0  # ring full: the caller fell back to a sync copy
        self.max_depth = 0

    def try_submit(self, fn: Callable[[], None]) -> bool:
        """Enqueue ``fn`` if a ring slot is free; False means ring full."""
        if not self._slots.acquire(blocking=False):
            self.rejected += 1
            return False
        with self._lock:
            if self._closed:
                self._slots.release()
                self.rejected += 1
                return False
            self.submitted += 1
            self._in_flight += 1
            self.max_depth = max(self.max_depth, self._in_flight)
            self._q.put(fn)
            self._ensure_worker()
        return True

    def depth(self) -> int:
        """Jobs queued or copying right now (0..ring)."""
        return self._in_flight

    def _ensure_worker(self) -> None:
        # caller holds self._lock
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._drain, name=f"{self.name}-worker", daemon=True
            )
            self._thread.start()

    def _drain(self) -> None:
        while True:
            fn = self._q.get()
            try:
                if fn is _SHUTDOWN:
                    return
                try:
                    fn()
                except BaseException:  # noqa: BLE001 — a copy job must never
                    pass  # kill the ring; the job owner observes via its event
                finally:
                    with self._lock:
                        self._in_flight -= 1
                    self._slots.release()
            finally:
                self._q.task_done()

    def close(self, wait: bool = True, timeout: Optional[float] = None) -> None:
        """Stop accepting jobs; optionally wait for queued copies to finish."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            thread = self._thread
            if thread is not None:
                self._q.put(_SHUTDOWN)
        if wait and thread is not None:
            thread.join(timeout)

    def stats(self) -> dict:
        return {
            "submitted": self.submitted,
            "rejected": self.rejected,
            "max_depth": self.max_depth,
            "ring": self.ring,
        }

    def __repr__(self) -> str:
        return (
            f"TransferExecutor({self.name!r}, ring={self.ring}, "
            f"submitted={self.submitted}, rejected={self.rejected})"
        )
