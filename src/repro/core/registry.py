"""Library registry — the Alchemist-Library Interface (ALI) analogue.

Paper §2.3/§3.5: each MPI library is wrapped by a thin shared object that
Alchemist ``dlopen``s at runtime; the wrapper's ``run`` function receives the
routine name plus serialized input/output parameter arrays and dispatches
into the library. Alchemist itself has *no* compiled-in knowledge of any
library.

The TPU adaptation keeps the late-binding-by-name contract and drops the
POSIX mechanism: a :class:`Library` subclass registers named
:class:`Routine` objects; libraries are resolved at runtime either from an
instance or from an import-path string ``"pkg.module:ClassName"`` — the
``dlopen`` analogue (the engine imports the module only when a client
registers it, so adding a library never touches engine code).
"""

from __future__ import annotations

import dataclasses
import importlib
import inspect
from typing import Any, Callable, Dict, Optional, Tuple, Union

from repro.core.errors import LibraryError


@dataclasses.dataclass(frozen=True)
class Routine:
    """One callable exposed by a library.

    ``fn`` receives distributed matrices as jax.Arrays (already resident in
    the session's GRID layout) plus scalar keyword parameters, and returns a
    single array, a tuple of arrays, scalars, or a mix. The engine wraps
    array outputs back into AlMatrix handles.
    """

    name: str
    fn: Callable[..., Any]
    doc: str = ""

    def signature(self) -> inspect.Signature:
        return inspect.signature(self.fn)


class Library:
    """Base class for engine libraries (the ALI contract).

    Subclasses set ``name`` and call :meth:`register` (typically in
    ``__init__``) for each exposed routine — the analogue of implementing the
    paper's ``Library``/``Parameters`` headers.
    """

    name: str = ""

    def __init__(self) -> None:
        if not self.name:
            raise LibraryError(f"{type(self).__name__} must set a class-level name")
        self._routines: Dict[str, Routine] = {}

    def register(
        self,
        name: str,
        fn: Callable[..., Any],
        doc: str = "",
        *,
        shape_rule: Optional[Callable] = None,
        unchecked_shapes: bool = False,
    ) -> None:
        """Expose ``fn`` as routine ``name``.

        Every routine must come with a shape story (DESIGN.md §7): the
        engine prices routine outputs for HBM admission and validates
        deferred chains at graph-build time through
        :data:`repro.core.expr.SHAPE_RULES`. Third-party libraries pass
        ``shape_rule`` — a ``(arg_shapes, params) -> output shapes``
        callable registered via
        :func:`repro.core.expr.register_shape_rule` — or explicitly opt out
        with ``unchecked_shapes=True`` (outputs stay unpriced and chains
        through the routine stop validating, exactly the pre-rule
        behaviour). Registering a routine with neither is rejected: a
        silently unpriced routine is how budgets drift.
        """
        # Imported here, not at module top: expr imports nothing from the
        # registry, but keeping the registry import-light preserves the
        # "engine has no compiled-in library knowledge" layering.
        from repro.core.expr import SHAPE_RULES, register_shape_rule

        if name in self._routines:
            raise LibraryError(f"routine {name!r} already registered in library {self.name!r}")
        if shape_rule is not None:
            register_shape_rule(name, shape_rule)
        elif name not in SHAPE_RULES and not unchecked_shapes:
            raise LibraryError(
                f"routine {name!r} of library {self.name!r} has no shape rule: "
                "pass shape_rule=... (see repro.core.expr.SHAPE_RULES for the "
                "contract) or opt out explicitly with unchecked_shapes=True"
            )
        self._routines[name] = Routine(name=name, fn=fn, doc=doc or (fn.__doc__ or ""))

    def routine(self, name: str) -> Routine:
        try:
            return self._routines[name]
        except KeyError:
            raise LibraryError(
                f"library {self.name!r} has no routine {name!r}; "
                f"available: {sorted(self._routines)}"
            ) from None

    def routine_names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._routines))

    # The paper's ALI `run(name, in_params, out_params)` entry point.
    def run(self, name: str, *args: Any, **kwargs: Any) -> Any:
        return self.routine(name).fn(*args, **kwargs)


LibrarySpec = Union[Library, type, str]


def load_library(spec: LibrarySpec) -> Library:
    """Resolve a library spec — instance, class, or ``"module:attr"`` string.

    The string form is the runtime-dynamic-linking analogue: the module is
    imported only now, at registration time.
    """
    if isinstance(spec, Library):
        return spec
    if isinstance(spec, type) and issubclass(spec, Library):
        return spec()
    if isinstance(spec, str):
        mod_name, sep, attr = spec.partition(":")
        if not sep:
            raise LibraryError(
                f"library path {spec!r} must look like 'package.module:ClassName'"
            )
        try:
            mod = importlib.import_module(mod_name)
        except ImportError as e:
            raise LibraryError(f"cannot import library module {mod_name!r}: {e}") from e
        try:
            cls = getattr(mod, attr)
        except AttributeError:
            raise LibraryError(f"module {mod_name!r} has no attribute {attr!r}") from None
        return load_library(cls)
    raise LibraryError(f"cannot load library from {type(spec).__name__}")
