"""The v2 client surface: ``connect()`` → :class:`Session` → :class:`AlArray`.

DESIGN.md §9. The paper frames Alchemist's value as "minimal coding overhead"
for Spark users, yet by PR 4 the repo exposed three parallel client APIs —
eager ``ac.send/run/collect``, async ``*_async`` futures, and the planner's
``LazyMatrix`` DAG — each with its own handle type, stats, and failure
surface. v2 collapses them into one lazy-by-default API:

    import repro

    engine = repro.AlchemistEngine()
    with repro.connect(engine, workers=4) as session:
        session.register_library("elemental", "repro.linalg.library:ElementalLib")
        a = session.send(A)                               # AlArray (deferred)
        c = a @ session.send(B)                           # builds the DAG
        u, s, v = session.run("elemental", "truncated_svd", c, n_outputs=3, k=8)
        U = u.data()                                      # forces through the planner

Every operation builds an expression node; **when** nodes execute is the
session's :class:`~repro.core.policy.ExecutionPolicy` (``Eager`` /
``Pipelined`` / ``Planned``), settable per session or per ``with
session.policy(...)`` scope — never a per-call API choice. All policies run
the same DAG through the same planner, so results are bit-identical.

``connect()`` is **admission-aware** (paper §2.4's "assuming a sufficient
number of workers is available", removed): when the engine cannot place the
worker group it queues the request until a group frees up (with an optional
timeout), and placement prefers the free device block whose resident-store
content the session's *declared datasets* will reuse — see
:meth:`AlchemistEngine.allocate`.

Layering: :class:`ClientCore` is the transport (the old ``AlchemistContext``
implementation, verbatim: task-queue submission, bridge relayouts, governor
reservations, resident-store publish/attach). :class:`Session` is the v2
facade over it; the v1 :class:`AlchemistContext` remains as a deprecation
shim that subclasses the same core, so the two surfaces cannot drift.
"""

from __future__ import annotations

import contextlib
import time
import warnings
from typing import TYPE_CHECKING, Any, Dict, Iterator, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import futures as futures_mod
from repro.core import handles as handles_mod
from repro.core import params as params_codec
from repro.core.errors import LibraryError, SessionError
from repro.core.expr import (
    LazyMatrix,
    arg_shape,
    content_key,
    infer_run_shapes,
    peeked_state,
)
from repro.core.futures import AlFuture
from repro.core.handles import AlMatrix
from repro.core.layouts import GRID, ROW, LayoutSpec
from repro.core.policy import ExecutionPolicy, PolicyLike, as_policy
from repro.core.registry import Library, LibrarySpec, load_library
from repro.core.relayout import (
    FUSED_PATHS,
    TransferRecord,
    pad_amounts,
    pad_for,
    staged_pad_path,
    timed_relayout,
    transfer_cost,
)
from repro.core.resident import ResidentEntry, ResidentStore
from repro.core.scheduler import PlacementRequest, PlacementTicket
from repro.core.transport import StagedShards, Transport, resolve_transport

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.core.engine import AlchemistEngine

# Sentinel distinguishing "kwarg not passed" from an explicit None/() on the
# deprecated v1 admission kwargs (DESIGN.md §12 migration table).
_UNSET = object()


def _coerce_placement(
    placement: Optional[PlacementRequest],
    *,
    workers: Optional[int] = None,
    grid: Optional[Tuple[int, int]] = None,
    datasets: Any = _UNSET,
    queue: Any = _UNSET,
    timeout: Any = _UNSET,
    default_queue: bool,
) -> PlacementRequest:
    """Fold the v1 admission kwargs into a :class:`PlacementRequest`.

    ``workers``/``grid`` stay first-class sugar (no warning); the v1
    admission trio (``datasets``/``queue``/``timeout``) warns and maps onto
    ``affinity``/``deadline``: ``queue=False`` → ``deadline=0`` (fail fast),
    ``queue=True, timeout=t`` → ``deadline=t`` (None waits indefinitely).
    """
    legacy = [
        kw
        for kw, value in (("datasets", datasets), ("queue", queue), ("timeout", timeout))
        if value is not _UNSET
    ]
    if legacy:
        warnings.warn(
            f"{', '.join(legacy)} kwarg(s) are deprecated; pass "
            "placement=PlacementRequest(affinity=..., deadline=...) instead "
            "(DESIGN.md §12 migration table)",
            DeprecationWarning,
            stacklevel=3,
        )
    if placement is not None:
        if workers is not None or grid is not None or legacy:
            raise SessionError(
                "pass either placement=PlacementRequest(...) or the legacy "
                "workers/grid/datasets/queue/timeout kwargs, not both"
            )
        return placement
    queue = default_queue if queue is _UNSET else bool(queue)
    timeout = None if timeout is _UNSET else timeout
    datasets = () if datasets is _UNSET else datasets
    deadline = (None if timeout is None else float(timeout)) if queue else 0.0
    return PlacementRequest(
        workers=workers, grid=grid, affinity=tuple(datasets), deadline=deadline
    )


class ClientCore:
    """The client-side transport: one session's bridge to the engine.

    All operations flow through the session's task queue. ``send_eager`` /
    ``run_eager`` submit a task and wait; the ``*_async`` twins submit and
    return an :class:`AlFuture`, letting transfers pipeline against compute
    within the session and letting independent sessions overlap across the
    engine. The v2 :class:`Session` and the v1 :class:`AlchemistContext` shim
    are both thin facades over this core.

    ``hbm_budget`` (bytes, optional) folds into the engine-wide governor's
    shared ceiling: sends and routine outputs are admitted against it,
    spilling least-recently/last-used matrices to a pinned host store and
    refilling them transparently on next use (DESIGN.md §7). Default:
    unlimited. Admission is declarative (DESIGN.md §12): pass
    ``placement=PlacementRequest(...)`` (workers, priority, content
    affinity, deadline, shareability); the v1 ``datasets``/``queue``/
    ``timeout`` kwargs keep working through a deprecation shim.
    """

    def __init__(
        self,
        engine: "AlchemistEngine",
        num_workers: Optional[int] = None,
        *,
        name: str = "app",
        grid: Optional[Tuple[int, int]] = None,
        client_layout: LayoutSpec = ROW,
        engine_layout: LayoutSpec = GRID,
        hbm_budget: Optional[int] = None,
        placement: Optional[PlacementRequest] = None,
        datasets: Any = _UNSET,
        queue: Any = _UNSET,
        timeout: Any = _UNSET,
        transport: Union[Transport, str, None] = None,
    ):
        self.engine = engine
        self.client_layout = client_layout
        self.engine_layout = engine_layout
        self._planner = None
        self._stopped = False
        placement = _coerce_placement(
            placement,
            workers=num_workers,
            grid=grid,
            datasets=datasets,
            queue=queue,
            timeout=timeout,
            default_queue=False,  # the v1 core failed fast by default
        )
        # The wire seam (DESIGN.md §11): every verb below reaches the engine
        # through this transport. Default comes from REPRO_TRANSPORT, so an
        # unmodified test suite can run over a localhost socket.
        self.transport = resolve_transport(transport)
        # Re-admission record (DESIGN.md §14): the kwargs a fleet recovery
        # replays through a surviving engine's queued connect path.
        self._admission = dict(name=name, hbm_budget=hbm_budget, placement=placement)
        self.session = self.transport.open_session(self, dict(self._admission))

    @classmethod
    def _over_session(cls, engine: "AlchemistEngine", session, client_layout, engine_layout):
        """Engine-side twin of a remote client (serve.wire): a core bound to
        an existing session, executing the ``_local_*`` verbs in-process.
        Never opens a transport and never owns admission — the server that
        built it releases the session on disconnect/CLOSE."""
        core = object.__new__(cls)
        core.engine = engine
        core.client_layout = client_layout
        core.engine_layout = engine_layout
        core._planner = None
        core._stopped = False
        core.transport = None
        core.session = session
        core._admission = {}
        return core

    # -- libraries -----------------------------------------------------------
    def register_library(self, name: str, spec: LibrarySpec) -> Library:
        """Load a library into this session (the paper's registerLibrary).

        ``spec`` may be a Library instance/class or an import-path string
        ``"repro.linalg.library:ElementalLib"`` — resolved only now, the
        runtime-dynamic-linking analogue. Import-path strings route through
        the transport (they are the wire-expressible form — the paper's
        "dlopen by name" request); live instances/classes are an in-process
        convenience and register directly.
        """
        self._check()
        if isinstance(spec, str):
            return self.transport.register_library(self, name, spec)
        return self._local_register_library(name, spec)

    def _local_register_library(self, name: str, spec: LibrarySpec) -> Library:
        lib = load_library(spec)
        if name != lib.name:
            # allow aliasing but keep it explicit in the session table
            lib.name = name
        self.session.libraries[name] = lib
        # Record the wire-expressible spec for the session's re-admission
        # descriptor: import-path strings verbatim, instances/classes as
        # their import path (best effort — a fleet recovery re-resolves it).
        if isinstance(spec, str):
            self.session.library_specs[name] = spec
        else:
            self.session.library_specs[name] = (
                f"{type(lib).__module__}:{type(lib).__name__}"
            )
        return lib

    def library(self, name: str) -> Library:
        self._check()
        try:
            return self.session.libraries[name]
        except KeyError:
            raise LibraryError(
                f"library {name!r} not registered in session {self.session.id}; "
                f"registered: {sorted(self.session.libraries)}"
            ) from None

    # -- matrix movement (the bridge) -----------------------------------------
    def send_async(self, array: Union[jax.Array, np.ndarray], name: str = "") -> AlFuture:
        """Pipelined RDD→Alchemist transfer: returns immediately with a
        future of the handle; the session worker stages + reshards it."""
        return self._submit_send(array, name=name, block=False)

    def send_eager(self, array: Union[jax.Array, np.ndarray], name: str = "") -> AlMatrix:
        """Ship a client-side (row-partitioned) matrix to the engine's grid
        layout and return its handle. The paper's RDD→Alchemist transfer."""
        return self._submit_send(array, name=name, block=True).result()

    def _submit_send(
        self,
        array: Union[jax.Array, np.ndarray],
        *,
        name: str,
        block: bool,
        key: Optional[Tuple] = None,
        payload: Optional[np.ndarray] = None,
    ) -> AlFuture:
        """``key``/``payload`` (internal, DESIGN.md §8): the payload's content
        key and a private host snapshot of its logical bytes, when the caller
        (the offload planner) already computed them. Validates client-side
        (fail fast), then hands the payload to the transport — which frames
        its bytes (loopback encodes/decodes in place; TCP ships them) before
        the engine-side :meth:`_local_submit_send` runs."""
        self._check()
        # Validate + capture metadata in the caller thread (fail fast, and
        # pending handles need shape/dtype before the transfer runs).
        if not isinstance(array, jax.Array):
            array = np.asarray(array)
        if array.ndim != 2:
            raise SessionError(f"send() expects a 2D matrix, got shape {tuple(array.shape)}")
        return self.transport.submit_send(
            self, array, name=name, block=block, key=key, payload=payload
        )

    def _local_submit_send(
        self,
        array: Union[jax.Array, np.ndarray],
        *,
        name: str,
        block: bool,
        key: Optional[Tuple] = None,
        payload: Optional[np.ndarray] = None,
    ) -> AlFuture:
        """Engine-side send: content-store attach decision, pending handle,
        governor reservation, task submission. With the engine's resident
        store enabled a content key is derived here for plain sends too, so
        every non-cyclic transfer publishes into the content index — and a
        send whose bytes another session already placed on the engine becomes
        an attach instead of a bridge crossing."""
        sess = self.session
        store = self._content_store()
        if store is not None:
            if key is None:
                key = content_key(array)
            entry = store.lookup(key)
            if entry is not None and entry.live_handle_for(sess.id) is None and entry.usable():
                # The engine already holds these bytes (another session's
                # placement, or content migrated out of a closed one): attach
                # — an engine-internal placement, zero bridge traffic. A
                # duplicate send *within* a session keeps its classic
                # full-transfer semantics (independent handles; the planner
                # is the intra-session dedup layer).
                return self._submit_attach(key, entry, array, name=name, block=block)
        h = sess.new_pending_handle(array.shape, array.dtype, self.engine_layout, name=name)
        if store is not None:
            # Publish before the transfer runs: a concurrent session's attach
            # may pin the entry now and wait on this pending placement.
            store.register(key, h, sess, payload=payload)
        # Reserve the *physical* footprint against the HBM budget before
        # enqueueing: logical shape plus the divisibility padding the staging
        # (client) and resident (engine) layouts will append (DESIGN.md §7).
        phys = self._send_physical_shape(tuple(int(d) for d in array.shape))
        reserve_bytes = sess.memgov.reserve(
            phys[0] * phys[1] * jnp.dtype(array.dtype).itemsize
        )

        def task() -> AlMatrix:
            admitted = 0
            staged = array if isinstance(array, StagedShards) else None
            try:
                mesh = sess.mesh
                # Make room before any bytes land on the worker group: the
                # governor spills last-used resident matrices to host until
                # the incoming footprint fits the budget, and claims the room
                # so a concurrent session's admission cannot take it first.
                sess.memgov.admit(reserve_bytes)
                admitted = reserve_bytes
                if (
                    staged is not None
                    and not self.engine_layout.cyclic  # padded slabs would
                    # defeat cyclic's no-pre-pad rule; degrade below
                    and staged.matches(self.client_layout, mesh)
                ):
                    # Shard-direct send (DESIGN.md §13): the wire already
                    # decoded into per-shard slabs (pad slack zero-filled at
                    # decode) and may have overlapped the device_puts with
                    # the socket reads — assemble, never reassemble on host.
                    x = staged.device_array(self.client_layout.sharding(mesh))
                    stage_path = staged_pad_path(staged.geom.pads)
                else:
                    # A stale geometry (layout/mesh changed under the frame)
                    # degrades to the classic materialize-and-pad path.
                    x = jnp.asarray(np.asarray(array)) if staged is not None else jnp.asarray(array)
                    # Stage on the client layout first (rows over all session
                    # workers) so the recorded transfer is the genuine
                    # ROW->GRID redistribution; uneven shapes are zero-padded
                    # to the next worker-count multiple so the device_put is
                    # legal. Cyclic layouts are never pre-padded — the
                    # emulation's permutation would interleave the zero rows
                    # (see pad_amounts) — so they keep the pre-padding
                    # behaviour: even shapes work, uneven ones fail loudly at
                    # the device_put.
                    stage_path = "none"
                    if not (self.client_layout.cyclic or self.engine_layout.cyclic):
                        x, _stage_pads, stage_path = pad_for(x, self.client_layout, mesh)
                    x = jax.device_put(x, self.client_layout.sharding(mesh))
                out, rec = timed_relayout(
                    x,
                    self.engine_layout,
                    mesh,
                    src=self.client_layout,
                    direction="send",
                    cache=sess.relayout_cache,
                    block=block,
                    strip=False,  # residency keeps the put-legal physical form
                )
                rec.fused = rec.fused or stage_path in FUSED_PATHS
                sess.stats.record_transfer(rec)
                with sess.memgov.lock:  # claim -> charge atomically
                    sess.memgov.settle(admitted)
                    admitted = 0
                    h.materialize(
                        out, pads=(out.shape[0] - h.shape[0], out.shape[1] - h.shape[1])
                    )
                    sess.memgov.charge(h)
                if staged is not None:
                    # Slabs go back to the pool unless a zero-copy device_put
                    # left a live array aliasing them (CPU backends).
                    staged.dispose(x, out)
                return h
            except BaseException as exc:
                if staged is not None:
                    staged.dispose()
                h.fail(exc)
                raise
            finally:
                sess.memgov.settle(admitted)
                sess.memgov.unreserve(reserve_bytes)

        return sess.tasks.submit(task, label=f"send:{name or h.id}")

    def _content_store(self) -> Optional[ResidentStore]:
        """The engine's resident store, when this session can use it: cyclic
        layouts store a physical row permutation that does not round-trip
        through the pure placement plan the attach/refill paths use."""
        store = self.engine.residents
        if not store.enabled:
            return None
        if self.client_layout.cyclic or self.engine_layout.cyclic:
            return None
        return store

    def _submit_attach(
        self,
        key: Tuple,
        entry: ResidentEntry,
        array: Union[jax.Array, np.ndarray],
        *,
        name: str,
        block: bool,
    ) -> AlFuture:
        """Produce this session's placement of an already-engine-resident
        content entry (DESIGN.md §8): an engine-internal ``device_put`` from
        the entry's host payload — no client↔engine bridge crossing, so no
        TransferRecord. Counted as ``cross_session_reuses``.

        ``array`` is the caller's own copy of the bytes: if the engine-side
        content vanishes between the attach decision and this task running
        (producer freed, orphan evicted by the retention cap), the placement
        falls back to it and is accounted as a genuine bridge send — never a
        spurious failure, never a wait on a handle that cannot materialize.

        Shared worker groups (DESIGN.md §12): when this session sits on the
        *same* worker group (same devices, same mesh geometry) as a live
        materialized placement of the content, the attach becomes a zero-byte
        **view** over that placement's device array — no ``device_put``, no
        governor charge (the source is pinned instead) — which is what makes
        the scheduler's shared-group join zero-byte engine-side.
        """
        sess = self.session
        store = self.engine.residents
        h = sess.new_pending_handle(entry.shape, entry.dtype, self.engine_layout, name=name)
        h._placement_only = True  # never a payload source while pending
        store.register(key, h, sess)
        pr, pc = pad_amounts(entry.shape, self.engine_layout, sess.mesh)
        phys = (entry.shape[0] + pr, entry.shape[1] + pc)
        reserve_bytes = sess.memgov.reserve(
            phys[0] * phys[1] * jnp.dtype(entry.dtype).itemsize
        )

        def task() -> AlMatrix:
            admitted = 0
            try:
                # Zero-byte path first: a live placement of these bytes on
                # this exact worker group can be shared in place. Checked and
                # committed under the governor lock so the source cannot be
                # spilled between the check and the pin.
                src = self._shared_view_source(entry)
                if src is not None:
                    with sess.memgov.lock:
                        if src.state == handles_mod.MATERIALIZED and src._data is not None:
                            h._host_fallback = src._host_fallback
                            h.materialize(
                                src._data,
                                pads=(
                                    src._data.shape[0] - h.shape[0],
                                    src._data.shape[1] - h.shape[1],
                                ),
                            )
                            sess.memgov.register_view(h, src)
                            sess.stats.record_shared_view()
                            sess.stats.record_cross_session_reuse()
                            store.record_attach()
                            return h
                # May block on the producing session's in-flight transfer —
                # a cross-session wait on a send task that depends on no one,
                # so it cannot deadlock the FIFOs (pending attach placements
                # are excluded as sources, see ensure_payload).
                payload = store.ensure_payload(entry)
                t0 = time.perf_counter()
                attached = payload is not None
                if not attached:
                    # The content died under us: the caller's bytes cross the
                    # bridge after all. Snapshot them (the caller may mutate
                    # its array later; the entry payload must stay true to
                    # the key) and publish so the content is shareable again.
                    payload = np.array(array)
                    store.register(key, h, sess, payload=payload)
                sess.memgov.admit(reserve_bytes)
                admitted = reserve_bytes
                x = jnp.asarray(payload)
                # src == dst: the cached plan is a pure placement (pads only),
                # exactly the governor's refill path.
                plan, _hit = sess.relayout_cache.plan(
                    tuple(x.shape), x.dtype, self.engine_layout, self.engine_layout, sess.mesh
                )
                out = plan.apply(x)
                if plan.fused_path in FUSED_PATHS:
                    sess.stats.record_fused_relayout()
                # Engine-side bytes this placement moved (a shared-group view
                # records none — that is the zero-byte acceptance criterion).
                sess.stats.record_placement_bytes(int(out.nbytes))
                if block:
                    out.block_until_ready()
                h._host_fallback = payload
                with sess.memgov.lock:  # claim -> charge atomically
                    sess.memgov.settle(admitted)
                    admitted = 0
                    h.materialize(
                        out, pads=(out.shape[0] - h.shape[0], out.shape[1] - h.shape[1])
                    )
                    sess.memgov.charge(h)
                if attached:
                    sess.stats.record_cross_session_reuse()
                    store.record_attach()
                else:
                    # Priced analytically: no staging relayout ran, so the
                    # plan cache's hit rate must not see this (planned=False).
                    cost = transfer_cost(
                        h.shape, h.dtype, self.client_layout, self.engine_layout, sess.mesh
                    )
                    sess.stats.record_transfer(
                        TransferRecord(
                            direction="send",
                            cost=cost,
                            seconds=time.perf_counter() - t0,
                            planned=False,
                        )
                    )
                return h
            except BaseException as exc:
                h.fail(exc)
                raise
            finally:
                sess.memgov.settle(admitted)
                sess.memgov.unreserve(reserve_bytes)

        return sess.tasks.submit(task, label=f"attach:{name or h.id}")

    def _shared_view_source(self, entry: ResidentEntry) -> Optional[AlMatrix]:
        """A live materialized placement of ``entry`` sharable in place.

        The source must belong to another session on the *same* worker group
        with the same mesh geometry and engine layout — then its device
        array is directly valid for this session's handles and the attach
        needs no engine-side bytes (DESIGN.md §12 shared worker groups).
        """
        sess = self.session
        my_ids = [d.id for d in sess.worker_devices]
        for src in entry.live_handles():
            if src.session_id == sess.id:
                continue
            if src.layout != self.engine_layout:
                continue
            src_sess = self.engine.sessions.get(src.session_id)
            if src_sess is None:
                continue
            if [d.id for d in src_sess.worker_devices] != my_ids:
                continue
            if src_sess.mesh.devices.shape != sess.mesh.devices.shape:
                continue
            if src.state == handles_mod.MATERIALIZED and src._data is not None:
                return src
        return None

    def collect_async(self, h: Union[AlMatrix, AlFuture]) -> AlFuture:
        """Future of the client-side array for ``h`` (which may itself be a
        future or a still-pending handle)."""
        return self._submit_collect(h)

    def collect(self, h: Union[AlMatrix, AlFuture]) -> jax.Array:
        """Materialize an engine-resident matrix back on the client layout.
        The only path that moves bulk data engine→client (paper §3.3)."""
        return self._submit_collect(h).result()

    def _submit_collect(self, h: Union[AlMatrix, AlFuture]) -> AlFuture:
        self._check()
        return self.transport.submit_collect(self, h)

    def _local_submit_collect(self, h: Union[AlMatrix, AlFuture]) -> AlFuture:
        sess = self.session

        def task() -> jax.Array:
            live = sess.resolve(self._resolve_handle(h))
            # A spilled matrix's bytes already sit in the host store — the
            # client side of the machine. Serving the collect from there
            # skips a pointless refill (device_put + admission that may
            # evict live working-set matrices) for data that would be pulled
            # straight back off the device. The handle stays spilled; a later
            # engine-side consumption refills as usual. Cyclic layouts store
            # permuted rows, so they take the ordinary refill path.
            host = sess.memgov.host_payload(live)
            if host is not None and not live.layout.cyclic:
                # Priced analytically (transfer_cost), not via cache.plan():
                # no relayout ran, so the plan cache and its hit/miss rate
                # must not see this transfer (planned=False below).
                cost = transfer_cost(
                    live.shape, live.dtype, live.layout, self.client_layout, sess.mesh
                )
                t0 = time.perf_counter()
                out = jnp.asarray(host[: live.shape[0], : live.shape[1]])
                out.block_until_ready()
                rec = TransferRecord(
                    direction="receive",
                    cost=cost,
                    seconds=time.perf_counter() - t0,
                    planned=False,
                )
                sess.stats.record_transfer(rec)
                return out
            out, rec = timed_relayout(
                live.data(),
                self.client_layout,
                sess.mesh,
                src=live.layout,
                direction="receive",
                cache=sess.relayout_cache,
                block=True,  # collect crosses the bridge: always materialize
            )
            sess.stats.record_transfer(rec)
            return out

        return sess.tasks.submit(task, label="collect")

    def free_async(self, h: Union[AlMatrix, AlFuture]) -> AlFuture:
        self._check()
        return self.transport.free(self, h)

    def _local_free_async(self, h: Union[AlMatrix, AlFuture]) -> AlFuture:
        sess = self.session
        return sess.tasks.submit(
            lambda: sess.free_handle(self._resolve_handle(h)), label="free"
        )

    def free(self, h: Union[AlMatrix, AlFuture]) -> None:
        # Routed through the queue so frees stay FIFO-ordered behind any
        # already-submitted task that still consumes the handle.
        self.free_async(h).result()

    def _send_physical_shape(self, shape: Tuple[int, int]) -> Tuple[int, int]:
        """Physical shape a sent matrix will occupy once resident: the
        logical shape padded first for the client-layout staging put, then
        for the engine-layout relayout — the exact sequence the send task
        performs (pad_for + timed_relayout(strip=False)). Keep the two in
        lockstep: memgov reservations are priced off this prediction, and the
        eventual charge uses the materialized array's real shape."""
        if self.client_layout.cyclic or self.engine_layout.cyclic:
            return shape  # cyclic layouts are never pre-padded (see the task)
        mesh = self.session.mesh
        pr, pc = pad_amounts(shape, self.client_layout, mesh)
        phys = (shape[0] + pr, shape[1] + pc)
        pr, pc = pad_amounts(phys, self.engine_layout, mesh)
        return (phys[0] + pr, phys[1] + pc)

    def _resolve_handle(self, h: Union[AlMatrix, AlFuture]) -> AlMatrix:
        resolved = futures_mod.resolve(h)
        if isinstance(resolved, params_codec.HandleRef):
            # Wire decay: over a real transport an AlMatrix crosses as a
            # HandleRef; resolve it against the session table here, at task
            # time, so unknown/freed/foreign ids fail with the same
            # HandleError surface the in-process path has (resolve is
            # duck-typed over .id/.session_id).
            resolved = self.session.resolve(resolved)
        if not isinstance(resolved, AlMatrix):
            raise SessionError(
                f"expected an AlMatrix (or a future of one), got {type(resolved).__name__}"
            )
        return resolved

    # -- routine invocation ----------------------------------------------------
    def run_async(
        self,
        library: str,
        routine: str,
        *args: Any,
        _out_shapes: Optional[Sequence] = None,
        _out_dtype: Any = None,
        **params: Any,
    ) -> AlFuture:
        """Pipelined routine invocation: enqueue it and return a future of
        its (wrapped) outputs. Arguments may be AlMatrix handles, futures of
        handles from earlier async calls, or plain scalars; the compute is
        async-dispatched, so the worker immediately proceeds to the next task
        while XLA executes.

        ``_out_shapes`` / ``_out_dtype`` (internal) let a caller that already
        ran shape inference — the offload planner, whose operands are still
        futures here — pass the routine's output shapes and element type so
        the memory governor can reserve their bytes up front."""
        return self._submit_run(
            library,
            routine,
            args,
            params,
            block=False,
            out_shapes=_out_shapes,
            out_dtype=_out_dtype,
        )

    def run_eager(self, library: str, routine: str, *args: Any, **params: Any) -> Any:
        """Invoke ``library.routine`` on the engine (the paper's ``ac.run``).

        Positional args may be AlMatrix handles (resolved engine-side) or
        plain scalars; keyword params must be scalars/small lists and travel
        through the Parameters codec, exactly like the paper's driver-to-
        driver metadata channel.
        """
        return self._submit_run(library, routine, args, params, block=True).result()

    def _submit_run(
        self,
        library: str,
        routine: str,
        args: Tuple[Any, ...],
        params: Dict[str, Any],
        *,
        block: bool,
        out_shapes: Optional[Sequence] = None,
        out_dtype: Any = None,
    ) -> AlFuture:
        self._check()
        # Fail-fast validation stays caller-side in every transport: library
        # and routine existence (the session's library table is shared with
        # the engine-side core), then dispatch through the wire seam.
        lib = self.library(library)
        lib.routine(routine)  # unknown-routine errors fail fast, caller-side
        return self.transport.submit_run(
            self,
            library,
            routine,
            args,
            params,
            block=block,
            out_shapes=out_shapes,
            out_dtype=out_dtype,
        )

    def _local_submit_run(
        self,
        library: str,
        routine: str,
        args: Tuple[Any, ...],
        params: Dict[str, Any],
        *,
        block: bool,
        out_shapes: Optional[Sequence] = None,
        out_dtype: Any = None,
    ) -> AlFuture:
        lib = self.library(library)
        r = lib.routine(routine)
        sess = self.session
        label = f"{library}.{routine}"
        # Caller-side shape inference (per-routine rules, DESIGN.md §7): a
        # dimension mismatch raises ShapeError here, at the call site, and a
        # successful inference prices the routine's matrix outputs so the
        # governor can reserve their bytes before the task is enqueued. The
        # planner passes its own inference in (its operands are futures whose
        # shapes this layer cannot see).
        if out_shapes is None:
            out_shapes = infer_run_shapes(
                routine, [arg_shape(a) for a in args], params
            )
        reserve_bytes = 0
        if out_shapes:
            if out_dtype is None:
                # Best-known operand dtype: a handle directly, or one behind
                # an already-resolved future (the planner also passes an
                # explicit hint, since its operands may still be in flight).
                for a in args:
                    if isinstance(a, AlFuture) and a.done() and a.exception() is None:
                        a = a.result()
                    if isinstance(a, AlMatrix):
                        out_dtype = a.dtype
                        break
            itemsize = jnp.dtype(out_dtype).itemsize if out_dtype is not None else 4
            est = sum(
                int(np.prod(s)) for s in out_shapes if s is not None and len(s) == 2
            )
            reserve_bytes = sess.memgov.reserve(est * itemsize)

        def task() -> Any:
            # Resolve futures from earlier tasks (same-session ones are
            # guaranteed done: the FIFO ran their producers first).
            rargs = tuple(futures_mod.resolve(a) for a in args)
            rparams = {k: futures_mod.resolve(v) for k, v in params.items()}

            # Drive every scalar through the wire codec: this is the
            # driver->driver parameter frame of §2.1 (and catches
            # unserializable arguments at the API boundary, as the real
            # system would).
            frame = params_codec.pack(
                {f"__pos_{i}": a for i, a in enumerate(rargs)} | rparams
            )
            decoded = params_codec.unpack(frame)

            def handle_of(v: Any) -> Any:
                return sess.get_handle(v.id) if isinstance(v, params_codec.HandleRef) else v

            pos = [handle_of(decoded[f"__pos_{i}"]) for i in range(len(rargs))]
            kw = {
                k: handle_of(v)
                for k, v in decoded.items()
                if not k.startswith("__pos_")
            }
            inputs = [v for v in (*pos, *kw.values()) if isinstance(v, AlMatrix)]

            admitted = 0
            try:
                # Inputs stay pinned (unspillable) while the routine runs:
                # admission for the outputs must not evict an operand, and a
                # spilled operand refills exactly once. Reading .data()
                # inside the pin is what triggers those refills.
                with sess.memgov.pinned(inputs):
                    call_args = [
                        v.data() if isinstance(v, AlMatrix) else v for v in pos
                    ]
                    call_kwargs = {
                        k: (v.data() if isinstance(v, AlMatrix) else v)
                        for k, v in kw.items()
                    }
                    # Admit the outputs only after every operand is resolved:
                    # a .data() above may have refilled a spilled input, and
                    # room made earlier would have been eaten again. The
                    # claim holds the room against concurrent sessions until
                    # the outputs' charges land.
                    sess.memgov.admit(reserve_bytes)
                    admitted = reserve_bytes

                    if "mesh" in r.signature().parameters:
                        call_kwargs["mesh"] = sess.mesh

                    t0 = time.perf_counter()
                    with sess.mesh:
                        result = r.fn(*call_args, **call_kwargs)
                    if block:
                        result = jax.block_until_ready(result)
                    sess.stats.record_compute(time.perf_counter() - t0)

                    with sess.memgov.lock:  # claim -> charges atomically
                        sess.memgov.settle(admitted)
                        admitted = 0
                        return self._wrap_outputs(result, label)
            finally:
                sess.memgov.settle(admitted)
                sess.memgov.unreserve(reserve_bytes)

        return sess.tasks.submit(task, label=f"run:{label}")

    def _wrap_outputs(self, result: Any, label: str) -> Any:
        """Array outputs become engine-resident handles; scalars/vectors are
        non-distributed outputs and return to the driver directly."""
        if isinstance(result, (tuple, list)):
            wrapped = tuple(self._wrap_outputs(r, label) for r in result)
            return type(result)(wrapped) if isinstance(result, list) else wrapped
        if isinstance(result, jax.Array) and result.ndim == 2:
            return self.session.new_handle(result, self.engine_layout, name=label)
        if isinstance(result, jax.Array) and result.ndim <= 1:
            return np.asarray(result)
        return result

    # -- lazy offload planner -----------------------------------------------
    @property
    def planner(self):
        """This session's :class:`~repro.core.planner.OffloadPlanner` (lazily
        created, one per client so its resident-matrix cache and elision
        counters are session-scoped, DESIGN.md §6)::

            pl = ac.planner
            la = pl.send(a)
            u, s, v = pl.run("elemental", "truncated_svd", la, n_outputs=3, k=8)
            proj = pl.run("elemental", "gemm", la, u)   # u never leaves the engine
            P = pl.collect(proj)                        # the one bridge crossing
        """
        self._check()
        if self._planner is None:
            from repro.core.planner import OffloadPlanner

            self._planner = OffloadPlanner(self)
        return self._planner

    # -- lifecycle ---------------------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> None:
        """Barrier: block until every task this session has queued so far
        (sends, runs, collects, frees) has executed."""
        self._check()
        self.transport.barrier(self, timeout)

    @property
    def stats(self):
        return self.session.stats

    @property
    def mesh(self) -> Mesh:
        return self.session.mesh

    def rebind(
        self,
        engine: "AlchemistEngine",
        *,
        transport: Union[Transport, str, None] = None,
        placement: Optional[PlacementRequest] = None,
    ) -> "Session":
        """Fail this core over to another engine (fleet recovery,
        DESIGN.md §14).

        Re-admits through ``engine``'s queued connect path using the
        original admission kwargs (optionally overriding the placement),
        swaps the transport and engine-side session **in place** — live
        :class:`AlArray` handles keep working because they reference this
        core, never the dead session — re-registers the old session's
        wire-expressible libraries, and drops the planner's lowering memos
        so the next materialization replays exactly the DAG suffix whose
        engine-side outputs were lost. Returns the new engine-side session.
        """
        specs = dict(getattr(self.session, "library_specs", None) or {})
        kwargs = dict(self._admission)
        if placement is not None:
            kwargs["placement"] = placement
        self.engine = engine
        self.transport = resolve_transport(transport)
        self.session = self.transport.open_session(self, kwargs)
        for lname, spec in specs.items():
            self.transport.register_library(self, lname, spec)
        if self._planner is not None:
            self._planner.reset()
        self._stopped = False
        return self.session

    def stop(self) -> None:
        """Disconnect and release the worker group (paper's ``ac.stop()``).

        Queued tasks are drained first (their futures resolve), then the
        worker-group devices return to the engine pool in canonical order —
        waking any ``connect()`` queued for admission.
        """
        if not self._stopped:
            self.transport.close_session(self)
            self._stopped = True

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def _check(self) -> None:
        if self._stopped:
            raise SessionError(f"{type(self).__name__} has been stopped")


class AlArray(LazyMatrix):
    """The uniform v2 matrix handle: a deferred engine-resident array.

    Unifies the three v1 handle types (DESIGN.md §9): like a ``LazyMatrix``
    it is an expression node (ops chain without executing), like an
    ``AlFuture`` it can be waited on (``.result(timeout)`` / ``await``), and
    like an ``AlMatrix`` it names engine-resident data (``.state``,
    ``.free()``, ``.materialize()``). Whether building one *executes*
    anything is the owning session's :class:`ExecutionPolicy` — the handle
    API is identical under all three.

    - ``.data()`` / ``.result()`` / ``await`` — force the DAG through the
      planner and return the client-side value (the one bridge crossing).
    - ``.materialize()`` — force execution but keep matrix data
      engine-resident; returns the raw engine-side value.
    - ``.state`` — where the value physically is: ``deferred`` / ``pending``
      / ``materialized`` / ``spilled`` / ``failed`` / ``freed``.
    - ``.free()`` — release engine-side storage, if any was ever produced.
    """

    def __init__(self, expr, planner, session: "Session"):
        super().__init__(expr, planner)
        self._session = session

    # -- chaining (policy-aware: the session decides when this executes) -----
    def __matmul__(self, other: Any) -> "AlArray":
        lib, routine = self.planner.matmul_routine
        return self._session.run(lib, routine, self, other)

    def __rmatmul__(self, other: Any) -> "AlArray":
        lib, routine = self.planner.matmul_routine
        return self._session.run(lib, routine, other, self)

    # -- forcing -------------------------------------------------------------
    def data(self) -> Any:
        """Force execution through the planner and return the client-side
        value: an array for matrix nodes, the scalar/vector itself for
        driver-side routine outputs."""
        return self.planner.collect(self)

    def result(self, timeout: Optional[float] = None) -> Any:
        """AlFuture-compatible spelling of :meth:`data`. ``timeout`` bounds
        the wait for the engine-side execution (raises
        :class:`~repro.core.errors.TaskError` like a future would)."""
        if timeout is not None:
            futures_mod.resolve(self.planner.lower(self), timeout)
        return self.data()

    def __await__(self):
        """``await arr`` forces off the event loop's thread: the blocking
        planner collect runs in the default executor, so concurrent awaits
        on independent DAGs pipeline like the v1 ``*_async`` surface."""
        import asyncio

        loop = asyncio.get_running_loop()
        return loop.run_in_executor(None, self.data).__await__()

    # -- residency -----------------------------------------------------------
    @property
    def state(self) -> str:
        """Physical placement of this node's value (never forces execution)."""
        return peeked_state(self.planner.peek(self))

    def free(self) -> None:
        """Release the engine-side storage behind this node, if its lowering
        ever produced any. A deferred node has no resources; freeing it is a
        no-op (and a later force transparently re-executes, the documented
        planner semantics)."""
        val = self.planner.peek(self)
        if isinstance(val, AlFuture):
            if val.exception() is not None:  # blocks until the task settled
                return
            val = val.result()
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, AlMatrix) and v.is_live:
                self._session.free(v)

    def __repr__(self) -> str:
        return f"AlArray({self.expr!r}, state={self.state})"


class Session(ClientCore):
    """The v2 client session: uniform :class:`AlArray` handles, pluggable
    execution policy, admission-aware placement. Built by :func:`connect`.

    Every verb builds expression nodes; the session's policy decides when
    they execute. ``close()`` (or the context manager) drains the queue and
    returns the worker group — waking any queued ``connect()``.
    """

    def __init__(
        self,
        engine: "AlchemistEngine",
        *,
        name: str = "app",
        workers: Optional[int] = None,
        grid: Optional[Tuple[int, int]] = None,
        hbm_budget: Optional[int] = None,
        policy: PolicyLike = None,
        placement: Optional[PlacementRequest] = None,
        datasets: Any = _UNSET,
        queue: Any = _UNSET,
        timeout: Any = _UNSET,
        client_layout: LayoutSpec = ROW,
        engine_layout: LayoutSpec = GRID,
        transport: Union[Transport, str, None] = None,
    ):
        self._policy = as_policy(policy)
        # Coerce here (not in the core) so the v2 default applies: a Session
        # queues indefinitely unless the request says otherwise.
        placement = _coerce_placement(
            placement,
            workers=workers,
            grid=grid,
            datasets=datasets,
            queue=queue,
            timeout=timeout,
            default_queue=True,
        )
        super().__init__(
            engine,
            name=name,
            client_layout=client_layout,
            engine_layout=engine_layout,
            hbm_budget=hbm_budget,
            placement=placement,
            transport=transport,
        )

    # -- placement ------------------------------------------------------------
    @property
    def placement(self) -> PlacementTicket:
        """The resolved placement ticket (DESIGN.md §12): devices, shared or
        private, queue wait in ns, and the scheduler's scoring breakdown."""
        return self.session.placement

    # -- policy ---------------------------------------------------------------
    @property
    def execution_policy(self) -> ExecutionPolicy:
        return self._policy

    @contextlib.contextmanager
    def policy(self, policy: PolicyLike) -> Iterator["Session"]:
        """Scope an execution policy::

            with session.policy("eager"):
                b = session.send(B)     # executes (and blocks) immediately
        """
        prev = self._policy
        self._policy = as_policy(policy)
        try:
            yield self
        finally:
            self._policy = prev

    def _adopt(self, lazy: LazyMatrix) -> AlArray:
        arr = AlArray(lazy.expr, self.planner, self)
        self._policy.apply(self.planner, arr)
        return arr

    # -- the v2 verbs ---------------------------------------------------------
    def send(self, array: Any, name: str = "") -> AlArray:
        """Declare a host→engine transfer; returns an :class:`AlArray`.
        Equal payloads dedup (session-local and engine-wide); when the
        transfer happens is the execution policy's call."""
        self._check()
        return self._adopt(self.planner.send(array, name=name))

    def run(
        self,
        library: str,
        routine: str,
        *args: Any,
        n_outputs: int = 1,
        **params: Any,
    ):
        """Declare ``library.routine`` over AlArrays / host arrays / scalars;
        returns an :class:`AlArray` (or a tuple of them for
        ``n_outputs > 1``). Chains validate shapes at the call site."""
        self._check()
        out = self.planner.run(library, routine, *args, n_outputs=n_outputs, **params)
        if isinstance(out, tuple):
            return tuple(self._adopt(o) for o in out)
        return self._adopt(out)

    # -- uniform collect/free over v2 handles ---------------------------------
    def collect(self, h: Union[AlArray, AlMatrix, AlFuture]) -> Any:
        if isinstance(h, LazyMatrix):
            return self.planner.collect(h)
        return super().collect(h)

    def free(self, h: Union[AlArray, AlMatrix, AlFuture]) -> None:
        if isinstance(h, AlArray):
            h.free()
            return
        super().free(h)

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        """v2 spelling of :meth:`ClientCore.stop`."""
        self.stop()


def connect(
    engine: "AlchemistEngine",
    *,
    name: str = "app",
    workers: Optional[int] = None,
    grid: Optional[Tuple[int, int]] = None,
    hbm_budget: Optional[int] = None,
    policy: PolicyLike = None,
    placement: Optional[PlacementRequest] = None,
    datasets: Any = _UNSET,
    queue: Any = _UNSET,
    timeout: Any = _UNSET,
    client_layout: LayoutSpec = ROW,
    engine_layout: LayoutSpec = GRID,
    transport: Union[Transport, str, None] = None,
) -> Session:
    """Connect an application to an :class:`AlchemistEngine` (DESIGN.md §9).

    - ``placement`` is the declarative admission request (DESIGN.md §12): a
      :class:`~repro.core.scheduler.PlacementRequest` naming the group size,
      priority, content affinity, admission deadline, and whether a shared
      worker group may serve it. The resolved ticket is exposed as
      ``session.placement``.
    - ``workers`` / ``grid`` remain sugar for a request with just a size
      (default: every currently free device, queueing indefinitely).
    - ``policy`` selects execution: ``"planned"`` (default), ``"pipelined"``,
      ``"eager"`` — an :class:`ExecutionPolicy` name, class, or instance.
    - ``datasets`` / ``queue`` / ``timeout`` are the deprecated v1 admission
      kwargs; they keep working through a shim that folds them into the
      request (``affinity`` / ``deadline`` — see the §12 migration table).
    - ``hbm_budget`` folds into the engine-wide governor ceiling (§7).
    - ``transport`` selects the wire (DESIGN.md §11): ``"loopback"``
      (default; in-process, frames still encoded/decoded) or ``"tcp"``
      (a localhost socket to a threaded :class:`~repro.serve.wire.
      EngineServer` wrapping the engine). ``REPRO_TRANSPORT`` sets the
      process-wide default.
    """
    legacy: Dict[str, Any] = {}
    if datasets is not _UNSET:
        legacy["datasets"] = datasets
    if queue is not _UNSET:
        legacy["queue"] = queue
    if timeout is not _UNSET:
        legacy["timeout"] = timeout
    return Session(
        engine,
        name=name,
        workers=workers,
        grid=grid,
        hbm_budget=hbm_budget,
        policy=policy,
        placement=placement,
        client_layout=client_layout,
        engine_layout=engine_layout,
        transport=transport,
        **legacy,
    )


class AlchemistContext(ClientCore):
    """Deprecated v1 ACI — a thin shim over the v2 client core.

    The paper-era surface (``send``/``run``/``collect``/``*_async`` +
    ``ac.planner``) delegates to the same :class:`ClientCore` transport the
    v2 :class:`Session` uses, so behaviour, stats, and error surfaces are
    identical; only the entry point is deprecated. Migrate with the
    DESIGN.md §9 table: ``repro.connect(engine, workers=n)`` and uniform
    :class:`AlArray` handles replace the per-call choice between eager,
    async, and planner APIs.
    """

    def __init__(
        self,
        engine: "AlchemistEngine",
        num_workers: Optional[int] = None,
        *,
        name: str = "app",
        grid: Optional[Tuple[int, int]] = None,
        client_layout: LayoutSpec = ROW,
        engine_layout: LayoutSpec = GRID,
        hbm_budget: Optional[int] = None,
        transport: Union[Transport, str, None] = None,
    ):
        warnings.warn(
            "AlchemistContext is deprecated; connect with "
            "`session = repro.connect(engine, workers=...)` and use AlArray "
            "handles with an ExecutionPolicy (DESIGN.md §9 has the "
            "call-for-call migration table)",
            DeprecationWarning,
            stacklevel=2,
        )
        super().__init__(
            engine,
            num_workers,
            name=name,
            grid=grid,
            client_layout=client_layout,
            engine_layout=engine_layout,
            hbm_budget=hbm_budget,
            transport=transport,
        )

    # The v1 spellings: eager send/run under the classic names.
    send = ClientCore.send_eager
    run = ClientCore.run_eager
