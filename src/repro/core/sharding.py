"""Mesh-axis conventions and sharding rules shared by the whole framework.

One place defines what each mesh axis means; everything else (engine layouts,
model parameter shardings, train/serve steps, the dry-run) derives from here.

Axes:
  - ``pod``   — pure data parallelism across pods (gradient all-reduce crosses
                the inter-pod links once per step).
  - ``data``  — intra-pod data parallelism; also the FSDP axis for weights and
                the row axis of engine GRID layouts.
  - ``model`` — tensor parallelism (attention heads / MLP hidden / experts) and
                the column axis of engine GRID layouts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.layouts import AXIS_DATA, AXIS_MODEL, AXIS_POD


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    return tuple(a for a in (AXIS_POD, AXIS_DATA) if a in mesh.axis_names)


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes weights are fully-sharded over (ZeRO-3 style)."""
    return tuple(a for a in (AXIS_DATA,) if a in mesh.axis_names)


def model_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in (AXIS_MODEL,) if a in mesh.axis_names)


def _entry(axes: Tuple[str, ...]):
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def batch_entry(mesh: Mesh):
    return _entry(batch_axes(mesh))


def fsdp_entry(mesh: Mesh):
    return _entry(fsdp_axes(mesh))


def model_entry(mesh: Mesh):
    return _entry(model_axes(mesh))


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-dimension -> mesh-axes table, resolved per mesh.

    Model code annotates parameters/activations with logical axis names; this
    table maps them to mesh axes. Swapping the table is how the perf loop
    changes sharding schemes without touching model code.
    """

    batch: Tuple[str, ...]
    fsdp: Tuple[str, ...]        # weight row-shard axis (ZeRO)
    tensor: Tuple[str, ...]      # tensor-parallel axis
    expert: Tuple[str, ...]      # expert-parallel axis
    sequence: Tuple[str, ...] = ()   # sequence/context parallel axis (opt-in)

    @staticmethod
    def default(mesh: Mesh) -> "ShardingRules":
        return ShardingRules(
            batch=batch_axes(mesh),
            fsdp=fsdp_axes(mesh),
            tensor=model_axes(mesh),
            expert=model_axes(mesh),
            sequence=(),
        )

    @staticmethod
    def zero3(mesh: Mesh) -> "ShardingRules":
        """ZeRO-3: weights fully sharded over data AND model axes, no tensor
        parallelism — trades activation all-reduces for per-layer parameter
        all-gathers (the deepseek-33b hillclimb hypothesis)."""
        return ShardingRules(
            batch=batch_axes(mesh),
            fsdp=tuple(a for a in (AXIS_DATA, AXIS_MODEL) if a in mesh.axis_names),
            tensor=(),
            expert=model_axes(mesh),
            sequence=(),
        )

    @staticmethod
    def zero3_full(mesh: Mesh) -> "ShardingRules":
        """ZeRO-3 done right: with no tensor axis, the model axis must join
        the batch axes (pure 256-way data parallelism), otherwise per-device
        compute inflates by the idle axis — the refuted first zero3 attempt."""
        axes = tuple(a for a in (AXIS_POD, AXIS_DATA, AXIS_MODEL) if a in mesh.axis_names)
        return ShardingRules(
            batch=axes,
            fsdp=tuple(a for a in (AXIS_DATA, AXIS_MODEL) if a in mesh.axis_names),
            tensor=(),
            expert=model_axes(mesh),
            sequence=(),
        )

    @staticmethod
    def seq_parallel(mesh: Mesh) -> "ShardingRules":
        """Default rules + sequence sharding of residuals over the model
        axis (Megatron sequence parallelism): activation all-reduces become
        reduce-scatter + all-gather pairs."""
        base = ShardingRules.default(mesh)
        return dataclasses.replace(base, sequence=model_axes(mesh))

    @staticmethod
    def fsdp_only(mesh: Mesh) -> "ShardingRules":
        """Pure data-parallel scheme — the 'Spark-like' 1D world: no tensor
        axis; the model axis is folded into batch. Used as the paper-faithful
        'what Spark alone gives you' comparison point."""
        axes = tuple(a for a in (AXIS_POD, AXIS_DATA, AXIS_MODEL) if a in mesh.axis_names)
        return ShardingRules(batch=axes, fsdp=(), tensor=(), expert=(), sequence=())

    def resolve(self, logical: Tuple[Optional[str], ...]) -> P:
        """Map a tuple of logical dim names to a PartitionSpec."""
        table = {
            "batch": _entry(self.batch),
            "fsdp": _entry(self.fsdp),
            "tensor": _entry(self.tensor),
            "expert": _entry(self.expert),
            "sequence": _entry(self.sequence),
            None: None,
        }
        entries = []
        used: set = set()
        for name in logical:
            if name not in table:
                raise KeyError(f"unknown logical axis {name!r}")
            entry = table[name]
            # a mesh axis may appear at most once per spec: first dim wins
            # (e.g. zero3_full on MoE weights: 'model' serves the expert dim,
            # so the fsdp entry of the same tensor drops it)
            if entry is None:
                entries.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            kept = tuple(a for a in axes if a not in used)
            used.update(kept)
            entries.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        return P(*entries)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Build a mesh from the available devices (works on the 1-CPU test env
    when shape == (1,)*n, and on the 512-host-device dry-run env)."""
    return jax.make_mesh(shape, axes)


def single_device_mesh() -> Mesh:
    """A (1, 1) ('data','model') mesh on the default device — used by smoke
    tests and CPU examples so the same sharded code paths run everywhere."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, (AXIS_DATA, AXIS_MODEL))


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _axis_prod(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


def divisible_spec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh doesn't divide evenly.

    ``with_sharding_constraint`` / pjit out-shardings reject uneven dims;
    this keeps every legal annotation and silently replicates the rest
    (XLA would have padded anyway — we prefer the explicit fallback).
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        out.append(entry if dim % _axis_prod(mesh, entry) == 0 else None)
    return P(*out)


def constrain(x, spec: P, mesh: Mesh):
    """Divisibility-safe ``with_sharding_constraint``."""
    safe = divisible_spec(tuple(x.shape), spec, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, safe))
