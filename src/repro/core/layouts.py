"""Layout descriptors for distributed matrices.

The paper bridges two layout worlds:

- Spark's ``IndexedRowMatrix``: rows partitioned contiguously across
  executors (a 1D row decomposition).
- Elemental's ``DistMatrix``: elements distributed cyclically over a 2D
  ``MC x MR`` process grid.

On TPU both worlds are shardings of one device mesh, so a "layout" here is a
named :class:`LayoutSpec` that resolves to a :class:`jax.sharding.PartitionSpec`
against the mesh-axis conventions in :mod:`repro.core.sharding`:

- :data:`ROW`        — ``P(('pod','data','model'), None)``: the Spark/ingest
  side — a pure 1D row decomposition over every device, which is what a
  per-host data pipeline naturally produces (each "executor" owns a slab of
  rows and all columns).
- :data:`GRID`       — ``P(('pod','data'), 'model')``: the Elemental side —
  a 2D block decomposition over the full mesh; ROW→GRID is a genuine
  all-to-all redistribution, the TPU analogue of the paper's socket transfer.
- :data:`COLUMN`     — ``P(None, ('pod','data','model'))``: column-partitioned
  (Spark's post-"explosion" layout when it transposes for multiplies).
- :data:`REPLICATED` — ``P(None, None)``: small operands / results.

Elemental's layout is block-*cyclic* to balance load for algorithms that walk
the matrix (LU, QR panels). XLA shardings are block-contiguous; we provide a
cyclic *emulation* (an explicit row/column permutation before a GRID layout)
for workloads with skewed row norms, and document that on TPU the MXU favours
contiguous 128-aligned tiles, so block layout is the native choice
(DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.errors import LayoutError

# Canonical mesh axis names used across the framework.
AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_MODEL = "model"


@dataclasses.dataclass(frozen=True)
class LayoutSpec:
    """A named distributed-matrix layout.

    Attributes:
      name: human-readable layout name.
      row_axes: mesh axes the row dimension is sharded over.
      col_axes: mesh axes the column dimension is sharded over.
      cyclic: if True, the layout is the block-cyclic emulation — the matrix
        rows are stored permuted (see :func:`cyclic_permutation`) and the
        physical sharding is the same as the non-cyclic variant.
    """

    name: str
    row_axes: Tuple[str, ...]
    col_axes: Tuple[str, ...]
    cyclic: bool = False

    def partition_spec(self, mesh: Mesh, *, leading_batch: int = 0) -> P:
        """Resolve to a PartitionSpec, keeping only axes present in ``mesh``.

        ``leading_batch`` prepends that many unsharded dimensions (for
        stacked/batched matrices).
        """
        present = set(mesh.axis_names)
        rows = tuple(a for a in self.row_axes if a in present)
        cols = tuple(a for a in self.col_axes if a in present)
        row_entry = rows if len(rows) > 1 else (rows[0] if rows else None)
        col_entry = cols if len(cols) > 1 else (cols[0] if cols else None)
        return P(*([None] * leading_batch), row_entry, col_entry)

    def sharding(self, mesh: Mesh, *, leading_batch: int = 0) -> NamedSharding:
        return NamedSharding(mesh, self.partition_spec(mesh, leading_batch=leading_batch))

    def grid_shape(self, mesh: Mesh) -> Tuple[int, int]:
        """(row shards, col shards) under ``mesh`` — the process-grid shape."""
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        def axes_prod(axes):
            return int(np.prod([sizes[a] for a in axes if a in sizes], dtype=np.int64))

        r = axes_prod(self.row_axes) if self.row_axes else 1
        c = axes_prod(self.col_axes) if self.col_axes else 1
        return max(r, 1), max(c, 1)

    def validate(self, shape: Sequence[int], mesh: Mesh) -> None:
        """Check the matrix is shardable under this layout (with padding XLA
        would insert, any shape is *legal*; we reject only rank problems)."""
        if len(shape) != 2:
            raise LayoutError(
                f"layout {self.name!r} applies to 2D matrices, got shape {tuple(shape)}"
            )

    def with_cyclic(self) -> "LayoutSpec":
        return dataclasses.replace(self, name=self.name + "_cyclic", cyclic=True)


# The four canonical layouts (axis names absent from a mesh are dropped at
# resolution time, so the same specs work on (data, model) and
# (pod, data, model) meshes, and on small test meshes).
ROW = LayoutSpec("row", row_axes=(AXIS_POD, AXIS_DATA, AXIS_MODEL), col_axes=())
GRID = LayoutSpec("grid", row_axes=(AXIS_POD, AXIS_DATA), col_axes=(AXIS_MODEL,))
COLUMN = LayoutSpec("column", row_axes=(), col_axes=(AXIS_POD, AXIS_DATA, AXIS_MODEL))
REPLICATED = LayoutSpec("replicated", row_axes=(), col_axes=())

_BY_NAME = {spec.name: spec for spec in (ROW, GRID, COLUMN, REPLICATED)}


def by_name(name: str) -> LayoutSpec:
    base = name.removesuffix("_cyclic")
    if base not in _BY_NAME:
        raise LayoutError(f"unknown layout {name!r}; known: {sorted(_BY_NAME)}")
    spec = _BY_NAME[base]
    return spec.with_cyclic() if name.endswith("_cyclic") else spec


def cyclic_permutation(n: int, n_shards: int) -> np.ndarray:
    """Permutation emulating Elemental's element-cyclic distribution.

    ``perm[i]`` is the source row stored at physical position ``i``: physical
    shard ``s`` holds logical rows ``s, s + n_shards, s + 2*n_shards, ...``.
    Applying ``x[perm]`` then sharding block-contiguously over ``n_shards``
    reproduces the cyclic assignment.
    """
    if n_shards <= 0:
        raise LayoutError(f"n_shards must be positive, got {n_shards}")
    pad = (-n) % n_shards
    idx = np.arange(n + pad).reshape(-1, n_shards).T.reshape(-1)
    return idx[idx < n]


def inverse_permutation(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.shape[0])
    return inv
