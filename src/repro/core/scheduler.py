"""Unified placement scheduler: tickets, scoring, watermarks, shared groups.

This module replaces the condition-variable scramble that used to live in
``AlchemistEngine.allocate()`` with a single ``PlacementScheduler`` owning the
free-device pool. Alchemist's allocation story (arXiv:1806.01270) — and the
deployment study that followed it (arXiv:1910.01354) — both land on the same
observation: once an MPI-side resource pool is shared by many Spark-side
clients, *placement policy* dominates multi-tenant behaviour. The scheduler
gives that policy one surface:

- **Declarative admission.** Callers describe what they need with a
  :class:`PlacementRequest` (workers, priority, content affinity, deadline,
  shareability) instead of a sprawl of ``queue=``/``timeout=``/``datasets=``
  kwargs. The engine converts legacy kwargs into a request via a deprecation
  shim, so policy decisions live in exactly one data structure.

- **Ticketed FIFO with anti-starvation aging.** Each admission attempt is a
  :class:`PlacementTicket` moving through ``queued -> scored -> placed |
  timed-out | cancelled``. Tickets are serviced in priority-then-arrival
  order, but a small request may overtake a blocked larger one at most
  ``aging_bound`` times: once a ticket has been passed by that many
  later-arriving requests, it becomes a barrier and nothing younger places
  until it does. (Preemption is out of scope, but the state machine leaves
  room for a future ``preempted`` edge out of ``placed``.)

- **Smallest-fit + content-affinity scoring.** Free devices are kept in
  canonical engine order; candidate windows are scored first by overlap with
  the devices already holding the request's declared datasets (via
  ``ResidentStore.device_affinity``), then by tightest contiguous fit, so
  small requests stop fragmenting large contiguous runs.

- **Pressure watermarks.** Admission consults ``memgov.pressure()`` in
  addition to the free-device count: above the high watermark new private
  placements stop, and they resume only once pressure falls below the low
  watermark (hysteresis, so admission does not flap at the boundary).

- **Shared worker groups.** Every placement is a refcounted
  :class:`WorkerGroup`. A request whose affinity keys all resolve to content
  live on one existing group *joins* that group instead of placing anew —
  one physical placement, many reader sessions — which is what makes
  content-affine attach zero-byte on the engine side.

The scheduler deliberately knows nothing about JAX: it trades in opaque
device objects (anything with an ``.id``), so unit tests drive it with fakes
and the engine keeps mesh construction to itself.
"""

from __future__ import annotations

import itertools
import math
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.errors import AdmissionTimeout, WorkerAllocationError

__all__ = [
    "PlacementRequest",
    "PlacementTicket",
    "WorkerGroup",
    "PlacementScheduler",
    "QUEUED",
    "SCORED",
    "PLACED",
    "TIMED_OUT",
    "CANCELLED",
]

# Ticket lifecycle states. Terminal states are PLACED / TIMED_OUT / CANCELLED;
# a future preemption edge would re-queue a PLACED ticket, which is why the
# state strings live here rather than inline.
QUEUED = "queued"
SCORED = "scored"
PLACED = "placed"
TIMED_OUT = "timed-out"
CANCELLED = "cancelled"

# Poll interval while a ticket waits on state the scheduler is not directly
# notified about (governor pressure decaying below the low watermark, or a
# dataset landing that would enable a shared-group join).
_POLL_S = 0.05


def near_square_grid(n: int) -> Tuple[int, int]:
    """Pick the most-square (rows, cols) grid for ``n`` workers."""
    best = (1, n)
    for r in range(1, int(math.sqrt(n)) + 1):
        if n % r == 0:
            best = (r, n // r)
    return best


@dataclass(frozen=True)
class PlacementRequest:
    """Declarative admission request — the v2 replacement for kwarg sprawl.

    Attributes
    ----------
    workers:
        Worker-group size. ``None`` means "all currently free devices"
        (or the whole engine when the pool is drained), pinned at submit
        time like v1 ``num_workers=None``.
    grid:
        Explicit ``(rows, cols)`` worker grid; overrides ``workers``.
    priority:
        Higher priorities are serviced first; ties break by arrival order.
    affinity:
        Datasets (arrays, ``AlArray`` handles, or content-key tuples) this
        session intends to read. Steers placement toward devices already
        holding that content, and — when every key resolves to one live
        worker group — lets the session *join* that group (see
        ``allow_shared``).
    deadline:
        Admission deadline in seconds. ``None`` waits indefinitely, ``0``
        fails fast when no placement is possible right now (v1
        ``queue=False``), positive values raise ``AdmissionTimeout`` on
        expiry (v1 ``queue=True, timeout=...``).
    allow_shared:
        Permit joining an existing worker group when affinity content is
        live there. Shared placements add no engine-side bytes; set False
        to force a private placement.
    """

    workers: Optional[int] = None
    grid: Optional[Tuple[int, int]] = None
    priority: int = 0
    affinity: Tuple[Any, ...] = ()
    deadline: Optional[float] = None
    allow_shared: bool = True

    def __post_init__(self) -> None:
        # Accept lists/generators for ergonomics; store a tuple so the
        # dataclass stays hashable-in-spirit (payload arrays are not
        # hashable, but the container is immutable).
        if not isinstance(self.affinity, tuple):
            object.__setattr__(self, "affinity", tuple(self.affinity))
        if self.grid is not None and not isinstance(self.grid, tuple):
            object.__setattr__(self, "grid", tuple(self.grid))


@dataclass
class WorkerGroup:
    """A physical placement: a device block plus the sessions reading it."""

    id: int
    devices: List[Any]
    grid: Tuple[int, int]
    refcount: int = 1
    session_ids: set = field(default_factory=set)

    @property
    def device_ids(self) -> FrozenSet[int]:
        return frozenset(d.id for d in self.devices)


@dataclass
class PlacementTicket:
    """One admission attempt moving through the scheduler state machine."""

    id: int
    seq: int
    n: int
    grid: Tuple[int, int]
    priority: int = 0
    keys: Tuple[Tuple[Any, ...], ...] = ()
    allow_shared: bool = True
    flexible: bool = False  # workers=None and grid=None: may adopt a group's size
    state: str = QUEUED
    passed_by: int = 0
    aged: bool = False
    shared: bool = False
    devices: Optional[List[Any]] = None
    group: Optional[WorkerGroup] = None
    score: Dict[str, int] = field(default_factory=dict)
    pressure_at_queue: int = 0
    pressure_at_placement: Optional[int] = None
    queued_ns: int = 0
    wait_ns: int = 0

    def summary(self) -> Dict[str, Any]:
        """JSON-serializable view of the resolved ticket."""
        return {
            "id": self.id,
            "state": self.state,
            "workers": self.n,
            "grid": list(self.grid),
            "priority": self.priority,
            "shared": self.shared,
            "devices": [getattr(d, "id", None) for d in (self.devices or [])],
            "wait_ns": int(self.wait_ns),
            "passed_by": self.passed_by,
            "score": dict(self.score),
            "pressure_at_queue": int(self.pressure_at_queue),
            "pressure_at_placement": (
                None if self.pressure_at_placement is None else int(self.pressure_at_placement)
            ),
        }


class PlacementScheduler:
    """FIFO ticket queue owning the engine's free-device pool.

    The scheduler holds the only mutable view of which devices are free. All
    admission flows through :meth:`submit`; all release flows through
    :meth:`release_session` / :meth:`abort`. Lock ordering: the scheduler's
    condition lock may be held while calling into the memory governor or the
    resident store (both take their own locks); neither ever calls back into
    the scheduler, so the ordering is acyclic.
    """

    def __init__(
        self,
        devices: Sequence[Any],
        *,
        memgov: Any,
        residents: Any,
        aging_bound: int = 4,
    ) -> None:
        if aging_bound < 1:
            raise ValueError(f"aging_bound must be >= 1, got {aging_bound}")
        self.devices: List[Any] = list(devices)
        self.memgov = memgov
        self.residents = residents
        self.aging_bound = int(aging_bound)

        self._free: List[Any] = list(self.devices)
        self._cond = threading.Condition(threading.Lock())
        self._queue: List[PlacementTicket] = []
        self._groups: Dict[int, WorkerGroup] = {}
        self._by_session: Dict[int, WorkerGroup] = {}
        self._ticket_ids = itertools.count(1)
        self._group_ids = itertools.count(1)
        self._seq = itertools.count(1)
        self._waiting = 0

        # Externally-visible admission counters. The first five keys predate
        # the scheduler and are asserted by tests/benchmarks; keep them.
        self.admissions: Dict[str, Any] = {
            "immediate": 0,
            "queued": 0,
            "timeouts": 0,
            "affinity_hits": 0,
            "last_queued_pressure": None,
            "pressure_at_placement": None,
            "smallest_fit_hits": 0,
        }
        # Scheduler-lifecycle counters surfaced via stats().
        self._placed = 0
        self._timed_out = 0
        self._cancelled = 0
        self._aged = 0
        self._shared_joins = 0
        self._pressure_blocked = 0

    # ------------------------------------------------------------------ #
    # Introspection                                                      #
    # ------------------------------------------------------------------ #

    @property
    def free_devices(self) -> List[Any]:
        """The free pool in canonical engine order (read-only snapshot)."""
        with self._cond:
            return list(self._free)

    @property
    def queued(self) -> int:
        """Number of tickets currently blocked in the queue."""
        with self._cond:
            return self._waiting

    def stats(self) -> Dict[str, Any]:
        """JSON-serializable scheduler section for ``engine.stats()``."""
        with self._cond:
            shared_groups = sum(1 for g in self._groups.values() if g.refcount > 1)
            wm = getattr(self.memgov, "watermarks", None)
            return {
                "queue_depth": len(self._queue),
                "free_workers": len(self._free),
                "placed": self._placed,
                "timed_out": self._timed_out,
                "cancelled": self._cancelled,
                "aged": self._aged,
                "groups": len(self._groups),
                "shared_groups": shared_groups,
                "shared_joins": self._shared_joins,
                "affinity_hits": self.admissions["affinity_hits"],
                "smallest_fit_hits": self.admissions["smallest_fit_hits"],
                "pressure_blocked": self._pressure_blocked,
                "aging_bound": self.aging_bound,
                "watermarks": None if wm is None else list(wm),
            }

    # ------------------------------------------------------------------ #
    # Admission                                                          #
    # ------------------------------------------------------------------ #

    def submit(
        self,
        request: PlacementRequest,
        *,
        keys: Sequence[Tuple[Any, ...]] = (),
    ) -> PlacementTicket:
        """Queue a request and block until it places or its deadline expires.

        ``keys`` are the resolved content keys for ``request.affinity`` (the
        engine normalizes arrays/handles to keys so the scheduler never
        touches payload bytes). Returns the PLACED ticket; raises
        ``WorkerAllocationError`` for impossible or fail-fast requests and
        ``AdmissionTimeout`` when a positive deadline expires.
        """
        if request.grid is not None:
            rows, cols = request.grid
            if rows <= 0 or cols <= 0:
                raise WorkerAllocationError(
                    f"requested a {rows}x{cols} grid; both dimensions must be positive"
                )
        elif request.workers is not None and request.workers <= 0:
            raise WorkerAllocationError(
                f"requested {request.workers} workers; need at least 1"
            )

        with self._cond:
            # Pin the request size now (v1 semantics): a flexible request on
            # a drained pool asks for the whole engine and waits for it.
            if request.grid is not None:
                rows, cols = request.grid
                n = rows * cols
                grid = (rows, cols)
            elif request.workers is not None:
                n = int(request.workers)
                grid = near_square_grid(n)
            else:
                n = len(self._free) if self._free else len(self.devices)
                grid = near_square_grid(n)

            if n > len(self.devices):
                raise WorkerAllocationError(
                    f"requested {n} workers but the engine only has {len(self.devices)}"
                )

            ticket = PlacementTicket(
                id=next(self._ticket_ids),
                seq=next(self._seq),
                n=n,
                grid=grid,
                priority=int(request.priority),
                keys=tuple(keys),
                allow_shared=bool(request.allow_shared),
                flexible=request.workers is None and request.grid is None,
                pressure_at_queue=int(self.memgov.pressure()),
                queued_ns=time.monotonic_ns(),
            )
            self._queue.append(ticket)
            deadline = None if request.deadline is None else time.monotonic() + request.deadline
            waited = False
            try:
                while True:
                    self._pass_locked()
                    if ticket.state == PLACED:
                        self.admissions["queued" if waited else "immediate"] += 1
                        return ticket
                    if request.deadline is not None and request.deadline <= 0:
                        ticket.state = CANCELLED
                        self._cancelled += 1
                        raise WorkerAllocationError(
                            f"requested {n} workers but only {len(self._free)} of "
                            f"{len(self.devices)} are available"
                        )
                    remaining = None if deadline is None else deadline - time.monotonic()
                    if remaining is not None and remaining <= 0:
                        ticket.state = TIMED_OUT
                        self._timed_out += 1
                        self.admissions["timeouts"] += 1
                        raise AdmissionTimeout(
                            f"connect queued for {request.deadline}s waiting for {n} "
                            f"worker(s); {len(self._free)} of {len(self.devices)} free"
                        )
                    if not waited:
                        waited = True
                        self._waiting += 1
                    # Device releases notify the condition directly; pressure
                    # decay and dataset arrival do not, so poll when either
                    # could unblock this ticket.
                    poll = (
                        _POLL_S
                        if (ticket.keys or getattr(self.memgov, "has_watermarks", False))
                        else None
                    )
                    if remaining is None:
                        self._cond.wait(poll)
                    else:
                        self._cond.wait(remaining if poll is None else min(remaining, poll))
            finally:
                if waited:
                    self._waiting -= 1
                if ticket.state != PLACED and ticket in self._queue:
                    self._queue.remove(ticket)

    def _pass_locked(self) -> None:
        """One scheduling pass: place every ticket that can place right now.

        Service order is priority-then-arrival. An *aged* ticket (passed by
        ``aging_bound`` later arrivals) becomes a barrier: no ticket that
        arrived after the oldest aged ticket may place until it does.
        """
        if self._queue:
            # Satellite fix: sample governor pressure on *every* pass with a
            # non-empty queue, not only when a wait begins.
            self.admissions["last_queued_pressure"] = int(self.memgov.pressure())
        while True:
            waiting = [t for t in self._queue if t.state in (QUEUED, SCORED)]
            if not waiting:
                return
            barrier = min(
                (t.seq for t in waiting if t.passed_by >= self.aging_bound),
                default=None,
            )
            placed = None
            for ticket in sorted(waiting, key=lambda t: (-t.priority, t.seq)):
                if barrier is not None and ticket.seq > barrier:
                    continue
                if self._try_place_locked(ticket):
                    placed = ticket
                    break
            if placed is None:
                return
            for other in self._queue:
                if other.seq < placed.seq and other.state in (QUEUED, SCORED):
                    other.passed_by += 1
                    if other.passed_by >= self.aging_bound and not other.aged:
                        other.aged = True
                        self._aged += 1
            self._cond.notify_all()

    def _try_place_locked(self, ticket: PlacementTicket) -> bool:
        ticket.state = SCORED
        # 1. Shared worker group: all affinity keys live on one existing
        #    group -> join it. No devices consumed, no pressure gate (the
        #    bytes are already placed; a reader adds none).
        if ticket.allow_shared and ticket.keys:
            group = self._shared_match_locked(ticket)
            if group is not None:
                group.refcount += 1
                ticket.devices = list(group.devices)
                ticket.grid = group.grid
                ticket.n = len(group.devices)
                ticket.shared = True
                ticket.group = group
                ticket.score = {"affinity": ticket.n, "fit": 0}
                self._shared_joins += 1
                self._finish_placement_locked(ticket)
                return True
        # 2. Pressure watermarks gate *private* placements only.
        if getattr(self.memgov, "has_watermarks", False) and self.memgov.admission_gate():
            self._pressure_blocked += 1
            return False
        # 3. Private placement from the free pool.
        if 0 < ticket.n <= len(self._free):
            devices, score = self._score_block_locked(ticket.n, ticket.keys)
            chosen = {d.id for d in devices}
            self._free = [d for d in self._free if d.id not in chosen]
            group = WorkerGroup(
                id=next(self._group_ids),
                devices=list(devices),
                grid=ticket.grid,
                refcount=1,
            )
            self._groups[group.id] = group
            ticket.devices = list(devices)
            ticket.group = group
            ticket.score = score
            self._finish_placement_locked(ticket)
            return True
        return False

    def _finish_placement_locked(self, ticket: PlacementTicket) -> None:
        ticket.state = PLACED
        pressure = int(self.memgov.pressure())
        ticket.pressure_at_placement = pressure
        self.admissions["pressure_at_placement"] = pressure
        ticket.wait_ns = time.monotonic_ns() - ticket.queued_ns
        self._placed += 1
        if ticket in self._queue:
            self._queue.remove(ticket)

    def _shared_match_locked(self, ticket: PlacementTicket) -> Optional[WorkerGroup]:
        """Find the live group holding *all* of the ticket's affinity keys."""
        affinity = self.residents.device_affinity(ticket.keys)
        if not affinity:
            return None
        id_sets = set(affinity)
        if len(id_sets) != 1:
            return None  # content is split across placements; no single group
        ids = next(iter(id_sets))
        for group in self._groups.values():
            if group.refcount > 0 and group.device_ids == ids:
                if ticket.flexible or ticket.n == len(group.devices):
                    return group
        return None

    # ------------------------------------------------------------------ #
    # Scoring                                                            #
    # ------------------------------------------------------------------ #

    def pick_block(self, n: int, keys: Sequence[Tuple[Any, ...]]) -> List[Any]:
        """Score-and-pick ``n`` free devices without consuming them.

        Kept public for the engine's legacy ``_pick_block`` delegate and for
        tests that probe scoring in isolation; placement itself removes the
        chosen window from the pool under the same lock hold.
        """
        with self._cond:
            if n > len(self._free):
                # Legacy preview semantics: a drained pool yields a short (or
                # empty) block rather than raising — placement proper never
                # takes this path because submit() checks capacity first.
                return list(self._free[:n])
            devices, _ = self._score_block_locked(n, tuple(keys))
            return devices

    def _score_block_locked(
        self, n: int, keys: Tuple[Tuple[Any, ...], ...]
    ) -> Tuple[List[Any], Dict[str, int]]:
        """Choose the best n-device window: max affinity, then tightest fit.

        The free list is kept in canonical engine order, so contiguous runs
        of it correspond to contiguous device blocks. Windows inside runs are
        scored ``(affinity_overlap, -run_length, -start)`` and the max wins:
        prefer content-warm devices, then the smallest run that fits
        (smallest-fit keeps large contiguous runs intact for large tickets),
        then the earliest window for determinism.
        """
        free = self._free
        # Keyed by device id (not the object): fake devices in unit tests
        # need not be hashable, and ids are unique within an engine.
        canon = {d.id: i for i, d in enumerate(self.devices)}
        runs: List[Tuple[int, int]] = []  # (start index in free list, length)
        start = 0
        for i in range(1, len(free) + 1):
            if i == len(free) or canon[free[i].id] != canon[free[i - 1].id] + 1:
                runs.append((start, i - start))
                start = i
        affinity = self.residents.device_affinity(keys) if keys else []

        def windows():
            fitting = [r for r in runs if r[1] >= n]
            if fitting:
                for run_start, run_len in fitting:
                    for i in range(run_start, run_start + run_len - n + 1):
                        yield i, run_len
            else:
                # No single run fits: span runs (legacy v1 behaviour, which
                # always took the first n free devices).
                for i in range(len(free) - n + 1):
                    yield i, len(free)

        best = None
        max_run = 0
        for i, run_len in windows():
            max_run = max(max_run, run_len)
            aff = 0
            if affinity:
                ids = {d.id for d in free[i : i + n]}
                aff = sum(len(ids & devs) for devs in affinity)
            cand = (aff, -run_len, -i)
            if best is None or cand > best:
                best = cand
        if best is None:
            raise WorkerAllocationError(
                f"requested {n} workers but only {len(free)} of {len(self.devices)} are available"
            )
        aff, neg_run, neg_i = best
        if aff > 0:
            self.admissions["affinity_hits"] += 1
        if -neg_run < max_run:
            self.admissions["smallest_fit_hits"] += 1
        i = -neg_i
        return list(free[i : i + n]), {"affinity": aff, "fit": -neg_run}

    # ------------------------------------------------------------------ #
    # Binding and release                                                #
    # ------------------------------------------------------------------ #

    def bind(self, ticket: PlacementTicket, session_id: int) -> None:
        """Associate a placed ticket's group with a session for release."""
        with self._cond:
            if ticket.group is not None:
                ticket.group.session_ids.add(session_id)
                self._by_session[session_id] = ticket.group

    def orphan(self, ticket: PlacementTicket) -> None:
        """Detach a placed ticket from group tracking (legacy ``allocate``).

        The devices stay out of the pool; the caller is responsible for
        returning them via :meth:`release_devices`.
        """
        with self._cond:
            if ticket.group is not None and not ticket.shared:
                self._groups.pop(ticket.group.id, None)
                ticket.group = None

    def abort(self, ticket: PlacementTicket) -> None:
        """Undo a placement whose session construction failed."""
        with self._cond:
            group = ticket.group
            if group is None:
                return
            ticket.group = None
            group.refcount -= 1
            if group.refcount <= 0:
                self._groups.pop(group.id, None)
                self._return_locked(group.devices)
            self._cond.notify_all()

    def release_session(self, session_id: int, devices: Sequence[Any]) -> None:
        """Return a session's placement to the pool (or drop a group ref)."""
        with self._cond:
            group = self._by_session.pop(session_id, None)
            if group is not None:
                group.session_ids.discard(session_id)
                group.refcount -= 1
                if group.refcount <= 0:
                    self._groups.pop(group.id, None)
                    self._return_locked(group.devices)
            else:
                # Session was never bound (legacy allocate path): trust the
                # caller's device list.
                self._return_locked(devices)
            self._cond.notify_all()

    def release_devices(self, devices: Sequence[Any]) -> None:
        """Return raw devices to the pool (legacy ``allocate`` callers)."""
        with self._cond:
            self._return_locked(devices)
            self._cond.notify_all()

    def _return_locked(self, devices: Sequence[Any]) -> None:
        returned = {d.id for d in devices} | {d.id for d in self._free}
        # Canonical order restore: freed devices slot back into engine order
        # so contiguous-run scoring stays meaningful.
        self._free = [d for d in self.devices if d.id in returned]

    def kick(self) -> None:
        """Wake waiters to re-evaluate (e.g. after external state changes)."""
        with self._cond:
            self._cond.notify_all()
