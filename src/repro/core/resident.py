"""ResidentStore — the engine-level content-addressed resident-matrix index.

DESIGN.md §8. The Alchemist papers stress that the server amortizes data
movement *across* clients: several Spark/Dask applications connect to one
Alchemist instance and share its worker-side matrices (arXiv:1805.11800,
arXiv:1910.01354). Until this layer existed, both the resident-matrix cache
(§6) and the memory governor (§7) were session-scoped — two sessions sending
the same dataset shipped it across the bridge twice and budgeted it twice.

The store lifts content identity to the engine:

- every non-cyclic send is **published** under its content key
  (:func:`repro.core.expr.content_key`): the entry records the host payload
  (when the caller can hand one over for free — the planner's snapshotted
  ``SendExpr`` arrays), plus one *placement* per session that holds the
  matrix on its worker group;
- a second session sending byte-identical data **attaches** instead: no
  bytes cross the client↔engine bridge — the engine already has them — and
  the session's placement is a plain engine-internal ``device_put`` from the
  entry's payload (counted as ``cross_session_reuses`` in that session's
  stats, and as ``attaches`` here);
- placements **pin** the entry: the refcount is the number of live
  placements, the session-pin set the sessions holding them. An explicit
  ``free`` unpins, and the entry dies with its last placement — exactly the
  old per-session lifecycle, observed through the store;
- when a session **closes**, its uniquely-referenced entries are *migrated*
  rather than freed: the device placement is dropped (its HBM charge with
  it), but the logical payload is kept host-side so a later session can
  refill the same content by key without ever re-crossing the bridge. The
  migration staging area is the same host-side plane the governor's spill
  store lives on (§7): ``ensure_payload`` pulls the bytes from the entry's
  snapshot, the handle's host fallback, the governor's host store, or — last
  resort — a ``device_get`` of the live placement.

Sessions therefore *view* the store: their handle tables hold per-session
placement handles (an :class:`~repro.core.handles.AlMatrix` whose
``store_key`` names the entry), and pin/unpin entries instead of owning the
content. The store is deliberately host-metadata only — device residency,
budgets, and spill/refill stay the engine-wide governor's job.

Cyclic layouts bypass the store: their resident form is a physical row
permutation of the payload, which does not round-trip through the pure
placement plan the attach/refill paths use (see ``pad_amounts``).
"""

from __future__ import annotations

import itertools
import threading
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import handles as handles_mod
from repro.core.errors import HandleError, TaskError
from repro.core.handles import AlMatrix

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.core.session import Session

_CLOCK = itertools.count(1)


class ResidentEntry:
    """One content-addressed resident matrix: host payload + placements."""

    def __init__(self, key: Tuple, shape: Tuple[int, int], dtype, layout):
        self.key = key
        self.shape = tuple(int(d) for d in shape)
        self.dtype = dtype
        self.layout = layout
        #: logical host bytes (row-major, unpadded) — None until a publisher
        #: hands them over or a migration/attach fetches them.
        self.payload: Optional[np.ndarray] = None
        #: session id -> that session's placement handles (usually one).
        self.placements: Dict[int, List[AlMatrix]] = {}
        #: ids of sessions whose placement was *migrated* out (session close
        #: secured the payload host-side). Lets the fleet recovery enumerate
        #: a drained session's content after the drain already ran — explicit
        #: frees never land here (a user free means the content is done).
        self.former_sessions: set = set()
        #: ids of the worker-group devices that most recently held a
        #: placement of this content — the admission-time affinity signal
        #: (DESIGN.md §9): a later ``connect(datasets=...)`` prefers the free
        #: block these ids name, so warm content is reused in place.
        self.device_ids: frozenset = frozenset()
        self.last_use: int = next(_CLOCK)

    # -- pin accounting ------------------------------------------------------
    @property
    def refcount(self) -> int:
        """Live placements across all sessions (the entry's pin count)."""
        return sum(len(hs) for hs in self.placements.values())

    @property
    def sessions(self) -> Tuple[int, ...]:
        """The session-pin set: ids of sessions holding a placement."""
        return tuple(sorted(self.placements))

    def handles_for(self, session_id: int) -> List[AlMatrix]:
        return list(self.placements.get(session_id, ()))

    def live_handle_for(self, session_id: int) -> Optional[AlMatrix]:
        for h in self.placements.get(session_id, ()):
            if h.is_live:
                return h
        return None

    def live_handles(self) -> List[AlMatrix]:
        return [h for hs in self.placements.values() for h in hs if h.is_live]

    def usable(self) -> bool:
        """Can a new placement be produced without a bridge crossing?"""
        return self.payload is not None or bool(self.live_handles())

    def nbytes(self) -> int:
        if self.payload is not None:
            return int(self.payload.nbytes)
        n = 1
        for d in self.shape:
            n *= d
        return n * jax.numpy.dtype(self.dtype).itemsize

    def __repr__(self) -> str:
        return (
            f"ResidentEntry(shape={self.shape}, refcount={self.refcount}, "
            f"sessions={list(self.sessions)}, payload={self.payload is not None})"
        )


class ResidentStore:
    """Engine-wide content index of resident matrices (DESIGN.md §8).

    ``enabled=False`` turns every lookup into a miss and every publish into a
    no-op — the session-scoped pre-store behaviour, kept as an explicit
    baseline for benchmarks (``AlchemistEngine(share_residents=False)``).

    ``retain_bytes`` caps the host bytes held by *orphaned* entries (content
    migrated out of closed sessions, awaiting a future attach); the oldest
    orphans are evicted beyond it. ``None`` retains everything — fine for
    tests and short-lived engines, bound it for long-running servers.
    """

    def __init__(self, enabled: bool = True, retain_bytes: Optional[int] = None):
        self.enabled = enabled
        self.retain_bytes = retain_bytes
        self._entries: Dict[Tuple, ResidentEntry] = {}
        self._lock = threading.RLock()
        self.publishes = 0
        self.attaches = 0
        self.migrations = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # -- index ---------------------------------------------------------------
    def lookup(self, key: Tuple) -> Optional[ResidentEntry]:
        """The entry for ``key`` (pruned of dead placements), or None."""
        if not self.enabled:
            return None
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                return None
            self._prune(entry)
            if not entry.usable() and entry.refcount == 0:
                # the content died everywhere (failed send, freed before any
                # payload was captured): forget it so the caller re-sends
                del self._entries[key]
                return None
            return entry

    def register(
        self,
        key: Tuple,
        handle: AlMatrix,
        session: "Session",
        payload: Optional[np.ndarray] = None,
    ) -> ResidentEntry:
        """Publish a (possibly still pending) placement under ``key``.

        Called by the send path for the producing session and by the attach
        path for every subsequent one; idempotent per handle. ``payload`` —
        the logical host bytes — is captured when the caller already owns a
        private copy (the planner's snapshotted send arrays), making later
        migration and cross-session placement free.
        """
        if not self.enabled:
            return ResidentEntry(key, handle.shape, handle.dtype, handle.layout)
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                entry = ResidentEntry(key, handle.shape, handle.dtype, handle.layout)
                self._entries[key] = entry
                self.publishes += 1
            if payload is not None and entry.payload is None:
                entry.payload = np.asarray(payload)
            hs = entry.placements.setdefault(session.id, [])
            if handle not in hs:
                hs.append(handle)
            handle.store_key = key
            if entry.payload is not None:
                handle._host_fallback = entry.payload
            devices = getattr(session, "worker_devices", ())
            if devices:
                entry.device_ids = frozenset(d.id for d in devices)
            entry.last_use = next(_CLOCK)
            return entry

    def record_attach(self) -> None:
        with self._lock:
            self.attaches += 1

    def device_affinity(self, keys) -> List[frozenset]:
        """Device-id sets that last held each of the given content keys.

        The admission-time placement signal (DESIGN.md §9): only *usable*
        entries count — content that can actually produce a new placement
        without a bridge crossing (a live placement or a host payload).
        Unknown keys and dead entries contribute nothing, so a declared
        dataset the engine has never seen simply doesn't steer placement.
        """
        if not self.enabled:
            return []
        out: List[frozenset] = []
        with self._lock:
            for key in keys:
                entry = self._entries.get(key)
                if entry is None:
                    continue
                self._prune(entry)
                if entry.device_ids and entry.usable():
                    out.append(entry.device_ids)
        return out

    # -- unpin / teardown ----------------------------------------------------
    def release(self, key: Tuple, session_id: int, handle: AlMatrix) -> None:
        """Explicit free of one placement: unpin, and drop the entry with its
        last pin (a user free means "this content is done", unlike a session
        close, which migrates)."""
        if not self.enabled:
            return
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                return
            hs = entry.placements.get(session_id)
            if hs is not None:
                hs[:] = [h for h in hs if h is not handle]
                if not hs:
                    del entry.placements[session_id]
            if entry.refcount == 0:
                del self._entries[key]

    def detach_session(self, session: "Session") -> int:
        """Session close: unpin every entry this session placed.

        Entries still pinned elsewhere just lose this session's placement;
        uniquely-referenced ones are **migrated** — the payload is secured
        host-side first (``ensure_payload``, staging through the governor's
        host store when the placement is spilled), then the device placement
        is freed. Returns the number of migrations.
        """
        if not self.enabled:
            return 0
        with self._lock:
            mine = [
                (entry, entry.placements.get(session.id, []))
                for entry in list(self._entries.values())
                if session.id in entry.placements
            ]
        migrated = 0
        for entry, hs in mine:
            sole = set(entry.sessions) <= {session.id}
            if sole and self.ensure_payload(entry) is not None:
                migrated += 1
            with self._lock:
                for h in hs:
                    if h.is_live:
                        h.free()  # drops the HBM charge + any spill bytes
                entry.placements.pop(session.id, None)
                if entry.payload is not None:
                    entry.former_sessions.add(session.id)
                if entry.refcount == 0 and entry.payload is None:
                    # nothing left to refill from: forget the key
                    self._entries.pop(entry.key, None)
        with self._lock:
            self.migrations += migrated
        self._enforce_retention()
        return migrated

    def clear(self) -> None:
        """Engine shutdown: drop every entry (placements were freed by their
        sessions' close)."""
        with self._lock:
            self._entries.clear()

    # -- lineage recovery (DESIGN.md §14) ------------------------------------
    def recoverable_for(self, session_id: int) -> Dict[Tuple, ResidentEntry]:
        """Content this session pinned whose host bytes can still be secured.

        The fleet recovery planner's enumeration step: for each entry the
        (dead) session holds a placement of, try ``ensure_payload`` — the
        snapshot captured at publish time, a host fallback, or the governor's
        spill store all survive an engine death because they live host-side.
        Entries whose bytes are gone everywhere are simply omitted: their
        content re-enters through lineage replay (the ``SendExpr`` that
        produced them re-runs), not through the store.
        """
        if not self.enabled:
            return {}
        with self._lock:
            mine = [
                entry
                for entry in self._entries.values()
                if session_id in entry.placements
                or session_id in entry.former_sessions
            ]
        out: Dict[Tuple, ResidentEntry] = {}
        for entry in mine:
            if self.ensure_payload(entry) is not None:
                out[entry.key] = entry
        return out

    def adopt(self, entry: ResidentEntry) -> bool:
        """Import another store's entry as an orphan: payload only, no
        placements, no pins.

        The recovery path seeds the *surviving* engine's store with the dead
        engine's secured payloads, so the re-admitted session's re-lowered
        sends take the attach path — content refills by key with zero bytes
        re-crossing the client↔engine bridge, exactly like a
        migration-on-close refill. Returns True when the payload was adopted
        (new key, or backfilled a payload-less local entry).
        """
        if not self.enabled or entry.payload is None:
            return False
        with self._lock:
            local = self._entries.get(entry.key)
            if local is None:
                local = ResidentEntry(entry.key, entry.shape, entry.dtype, entry.layout)
                local.payload = entry.payload
                self._entries[entry.key] = local
                self.publishes += 1
                adopted = True
            elif local.payload is None:
                local.payload = entry.payload
                adopted = True
            else:
                adopted = False
            local.last_use = next(_CLOCK)
        self._enforce_retention()
        return adopted

    # -- payload staging -----------------------------------------------------
    def ensure_payload(self, entry: ResidentEntry) -> Optional[np.ndarray]:
        """Secure the entry's logical host bytes, fetching them if needed.

        Source order: the entry's snapshot, a placement's host fallback, the
        governor's host store (a spilled placement — no refill performed),
        then a ``device_get`` of a live placement. May block on a *producer*
        placement whose transfer is still in flight (cross-session wait: the
        producer's FIFO owes no task to ours, so this cannot deadlock);
        pending **attach** placements are never used as sources — they
        consume this very payload, and waiting on one (our own, or a sibling
        session's) would deadlock the queue workers against each other.
        Returns None when the content is gone everywhere.
        """
        with self._lock:
            if entry.payload is not None:
                return entry.payload
            candidates = [
                h
                for h in entry.live_handles()
                if not (h._placement_only and h.state == handles_mod.PENDING)
            ]
        for h in candidates:
            payload = self._payload_from(h)
            if payload is not None:
                with self._lock:
                    if entry.payload is None:
                        entry.payload = payload
                    # Backfill every live placement: any of them can now
                    # spill for free (drop device bytes, no device_get) and
                    # refill from the entry instead of a private host copy.
                    for live in entry.live_handles():
                        if live._host_fallback is None:
                            live._host_fallback = entry.payload
                    return entry.payload
        return None

    @staticmethod
    def _payload_from(h: AlMatrix) -> Optional[np.ndarray]:
        if h._host_fallback is not None:
            return h._host_fallback
        gov = h._governor
        if gov is not None:
            host = gov.host_payload(h)
            if host is not None:  # spilled: physical bytes, pads still on
                return np.asarray(host[: h.shape[0], : h.shape[1]])
        try:
            return np.asarray(jax.device_get(h.data()))
        except (HandleError, TaskError):
            return None  # freed or failed under us: try the next placement

    # -- maintenance ---------------------------------------------------------
    def _prune(self, entry: ResidentEntry) -> None:
        # caller holds self._lock
        for sid in list(entry.placements):
            hs = [h for h in entry.placements[sid] if h.is_live]
            if hs:
                entry.placements[sid] = hs
            else:
                del entry.placements[sid]

    def _enforce_retention(self) -> None:
        if self.retain_bytes is None:
            return
        with self._lock:
            orphans = [
                e for e in self._entries.values() if e.refcount == 0 and e.payload is not None
            ]
            held = sum(e.nbytes() for e in orphans)
            for e in sorted(orphans, key=lambda e: e.last_use):
                if held <= self.retain_bytes:
                    break
                held -= e.nbytes()
                del self._entries[e.key]
                self.evictions += 1

    def stats(self) -> Dict[str, int]:
        with self._lock:
            orphaned = sum(1 for e in self._entries.values() if e.refcount == 0)
            return {
                "entries": len(self._entries),
                "orphaned": orphaned,
                "pinned": len(self._entries) - orphaned,
                "payload_bytes": sum(
                    e.nbytes() for e in self._entries.values() if e.payload is not None
                ),
                "publishes": self.publishes,
                "attaches": self.attaches,
                "migrations": self.migrations,
                "evictions": self.evictions,
            }

    def snapshot(self) -> Dict[Tuple, Dict]:
        """Per-entry view for tests/debugging."""
        with self._lock:
            return {
                key: {
                    "refcount": e.refcount,
                    "sessions": list(e.sessions),
                    "payload": e.payload is not None,
                    "states": [h.state for h in e.live_handles()],
                }
                for key, e in self._entries.items()
            }

    def __repr__(self) -> str:
        s = self.stats()
        return (
            f"ResidentStore(entries={s['entries']}, pinned={s['pinned']}, "
            f"orphaned={s['orphaned']}, attaches={s['attaches']}, "
            f"migrations={s['migrations']})"
        )
