"""Typed scalar-parameter packing — the ``Parameters`` header analogue.

Paper §2.1/§3.5: non-distributed inputs (step sizes, iteration counts,
cut-offs, routine names) travel driver-to-driver via serialization, separate
from the worker-to-worker distributed payloads. §3.5: "The Parameters header
file performs the serialization and deserialization of a wide array of
standard types, as well as pointers to Elemental distributed matrices."

Here the pack format is a compact, versioned binary frame (struct-packed),
and "pointers to distributed matrices" serialize as handle ids — exactly the
paper's split: metadata crosses as bytes, matrix payloads never do.

This layer is also what a real multi-controller deployment would put on the
wire between the client process and the engine controller, so it is
implemented and tested as a genuine codec, not a dict passthrough.
"""

from __future__ import annotations

import struct
from typing import Any, Dict, Tuple

import numpy as np

from repro.core.errors import ParameterError
from repro.core.handles import AlMatrix

_MAGIC = b"ALPK"
_VERSION = 2

# type tags
_T_INT = 0x01
_T_FLOAT = 0x02
_T_BOOL = 0x03
_T_STR = 0x04
_T_MATRIX_HANDLE = 0x05
_T_INT_LIST = 0x06
_T_FLOAT_LIST = 0x07
_T_NONE = 0x08


def _pack_str(s: str) -> bytes:
    b = s.encode("utf-8")
    return struct.pack("<I", len(b)) + b


def _unpack_str(buf: memoryview, off: int) -> Tuple[str, int]:
    (n,) = struct.unpack_from("<I", buf, off)
    off += 4
    return bytes(buf[off : off + n]).decode("utf-8"), off + n


def pack(params: Dict[str, Any]) -> bytes:
    """Serialize a flat dict of scalars / small lists / AlMatrix handles."""
    out = [_MAGIC, struct.pack("<HI", _VERSION, len(params))]
    for key, val in params.items():
        out.append(_pack_str(key))
        if val is None:
            out.append(struct.pack("<B", _T_NONE))
        elif isinstance(val, bool):  # before int: bool is an int subclass
            out.append(struct.pack("<BB", _T_BOOL, int(val)))
        elif isinstance(val, (int, np.integer)):
            out.append(struct.pack("<Bq", _T_INT, int(val)))
        elif isinstance(val, (float, np.floating)):
            out.append(struct.pack("<Bd", _T_FLOAT, float(val)))
        elif isinstance(val, str):
            out.append(struct.pack("<B", _T_STR) + _pack_str(val))
        elif isinstance(val, AlMatrix):
            out.append(
                struct.pack(
                    "<Bqqqq",
                    _T_MATRIX_HANDLE,
                    val.id,
                    val.session_id,
                    val.shape[0],
                    val.shape[1],
                )
                + _pack_str(np.dtype(val.dtype).name)
                + _pack_str(val.layout.name)
            )
        elif isinstance(val, (list, tuple)) and all(
            isinstance(v, (int, np.integer)) and not isinstance(v, bool) for v in val
        ):
            vals = [int(v) for v in val]
            out.append(struct.pack(f"<BI{len(val)}q", _T_INT_LIST, len(val), *vals))
        elif isinstance(val, (list, tuple)) and all(
            isinstance(v, (float, np.floating)) for v in val
        ):
            vals = [float(v) for v in val]
            out.append(struct.pack(f"<BI{len(val)}d", _T_FLOAT_LIST, len(val), *vals))
        else:
            raise ParameterError(
                f"cannot pack parameter {key!r} of type {type(val).__name__}; "
                "supported: int, float, bool, str, None, AlMatrix, int/float lists"
            )
    return b"".join(out)


class HandleRef:
    """Deserialized stand-in for an AlMatrix — carries only metadata.

    The engine resolves it back to the live handle via its session table;
    this is the 'pointer to a DistMatrix' of the paper.
    """

    def __init__(
        self, handle_id: int, session_id: int, shape: Tuple[int, int], dtype: str, layout: str
    ):
        self.id = handle_id
        self.session_id = session_id
        self.shape = shape
        self.dtype = dtype
        self.layout = layout

    def __repr__(self) -> str:
        return f"HandleRef(id={self.id}, session={self.session_id}, shape={self.shape})"


def unpack(buf: bytes) -> Dict[str, Any]:
    """Inverse of :func:`pack`. AlMatrix entries come back as HandleRef."""
    mv = memoryview(buf)
    if bytes(mv[:4]) != _MAGIC:
        raise ParameterError("bad magic — not an ALPK parameter frame")
    version, count = struct.unpack_from("<HI", mv, 4)
    if version > _VERSION:
        raise ParameterError(f"frame version {version} newer than supported {_VERSION}")
    off = 10
    out: Dict[str, Any] = {}
    for _ in range(count):
        key, off = _unpack_str(mv, off)
        (tag,) = struct.unpack_from("<B", mv, off)
        off += 1
        if tag == _T_NONE:
            out[key] = None
        elif tag == _T_BOOL:
            (v,) = struct.unpack_from("<B", mv, off)
            off += 1
            out[key] = bool(v)
        elif tag == _T_INT:
            (v,) = struct.unpack_from("<q", mv, off)
            off += 8
            out[key] = v
        elif tag == _T_FLOAT:
            (v,) = struct.unpack_from("<d", mv, off)
            off += 8
            out[key] = v
        elif tag == _T_STR:
            out[key], off = _unpack_str(mv, off)
        elif tag == _T_MATRIX_HANDLE:
            hid, sid, r, c = struct.unpack_from("<qqqq", mv, off)
            off += 32
            dtype, off = _unpack_str(mv, off)
            layout, off = _unpack_str(mv, off)
            out[key] = HandleRef(hid, sid, (r, c), dtype, layout)
        elif tag == _T_INT_LIST:
            (n,) = struct.unpack_from("<I", mv, off)
            off += 4
            out[key] = list(struct.unpack_from(f"<{n}q", mv, off))
            off += 8 * n
        elif tag == _T_FLOAT_LIST:
            (n,) = struct.unpack_from("<I", mv, off)
            off += 4
            out[key] = list(struct.unpack_from(f"<{n}d", mv, off))
            off += 8 * n
        else:
            raise ParameterError(f"unknown type tag 0x{tag:02x} for key {key!r}")
    return out
