"""Typed scalar-parameter packing — the ``Parameters`` header analogue.

Paper §2.1/§3.5: non-distributed inputs (step sizes, iteration counts,
cut-offs, routine names) travel driver-to-driver via serialization, separate
from the worker-to-worker distributed payloads. §3.5: "The Parameters header
file performs the serialization and deserialization of a wide array of
standard types, as well as pointers to Elemental distributed matrices."

Here the pack format is a compact, versioned binary frame (struct-packed),
and "pointers to distributed matrices" serialize as handle ids — exactly the
paper's split: metadata crosses as bytes, matrix payloads never do.

Since DESIGN.md §11 this codec sits on a real socket (``serve.wire``), so
:func:`unpack` is hardened against hostile input: every read is
bounds-checked, and any malformed frame — truncated, corrupt, trailing
garbage — raises :class:`~repro.core.errors.ParameterError`, never a raw
``struct.error`` or ``UnicodeDecodeError``. A garbage read off the wire must
surface as a protocol error the server loop can map, not an undeclared crash.
"""

from __future__ import annotations

import struct
from typing import Any, Dict, Tuple, Union

import numpy as np

from repro.core.errors import ParameterError
from repro.core.handles import AlMatrix

_MAGIC = b"ALPK"
# v3: empty lists get their own tag (v2 packed every empty list as
# _T_INT_LIST, silently changing a float list's element type across the
# wire). Readers accept every version <= theirs; v2 frames contain no
# _T_EMPTY_LIST so they decode unchanged.
_VERSION = 3

# type tags
_T_INT = 0x01
_T_FLOAT = 0x02
_T_BOOL = 0x03
_T_STR = 0x04
_T_MATRIX_HANDLE = 0x05
_T_INT_LIST = 0x06
_T_FLOAT_LIST = 0x07
_T_NONE = 0x08
_T_EMPTY_LIST = 0x09


def _pack_str(s: str) -> bytes:
    b = s.encode("utf-8")
    return struct.pack("<I", len(b)) + b


class _FrameReader:
    """Bounds-checked cursor over a parameter frame. Every decode error —
    overrun, bad struct data, invalid utf-8 — comes out as ParameterError
    with the offset, so a socket feeding garbage produces a mappable
    protocol error instead of crashing the server loop."""

    __slots__ = ("mv", "off")

    def __init__(self, buf: Union[bytes, memoryview]):
        self.mv = memoryview(buf)
        self.off = 0

    def need(self, n: int, what: str) -> None:
        if self.off + n > len(self.mv):
            raise ParameterError(
                f"truncated ALPK frame: need {n} byte(s) for {what} at offset "
                f"{self.off}, have {len(self.mv) - self.off}"
            )

    def take(self, fmt: str, what: str) -> Tuple:
        self.need(struct.calcsize(fmt), what)
        try:
            vals = struct.unpack_from(fmt, self.mv, self.off)
        except struct.error as exc:  # pragma: no cover - need() guards sizes
            raise ParameterError(f"corrupt ALPK frame at {what}: {exc}") from None
        self.off += struct.calcsize(fmt)
        return vals

    def take_str(self, what: str) -> str:
        (n,) = self.take("<I", f"{what} length")
        self.need(n, what)
        raw = bytes(self.mv[self.off : self.off + n])
        self.off += n
        try:
            return raw.decode("utf-8")
        except UnicodeDecodeError as exc:
            raise ParameterError(f"corrupt ALPK frame: {what} is not utf-8 ({exc})") from None


def pack(params: Dict[str, Any]) -> bytes:
    """Serialize a flat dict of scalars / small lists / AlMatrix handles.

    :class:`HandleRef` packs identically to the AlMatrix it stands in for,
    so a decoded frame can be re-encoded — the engine side of the wire
    (DESIGN.md §11) forwards matrix references without resolving them first.
    """
    out = [_MAGIC, struct.pack("<HI", _VERSION, len(params))]
    for key, val in params.items():
        out.append(_pack_str(key))
        if val is None:
            out.append(struct.pack("<B", _T_NONE))
        elif isinstance(val, bool):  # before int: bool is an int subclass
            out.append(struct.pack("<BB", _T_BOOL, int(val)))
        elif isinstance(val, (int, np.integer)):
            out.append(struct.pack("<Bq", _T_INT, int(val)))
        elif isinstance(val, (float, np.floating)):
            out.append(struct.pack("<Bd", _T_FLOAT, float(val)))
        elif isinstance(val, str):
            out.append(struct.pack("<B", _T_STR) + _pack_str(val))
        elif isinstance(val, (AlMatrix, HandleRef)):
            layout = val.layout
            out.append(
                struct.pack(
                    "<Bqqqq",
                    _T_MATRIX_HANDLE,
                    val.id,
                    val.session_id,
                    val.shape[0],
                    val.shape[1],
                )
                + _pack_str(np.dtype(val.dtype).name)
                + _pack_str(layout if isinstance(layout, str) else layout.name)
            )
        elif isinstance(val, (list, tuple)) and len(val) == 0:
            # A dedicated tag: the element-typed list tags below are
            # vacuously satisfied by [], and which one an empty list landed
            # on must not depend on branch order (a wire peer decodes the
            # tag, not the sender's intent).
            out.append(struct.pack("<B", _T_EMPTY_LIST))
        elif isinstance(val, (list, tuple)) and all(
            isinstance(v, (int, np.integer)) and not isinstance(v, bool) for v in val
        ):
            vals = [int(v) for v in val]
            out.append(struct.pack(f"<BI{len(val)}q", _T_INT_LIST, len(val), *vals))
        elif isinstance(val, (list, tuple)) and all(
            isinstance(v, (float, np.floating)) for v in val
        ):
            vals = [float(v) for v in val]
            out.append(struct.pack(f"<BI{len(val)}d", _T_FLOAT_LIST, len(val), *vals))
        elif isinstance(val, (list, tuple)):
            kinds = sorted({type(v).__name__ for v in val})
            raise ParameterError(
                f"cannot pack parameter {key!r}: list elements must be all-int "
                f"or all-float, got mixed/unsupported element types {kinds} "
                "(cast to one numeric type first; bools are not list elements)"
            )
        else:
            raise ParameterError(
                f"cannot pack parameter {key!r} of type {type(val).__name__}; "
                "supported: int, float, bool, str, None, AlMatrix, int/float lists"
            )
    return b"".join(out)


class HandleRef:
    """Deserialized stand-in for an AlMatrix — carries only metadata.

    The engine resolves it back to the live handle via its session table;
    this is the 'pointer to a DistMatrix' of the paper.
    """

    def __init__(
        self, handle_id: int, session_id: int, shape: Tuple[int, int], dtype: str, layout: str
    ):
        self.id = handle_id
        self.session_id = session_id
        self.shape = shape
        self.dtype = dtype
        self.layout = layout

    def __repr__(self) -> str:
        return f"HandleRef(id={self.id}, session={self.session_id}, shape={self.shape})"


def unpack(buf: Union[bytes, memoryview]) -> Dict[str, Any]:
    """Inverse of :func:`pack`. AlMatrix entries come back as HandleRef.

    Raises :class:`ParameterError` — and only ParameterError — on any
    malformed input: bad magic, unsupported version, truncation at any
    offset, corrupt strings, unknown tags, or trailing bytes after the
    declared item count (a frame is exact, not a prefix of one).
    """
    r = _FrameReader(buf)
    r.need(4, "magic")
    if bytes(r.mv[:4]) != _MAGIC:
        raise ParameterError("bad magic — not an ALPK parameter frame")
    r.off = 4
    version, count = r.take("<HI", "header")
    if version > _VERSION:
        raise ParameterError(f"frame version {version} newer than supported {_VERSION}")
    out: Dict[str, Any] = {}
    for _ in range(count):
        key = r.take_str("key")
        (tag,) = r.take("<B", f"tag for key {key!r}")
        if tag == _T_NONE:
            out[key] = None
        elif tag == _T_BOOL:
            (v,) = r.take("<B", f"bool {key!r}")
            out[key] = bool(v)
        elif tag == _T_INT:
            (v,) = r.take("<q", f"int {key!r}")
            out[key] = v
        elif tag == _T_FLOAT:
            (v,) = r.take("<d", f"float {key!r}")
            out[key] = v
        elif tag == _T_STR:
            out[key] = r.take_str(f"str {key!r}")
        elif tag == _T_MATRIX_HANDLE:
            hid, sid, rows, cols = r.take("<qqqq", f"handle {key!r}")
            dtype = r.take_str(f"handle dtype {key!r}")
            layout = r.take_str(f"handle layout {key!r}")
            out[key] = HandleRef(hid, sid, (rows, cols), dtype, layout)
        elif tag == _T_EMPTY_LIST:
            out[key] = []
        elif tag == _T_INT_LIST:
            (n,) = r.take("<I", f"list length {key!r}")
            out[key] = list(r.take(f"<{n}q", f"int list {key!r}"))
        elif tag == _T_FLOAT_LIST:
            (n,) = r.take("<I", f"list length {key!r}")
            out[key] = list(r.take(f"<{n}d", f"float list {key!r}"))
        else:
            raise ParameterError(f"unknown type tag 0x{tag:02x} for key {key!r}")
    if r.off != len(r.mv):
        raise ParameterError(
            f"{len(r.mv) - r.off} trailing byte(s) after {count} declared "
            "item(s) — not a well-formed ALPK frame"
        )
    return out
