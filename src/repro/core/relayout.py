"""The bridge: resharding matrices between layouts, with a transfer-cost model.

This module is the TPU adaptation of the paper's socket-transfer machinery
(§2.1 "The critical functionality of Alchemist is an efficient implementation
of communication for distributed data structures"). On Cori the bridge was
row-at-a-time TCP streams between Spark executors and MPI workers; on a TPU
mesh it is a single resharding boundary, lowered by XLA to
``all-to-all``/``collective-permute`` on ICI.

Two faces:

- :func:`relayout` / :func:`relayout_in_jit` — perform the resharding
  (eagerly via ``jax.device_put`` or inside a jitted program via
  ``with_sharding_constraint``).
- :func:`transfer_cost` — the analytic model of the same movement: exact
  bytes-that-change-owner and message counts per (src-device, dst-device)
  pair. This is what reproduces the *shape* of the paper's Tables 2–3
  (tall-skinny vs short-wide transfer behaviour) without a TCP wall clock:
  the row-granular wire format's cost reappears as message count and
  per-message size.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core.errors import LayoutError
from repro.core.layouts import LayoutSpec, cyclic_permutation, inverse_permutation

#: ops.pad_to / ops.strip_to path names that mean "the fused Pallas kernel
#: actually ran" (vs the jnp reference fallback). Consumers — the plan cache,
#: SessionStats.fused_relayouts, the governor's refill — test membership here
#: rather than string-matching, so adding a backend stays a one-line change.
FUSED_PATHS = ("pallas", "pallas-interpret")


def _kernel_ops():
    """Lazy kernels.ops import: relayout is imported by modules that must not
    pay the Pallas import (and kernels.ops probes the backend at import)."""
    from repro.kernels import ops as kops

    return kops


# ---------------------------------------------------------------------------
# Shard-interval geometry
# ---------------------------------------------------------------------------

def shard_intervals(n: int, n_shards: int) -> np.ndarray:
    """[n_shards, 2] (start, end) intervals of a block decomposition.

    XLA pads uneven dims: shard size is ceil(n / n_shards); trailing shards
    may be empty. end is clamped to n.
    """
    size = -(-n // n_shards)
    starts = np.arange(n_shards) * size
    ends = np.minimum(starts + size, n)
    starts = np.minimum(starts, n)
    return np.stack([starts, ends], axis=1)


@dataclasses.dataclass(frozen=True)
class ShardGeometry:
    """Row-slab decomposition a shard-direct wire transfer targets (§13).

    Describes how a 2D matrix staged under a pure row layout decomposes into
    per-device slabs: the wire can then align its chunk boundaries with the
    slabs, and a receiver can decode each slab straight into its own staging
    buffer and ``device_put`` it as the bytes land — no full-array reassembly.
    The wire carries *logical* bytes only; the receiver zero-fills each slab's
    divisibility-pad slack, which is where the pad "kernel" of the legacy path
    goes in this path (fused into the decode).
    """

    shape: Tuple[int, int]  # logical (rows, cols)
    physical_shape: Tuple[int, int]  # rows padded to a shard-count multiple
    dtype: str
    n_shards: int
    shard_rows: int  # physical rows per slab (physical_shape[0] / n_shards)
    #: logical (start, end) row interval each shard carries on the wire;
    #: trailing shards of a short matrix may be empty.
    intervals: Tuple[Tuple[int, int], ...]
    layout_name: str
    mesh_key: Tuple
    #: shard index -> the jax.Device owning that slab under the layout.
    devices: Tuple[Any, ...]

    @property
    def pads(self) -> Tuple[int, int]:
        return (self.physical_shape[0] - self.shape[0], 0)

    @property
    def itemsize(self) -> int:
        return int(np.dtype(self.dtype).itemsize)

    def slab_shape(self) -> Tuple[int, int]:
        return (self.shard_rows, self.shape[1])

    def logical_bytes(self, shard: int) -> int:
        s, e = self.intervals[shard]
        return (e - s) * self.shape[1] * self.itemsize

    def matches(self, layout: LayoutSpec, mesh: Mesh) -> bool:
        return self.layout_name == layout.name and self.mesh_key == _mesh_cache_key(mesh)


def shard_geometry(
    shape: Tuple[int, int], dtype, layout: LayoutSpec, mesh: Mesh
) -> Optional[ShardGeometry]:
    """The :class:`ShardGeometry` for staging ``shape`` under ``layout``, or
    None when the layout cannot take a shard-direct stream: cyclic layouts
    (rows are stored permuted), column-sharded or replicated layouts (a slab
    is not a contiguous byte range of the logical array), empty matrices, and
    dtypes jax would silently canonicalize away (an f64 payload under default
    x64-off must take the reassembly path, whose ``jnp.asarray`` converts)."""
    if layout.cyclic:
        return None
    rows, cols = int(shape[0]), int(shape[1])
    if rows <= 0 or cols <= 0:
        return None
    dt = np.dtype(dtype)
    try:
        if jax.dtypes.canonicalize_dtype(dt) != dt:
            return None
    except Exception:  # pragma: no cover - exotic dtypes: fall back
        return None
    n_r, n_c = layout.grid_shape(mesh)
    n_dev = int(np.asarray(mesh.devices).size)
    if n_c != 1 or n_r != n_dev:
        return None  # column shards or replication: slabs are not row slabs
    pr, _pc = pad_amounts((rows, cols), layout, mesh)
    phys = (rows + pr, cols)
    shard_rows = phys[0] // n_r
    sharding = layout.sharding(mesh)
    try:
        imap = sharding.addressable_devices_indices_map(phys)
    except Exception:  # pragma: no cover - non-addressable meshes
        return None
    by_start: Dict[int, Any] = {}
    for dev, idx in imap.items():
        r = idx[0]
        by_start[0 if r.start is None else int(r.start)] = dev
    devices = []
    for j in range(n_r):
        dev = by_start.get(j * shard_rows)
        if dev is None:
            return None
        devices.append(dev)
    return ShardGeometry(
        shape=(rows, cols),
        physical_shape=phys,
        dtype=dt.name,
        n_shards=n_r,
        shard_rows=shard_rows,
        intervals=tuple((int(s), int(e)) for s, e in shard_intervals(rows, n_r)),
        layout_name=layout.name,
        mesh_key=_mesh_cache_key(mesh),
        devices=tuple(devices),
    )


def staged_pad_path(pads: Tuple[int, int]) -> str:
    """Accounting parity for shard-direct receives: the divisibility pad is
    fused into the staged decode itself (slack rows are memset in the slab,
    no separate pad op ever runs), so report the path the kernel dispatch
    *would* have taken — ``SessionStats.fused_relayouts`` keeps one meaning
    across the legacy and staged send paths."""
    if pads == (0, 0):
        return "none"
    kops = _kernel_ops()
    return kops._BACKEND if kops.use_pallas() else "ref"


def _device_shard_coords(layout: LayoutSpec, mesh: Mesh) -> Tuple[np.ndarray, np.ndarray, int, int]:
    """For each device (flat order of mesh.devices): its (row-shard, col-shard)
    index under ``layout``, plus the grid shape (n_row_shards, n_col_shards)."""
    axis_names = list(mesh.axis_names)
    shape = mesh.devices.shape
    coords = np.indices(shape).reshape(len(shape), -1)  # [n_axes, n_dev]

    def shard_index(axes: Tuple[str, ...]) -> Tuple[np.ndarray, int]:
        idx = np.zeros(coords.shape[1], dtype=np.int64)
        total = 1
        for a in axes:
            if a not in axis_names:
                continue
            ai = axis_names.index(a)
            idx = idx * shape[ai] + coords[ai]
            total *= shape[ai]
        return idx, total

    row_idx, n_row = shard_index(layout.row_axes)
    col_idx, n_col = shard_index(layout.col_axes)
    return row_idx, col_idx, n_row, n_col


@dataclasses.dataclass(frozen=True)
class TransferCost:
    """Analytic cost of one relayout.

    Attributes:
      bytes_total: size of the matrix.
      bytes_moved: bytes that change device ownership (the ICI traffic).
      messages: number of (src device, dst device) pairs exchanging data.
      max_message_bytes / min_message_bytes: extremes over messages.
      row_fragments: number of distinct (row-slab x device-pair) fragments —
        the analogue of the paper's per-row sends; high counts are the
        tall-skinny penalty of Tables 2–3.
      replication_factor: dst copies per element (replicated layouts).
    """

    bytes_total: int
    bytes_moved: int
    messages: int
    max_message_bytes: int
    min_message_bytes: int
    row_fragments: int
    replication_factor: float

    @property
    def moved_fraction(self) -> float:
        return self.bytes_moved / max(self.bytes_total, 1)

    def ici_seconds(self, link_bw: float = 50e9, n_links: Optional[int] = None) -> float:
        """Lower-bound transfer time at ``link_bw`` bytes/s per device link."""
        links = n_links or 1
        return self.bytes_moved / (link_bw * links)


def transfer_cost(
    shape: Tuple[int, int],
    dtype,
    src: LayoutSpec,
    dst: LayoutSpec,
    mesh: Mesh,
) -> TransferCost:
    """Exact bytes/messages for a src→dst relayout of ``shape`` on ``mesh``.

    Model: under ``src`` each device owns a (row-interval x col-interval)
    block (devices sharing a shard index hold replicas; we count the src copy
    in the same mesh position as the canonical owner and charge replication
    on the destination side, which matches how XLA lowers broadcast-like
    resharding as all-gathers).
    """
    n_rows, n_cols = int(shape[0]), int(shape[1])
    itemsize = jnp.dtype(dtype).itemsize
    bytes_total = n_rows * n_cols * itemsize

    s_row_idx, s_col_idx, s_nr, s_nc = _device_shard_coords(src, mesh)
    d_row_idx, d_col_idx, d_nr, d_nc = _device_shard_coords(dst, mesh)

    s_rows = shard_intervals(n_rows, s_nr)
    s_cols = shard_intervals(n_cols, s_nc)
    d_rows = shard_intervals(n_rows, d_nr)
    d_cols = shard_intervals(n_cols, d_nc)

    n_dev = s_row_idx.shape[0]
    # Canonical source owner per src shard (first device holding that shard):
    # replicas don't re-send.
    owner = {}
    src_owner = np.zeros(n_dev, dtype=bool)
    for dev in range(n_dev):
        key = (int(s_row_idx[dev]), int(s_col_idx[dev]))
        if key not in owner:
            owner[key] = dev
            src_owner[dev] = True

    # Per-device intervals.
    sr = s_rows[s_row_idx]  # [n_dev, 2]
    sc = s_cols[s_col_idx]
    dr = d_rows[d_row_idx]
    dc = d_cols[d_col_idx]

    # Pairwise overlaps, vectorized: overlap length of [a0,a1) x [b0,b1).
    def overlap(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        lo = np.maximum(a[:, None, 0], b[None, :, 0])
        hi = np.minimum(a[:, None, 1], b[None, :, 1])
        return np.maximum(hi - lo, 0)

    row_ov = overlap(sr, dr)  # [src_dev, dst_dev]
    col_ov = overlap(sc, dc)
    elems = row_ov.astype(np.int64) * col_ov.astype(np.int64)
    elems[~src_owner, :] = 0  # replicas don't send
    np.fill_diagonal(elems, 0)  # data already in place is free

    msg_bytes = elems * itemsize
    nonzero = msg_bytes > 0
    bytes_moved = int(msg_bytes.sum())
    messages = int(nonzero.sum())
    max_msg = int(msg_bytes.max()) if messages else 0
    min_msg = int(msg_bytes[nonzero].min()) if messages else 0
    # Row fragments: each message carries row_ov distinct row slices (the
    # paper streamed each row separately; fragment count is the TCP-message
    # analogue).
    row_frag = int((row_ov * nonzero).sum())

    dst_copies = n_dev / (d_nr * d_nc)
    return TransferCost(
        bytes_total=bytes_total,
        bytes_moved=bytes_moved,
        messages=messages,
        max_message_bytes=max_msg,
        min_message_bytes=min_msg,
        row_fragments=row_frag,
        replication_factor=float(dst_copies),
    )


# ---------------------------------------------------------------------------
# Pad-to-divisible geometry
# ---------------------------------------------------------------------------
#
# ``jax.device_put`` into a NamedSharding (the bridge's send path) requires
# each sharded dim to be divisible by its shard count on jax 0.4.x, so e.g. a
# 6x6 matrix could not be sent to a 4-worker session. The bridge lifts this by
# padding each dim up to the next multiple of its destination shard count with
# zero rows/cols before ``device_put`` and slicing the padding back off on
# collect/refill. Padding amounts are part of the relayout plan; the handle
# layer records (pad_rows, pad_cols) so logical reads never see the zeros.


def pad_amounts(shape: Tuple[int, int], dst: LayoutSpec, mesh: Mesh) -> Tuple[int, int]:
    """(pad_rows, pad_cols) making ``shape`` shardable under ``dst`` on ``mesh``.

    Cyclic layouts cannot be padded: the emulation's row permutation is a
    function of the physical length, so appended zero rows would interleave
    into the interior and silently corrupt ``data()``/collect slicing. An
    uneven shape into a cyclic layout raises loudly instead (exactly the
    pre-padding behaviour of the bare ``device_put``).
    """
    n_r, n_c = dst.grid_shape(mesh)
    pads = (-int(shape[0])) % n_r, (-int(shape[1])) % n_c
    if pads != (0, 0) and dst.cyclic:
        raise LayoutError(
            f"shape {tuple(shape)} is not divisible for cyclic layout {dst.name!r} "
            f"(grid {n_r}x{n_c}); pad-to-divisible does not compose with the "
            "cyclic row permutation — pad the matrix explicitly before sending"
        )
    return pads


def pad_for(
    x: jax.Array, dst: LayoutSpec, mesh: Mesh
) -> Tuple[jax.Array, Tuple[int, int], str]:
    """Zero-pad ``x`` so ``device_put`` into ``dst`` is legal.

    Returns ``(padded, pads, path)`` where ``path`` names the kernel backend
    that performed the pad ("pallas"/"pallas-interpret"/"ref", see
    :data:`FUSED_PATHS`) or "none" when no padding was needed.
    """
    pads = pad_amounts(tuple(x.shape), dst, mesh)
    path = "none"
    if pads != (0, 0):
        m, n = int(x.shape[0]), int(x.shape[1])
        x, path = _kernel_ops().pad_to(x, (m + pads[0], n + pads[1]))
    return x, pads, path


# ---------------------------------------------------------------------------
# Performing the relayout
# ---------------------------------------------------------------------------

def relayout(
    x: jax.Array,
    dst: LayoutSpec,
    mesh: Mesh,
    *,
    src: Optional[LayoutSpec] = None,
    donate: bool = False,
) -> jax.Array:
    """Eagerly reshard ``x`` (a 2D matrix) into layout ``dst`` on ``mesh``.

    If the source layout was cyclic and the destination is not (or vice
    versa), the row permutation is applied/undone first. Shapes whose dims
    are not divisible by the destination shard counts are padded for the
    ``device_put`` and sliced back, so the logical shape is preserved.
    """
    dst.validate(x.shape, mesh)
    arr = x
    src_cyclic = bool(src.cyclic) if src is not None else False
    if src_cyclic != dst.cyclic:
        if dst.cyclic:
            n_shards = dst.grid_shape(mesh)[0]
        else:
            n_shards = src.grid_shape(mesh)[0] if src else 1
        perm = cyclic_permutation(x.shape[0], n_shards)
        if dst.cyclic:
            arr = jnp.take(arr, jnp.asarray(perm), axis=0)
        else:
            arr = jnp.take(arr, jnp.asarray(inverse_permutation(perm)), axis=0)
    arr, pads, _ = pad_for(arr, dst, mesh)
    out = jax.device_put(arr, dst.sharding(mesh), donate=donate)
    if pads != (0, 0):
        out, _ = _kernel_ops().strip_to(out, (x.shape[0], x.shape[1]))
    return out


def relayout_in_jit(x: jax.Array, dst: LayoutSpec, mesh: Mesh) -> jax.Array:
    """Resharding boundary usable inside a jitted program."""
    return jax.lax.with_sharding_constraint(x, dst.sharding(mesh))


# ---------------------------------------------------------------------------
# Relayout plan cache
# ---------------------------------------------------------------------------

def _mesh_cache_key(mesh: Mesh) -> Tuple:
    """Hashable identity of a mesh: axis names, grid shape, device ids."""
    devices = np.asarray(mesh.devices, dtype=object).ravel()
    return (
        tuple(mesh.axis_names),
        tuple(np.asarray(mesh.devices).shape),
        tuple(getattr(d, "id", i) for i, d in enumerate(devices)),
    )


@dataclasses.dataclass
class RelayoutPlan:
    """Everything derivable from (shape, dtype, src, dst, mesh) alone.

    Building a plan is the expensive, data-independent half of a transfer:
    the O(n_devices^2) shard-geometry sweep of :func:`transfer_cost`, the
    cyclic row permutation (an O(n_rows) host-side index build shipped to
    device), and the destination NamedSharding. A cached plan turns a repeat
    send/collect of the same (shape, dtype, layout pair, mesh) into a single
    ``device_put`` — the paper's "minimal communication overhead" claim made
    structural (DESIGN.md §5).
    """

    shape: Tuple[int, int]
    dtype: Any
    src_name: str
    dst_name: str
    cost: TransferCost
    dst_sharding: NamedSharding
    permutation: Optional[jnp.ndarray]  # pre-relayout row permutation, if any
    pads: Tuple[int, int] = (0, 0)  # zero rows/cols appended for divisibility
    uses: int = 0
    #: Kernel backend that ran this plan's last pad or strip — a member of
    #: :data:`FUSED_PATHS` when the fused Pallas kernel compiled, "ref" for
    #: the jnp fallback, None for unpadded plans. Last-write-wins across
    #: threads is fine: the plan's geometry is fixed, so every apply of the
    #: same plan takes the same path (the backend probe is module-static).
    fused_path: Optional[str] = None

    @property
    def physical_shape(self) -> Tuple[int, int]:
        return (self.shape[0] + self.pads[0], self.shape[1] + self.pads[1])

    def apply(self, x: jax.Array, *, donate: bool = False) -> jax.Array:
        """Execute the planned relayout on ``x`` (async-dispatched).

        Returns the *physical* (possibly padded) array; use :meth:`strip` to
        recover the logical matrix, or keep it padded for residency and strip
        on read (the handle layer's choice). With ``donate=True`` the input
        buffer is donated to the ``device_put`` (the governor's refill path:
        its host staging copy is dead after the put).
        """
        arr = x
        if self.permutation is not None:
            arr = jnp.take(arr, self.permutation, axis=0)
        if self.pads != (0, 0):
            arr, self.fused_path = _kernel_ops().pad_to(arr, self.physical_shape)
            # the pad kernel's output is ours alone — always safe to donate
            donate = True
        return jax.device_put(arr, self.dst_sharding, donate=donate)

    def strip(self, y: jax.Array) -> jax.Array:
        """Slice the divisibility padding back off a planned-relayout result."""
        if self.pads == (0, 0):
            return y
        out, self.fused_path = _kernel_ops().strip_to(y, self.shape)
        return out


class RelayoutPlanCache:
    """Per-session memo of :class:`RelayoutPlan`, keyed on
    ``(shape, dtype, src_layout, dst_layout, mesh)``.

    Thread-safe; hit/miss counters feed ``session.stats``.
    """

    def __init__(self):
        self._plans: Dict[Tuple, RelayoutPlan] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._plans)

    def plan(
        self,
        shape: Tuple[int, int],
        dtype,
        src: LayoutSpec,
        dst: LayoutSpec,
        mesh: Mesh,
    ) -> Tuple[RelayoutPlan, bool]:
        """Return ``(plan, was_cache_hit)`` for this relayout geometry."""
        key = (
            tuple(int(d) for d in shape),
            str(jnp.dtype(dtype)),
            src.name,
            dst.name,
            _mesh_cache_key(mesh),
        )
        with self._lock:
            cached = self._plans.get(key)
            if cached is not None:
                self.hits += 1
                cached.uses += 1
                return cached, True
            self.misses += 1
        # Build outside the lock: geometry sweeps can be slow and plans are
        # deterministic, so a racing double-build is harmless.
        built = self._build(shape, dtype, src, dst, mesh)
        with self._lock:
            plan = self._plans.setdefault(key, built)
            plan.uses += 1
        return plan, False

    @staticmethod
    def _build(shape, dtype, src: LayoutSpec, dst: LayoutSpec, mesh: Mesh) -> RelayoutPlan:
        dst.validate(shape, mesh)
        cost = transfer_cost(tuple(shape), dtype, src, dst, mesh)
        perm = None
        if bool(src.cyclic) != bool(dst.cyclic):
            n_shards = dst.grid_shape(mesh)[0] if dst.cyclic else src.grid_shape(mesh)[0]
            p = cyclic_permutation(shape[0], n_shards)
            if not dst.cyclic:
                p = inverse_permutation(p)
            perm = jnp.asarray(p)
        return RelayoutPlan(
            shape=tuple(shape),
            dtype=jnp.dtype(dtype),
            src_name=src.name,
            dst_name=dst.name,
            cost=cost,
            dst_sharding=dst.sharding(mesh),
            permutation=perm,
            pads=pad_amounts(tuple(shape), dst, mesh),
        )

    def stats(self) -> Dict[str, int]:
        with self._lock:
            fused = sum(1 for p in self._plans.values() if p.fused_path in FUSED_PATHS)
            return {
                "hits": self.hits,
                "misses": self.misses,
                "plans": len(self._plans),
                "fused_plans": fused,
            }


@dataclasses.dataclass
class TransferRecord:
    """One observed transfer: analytic cost + measured wall time."""

    direction: str  # "send" (client→engine) or "receive" (engine→client)
    cost: TransferCost
    seconds: float
    cache_hit: bool = False  # did the relayout plan come from the plan cache?
    pads: Tuple[int, int] = (0, 0)  # divisibility padding applied by the plan
    #: False for transfers that never consulted the plan cache (a collect
    #: served from the governor's host store) — they must not count toward
    #: the cache hit/miss rate.
    planned: bool = True
    #: Did the fused Pallas pad/strip kernel run for this transfer (vs the
    #: jnp reference or no padding at all)? Feeds SessionStats.fused_relayouts.
    fused: bool = False


def timed_relayout(
    x: jax.Array,
    dst: LayoutSpec,
    mesh: Mesh,
    *,
    src: LayoutSpec,
    direction: str = "send",
    cache: Optional[RelayoutPlanCache] = None,
    block: bool = True,
    strip: bool = True,
) -> Tuple[jax.Array, TransferRecord]:
    """Relayout + analytic cost + measured wall time, as one record.

    This is the engine's instrumented transfer path: the paper reports
    Send/Compute/Receive columns (Table 1); records produced here feed the
    same decomposition.

    With ``cache`` the shard geometry / permutation / sharding come from the
    session's :class:`RelayoutPlanCache`. With ``block=False`` the relayout is
    dispatched asynchronously and ``seconds`` measures dispatch only — the
    task-queue engine's pipelined path, where the wait is absorbed by the
    eventual ``collect``. With ``strip=False`` a divisibility-padded result is
    returned physical (padded); the caller records ``rec.pads`` against the
    handle so logical reads slice the zeros back off (the send path's choice
    — a resident matrix keeps its put-legal physical form for cheap refills).
    """
    hit = False
    pads = (0, 0)
    fused = False
    if cache is not None:
        plan, hit = cache.plan(tuple(x.shape), x.dtype, src, dst, mesh)
        cost = plan.cost
        pads = plan.pads
        t0 = time.perf_counter()
        out = plan.apply(x)
        if strip:
            out = plan.strip(out)
            pads = (0, 0)
        fused = plan.fused_path in FUSED_PATHS
    else:
        cost = transfer_cost(tuple(x.shape), x.dtype, src, dst, mesh)
        t0 = time.perf_counter()
        out = relayout(x, dst, mesh, src=src)  # pads + strips internally
    if block:
        out.block_until_ready()
    dt = time.perf_counter() - t0
    return out, TransferRecord(
        direction=direction, cost=cost, seconds=dt, cache_hit=hit, pads=pads, fused=fused
    )
